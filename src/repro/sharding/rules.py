"""Logical-axis -> mesh-axis sharding rules (MaxText-style, divisibility-aware).

Logical axes used throughout the model zoo:

  batch     -> ('pod', 'data')  [or ('data',) single-pod]
  seq       -> None             (activations: sequence replicated)
  embed     -> None             (d_model rows of weight matrices)
  heads     -> 'model'          (attention q heads)
  kv_heads  -> 'model'          (KV heads; replicated if too few)
  ffn       -> 'model'          (MLP hidden)
  expert    -> 'model'          (MoE expert axis)
  vocab     -> 'model'          (embedding / logits)
  stage     -> 'data'           (LIME pipeline: the data axis doubles as the
                                 pipeline-stage axis in the serving engine)
  layer     -> None             (scan-stacked layer dim)

A rule only applies when the dimension is divisible by the mesh-axis size;
otherwise the dim is replicated (this is what real launchers do for e.g.
gemma3's 4 q-heads on a 16-way model axis — the MLP still shards).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import spec as pspec

RULES = {
    "batch": ("pod", "data"),
    "seq": (),
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "expert": ("model",),
    "vocab": ("model",),
    "layer": (),
    "stage": ("data",),
    "kv_seq": (),
}


def fsdp_rules():
    """FSDP: weight matrices additionally sharded over 'data' on their
    d_model rows. Required when total_params x 2B / |model| exceeds the HBM
    weight budget (kimi-k2 1T: 2 TB / 16 = 125 GB/chip without it;
    8 GB/chip with). MoE experts stay sharded over 'model' during compute
    (token dispatch, not weight gather), so the data-dim psum only touches
    the expert einsum's contraction."""
    r = dict(RULES)
    r["embed"] = ("data",)
    return r


def dp_rules():
    """Pure data-parallel strategy: weights replicated across 'model',
    batch sharded over every mesh axis. The right call for small models
    on a big mesh, where 16-way tensor parallelism's per-layer allreduces
    dominate the step (EXPERIMENTS.md §Perf/H2)."""
    r = {k: tuple(a for a in v if a != "model") for k, v in RULES.items()}
    r["batch"] = ("pod", "data", "model")
    return r


def mesh_axis_size(mesh: Mesh, names: Tuple[str, ...]) -> int:
    n = 1
    for name in names:
        if name in mesh.shape:
            n *= mesh.shape[name]
    return n


def spec_for(shape, axes, mesh: Mesh, rules=None) -> P:
    rules = rules or RULES
    parts = []
    for dim, ax in zip(shape, axes):
        if ax is None:
            parts.append(None)
            continue
        mesh_axes = tuple(a for a in rules.get(ax, ()) if a in mesh.shape)
        size = mesh_axis_size(mesh, mesh_axes)
        if mesh_axes and size > 1 and dim % size == 0:
            parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shardings(specs, mesh: Mesh, rules=None):
    """NamedSharding tree for a ParamSpec tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for(s.shape, s.axes, mesh, rules)),
        specs, is_leaf=pspec.is_spec)


def partition_specs(specs, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda s: spec_for(s.shape, s.axes, mesh, rules),
        specs, is_leaf=pspec.is_spec)


def activation_sharding(mesh: Mesh, *axes: Optional[str], rules=None):
    """NamedSharding for an activation given logical axis names (None ok)."""
    rules = rules or RULES
    parts = []
    for ax in axes:
        if ax is None:
            parts.append(None)
        else:
            mesh_axes = tuple(a for a in rules.get(ax, ()) if a in mesh.shape)
            parts.append(mesh_axes if len(mesh_axes) > 1 else
                         (mesh_axes[0] if mesh_axes else None))
    return NamedSharding(mesh, P(*parts))
