"""Execution backends for LIME-Serve (DESIGN.md §9).

One protocol, two substrates:

  EngineBackend  the real thing — prefill on GSPMD params, cache adoption
                 into the InterleavedEngine layout, real sampled tokens,
                 wall-clock time. Batch membership is fixed once the caches
                 are seeded (`can_join_running = False`): the scheduler
                 runs it in epochs.
  SimBackend     the discrete-event InterleavedPipelineSim on a CostEnv —
                 virtual time, per-step micro-batch occupancy, planner/KV
                 protocol effects. Slots are bookkeeping
                 (`can_join_running = True`): continuous batching.

The protocol (duck-typed; SimBackend and EngineBackend are the reference
implementations):

  n_slots            micro-batch slots the substrate co-schedules
  can_join_running   may the scheduler refill freed slots mid-flight?
  now()              current time (wall or virtual, seconds)
  advance_to(t)      idle until t (arrival wait)
  kv_budget_tokens() fleet KV capacity in tokens, or None (unbounded)
  start_batch(reqs)  admit an idle-state batch; returns first token per
                     request (None where the substrate has no real tokens)
  decode_active(slots) one decode step; {slot: token-or-None} per live slot
  join(slot, req)    mid-flight admission (only if can_join_running)
  release(slot)      slot freed by the scheduler
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cost_model import CostEnv
from repro.core.pipeline_sim import InterleavedPipelineSim
from repro.obs import trace as tr_ev
from repro.obs.trace import get_tracer


# ============================================================================
# Simulator backend
# ============================================================================
class SimBackend:
    """Discrete-event substrate: prices each decode step by live occupancy.

    Per-request KV accounting feeds the OnlinePlanner: every step passes
    kv_tokens = ceil(Σ_active ctx_i / n_micro_env), the effective
    per-stream token count under the Workload's n_micro scaling — so the
    TS thresholds (paper Eq. 5) fire exactly when the *admitted* KV load
    says they should, not on a fixed token loop.
    """

    can_join_running = True

    def __init__(self, env: CostEnv, plan=None, *, n_slots: int = 0,
                 use_planner: bool = True, use_kv_transfer: bool = True,
                 prompt_tokens: int = 64, spec=None, adapt: bool = False,
                 refit: bool = False, true_env: Optional[CostEnv] = None):
        if plan is None:
            from repro.core.offline_scheduler import allocate
            r = allocate(env, env.work.cfg.n_layers,
                         n_emp=max(prompt_tokens, 1))
            if not r.feasible:
                raise ValueError(f"infeasible allocation: {r.reason}")
            plan = r.plan
        self.env = env
        self.plan = plan
        self.n_slots = n_slots or max(env.work.n_micro, 1)
        self.sim = InterleavedPipelineSim(
            env, plan, use_planner=use_planner,
            use_kv_transfer=use_kv_transfer, prompt_tokens=prompt_tokens,
            true_env=true_env)
        # online re-fit (DESIGN.md §18): observe the sim's fetch/compute
        # telemetry and fold measured drift back into the planned env
        self.refit = None
        if refit:
            from repro.tune.refit import OnlineRefit
            self.refit = OnlineRefit(env)
            self.sim.attach_refit(self.refit)
        self._ctx: Dict[int, int] = {}        # slot -> prompt + generated
        self._kv_pages = None                 # (pages_in_use, page_size)
        # adaptation telemetry (DESIGN.md §13): planner (α, β) moves are
        # reported in whole-layer equivalents; scheduler-driven reclaims
        # (reclaim_kv_pages) force-advance the TS ladder and credit the
        # freed bytes to the admission page pool. `adapt` gates the
        # reclaim hook — with it off (default) admission pressure behaves
        # exactly as the static plan (preempt, never retier).
        self.adapt = adapt
        self._pool = None
        self._adapt = {"retier_events": 0, "layers_demoted": 0,
                       "layers_promoted": 0, "hbm_returned_bytes": 0.0}
        # speculative decoding (DESIGN.md §11): the simulator has no real
        # tokens to verify, so a spec config prices each decode round as a
        # (k+1)-query verify pass and draws per-slot accepted counts from
        # the acceptance-rate model (each draft token independently
        # accepted with prob spec.acceptance, stopping at the first
        # rejection — the geometric shape real rejection sampling has).
        # draft="resident" (DESIGN.md §14) scales that acceptance by the
        # LIVE resident fraction — the plan's resident share minus
        # whatever the TS ladder has demoted — and adapts draft depth per
        # rung through a DepthController, so planner demotions visibly
        # thin the self-draft exactly as they do on the real engine.
        self.spec = spec
        self._depth = None
        if spec is not None:
            from repro.specdec import SpecStats
            self._spec_rng = np.random.default_rng(spec.seed)
            self._spec_stats = SpecStats()
            if spec.draft == "resident":
                total = max(plan.layers_total(), 1)
                self._res_frac0 = min(
                    sum(st.resident_total for st in plan.stages) / total,
                    1.0)
                if spec.adapt_k:
                    from repro.specdec import DepthController
                    self._depth = DepthController(
                        k_max=spec.k, prior=self._spec_acceptance())

    # -- clock -------------------------------------------------------------------
    def now(self) -> float:
        return self.sim.now

    def advance_to(self, t: float) -> None:
        self.sim.advance_to(t)

    # -- capacity ----------------------------------------------------------------
    def kv_budget_tokens(self) -> Optional[int]:
        """Fleet KV capacity in per-request tokens: aggregate memory left
        after weights, divided by the per-token-per-sequence KV rate
        (kv_bytes_per_token_layer covers the whole mb × n_micro set)."""
        cfg = self.env.work.cfg
        w = self.env.work
        per_seq = w.kv_bytes_per_token_layer() \
            / (max(w.mb, 1) * max(w.n_micro, 1))
        rate = cfg.n_layers * per_seq
        if rate <= 0:
            return None                       # attention-free: KV is not a budget
        agg = sum(d.mem_bytes for d in self.env.devices)
        budget = max(agg - cfg.total_params() * 2, agg * 0.03)
        return int(budget // rate)

    def kv_bytes_per_token(self) -> float:
        """Fleet KV bytes one context token costs one sequence (page
        pricing for the paged scheduler's spill/fetch accounting)."""
        cfg = self.env.work.cfg
        w = self.env.work
        return cfg.n_layers * w.kv_bytes_per_token_layer() \
            / (max(w.mb, 1) * max(w.n_micro, 1))

    # -- paged-KV hooks (DESIGN.md §10) ------------------------------------------
    def note_kv_pages(self, pages_in_use: int, page_size: int) -> None:
        """Scheduler callback: current page-granular occupancy. Attaches
        the planner/KV-transfer accounting to *allocated* pages, so the TS
        ladder (paper Eq. 5) fires on what admission actually holds."""
        self._kv_pages = (pages_in_use, page_size)

    def note_slo_pressure(self, pressure: float) -> None:
        """Scheduler callback (DESIGN.md §17): forward SLO pressure
        (1 - health) to the sim's OnlinePlanner so its TS thresholds
        fire early while the serving layer is breaching."""
        if self.sim.planner is not None:
            self.sim.planner.note_slo_pressure(pressure)

    def attach_page_pool(self, pool) -> None:
        """Expose a PagePool to the simulator so Eq. 8 volumes move real
        pages (core/kv_transfer.sync_pool) every step, and to the
        adaptation path so retiered weight bytes grow its device tier."""
        self.sim.attach_page_pool(pool)
        self._pool = pool

    # -- online memory adaptation (DESIGN.md §13) --------------------------------
    def _planner_snapshot(self):
        pl = self.sim.planner
        return [(st.alpha, st.beta) for st in pl.states] if pl else None

    def _note_planner_delta(self, before) -> None:
        """Fold planner (α, β) moves since `before` into the adaptation
        telemetry (whole-layer equivalents: a layer = 1 MHA + 1 MLP).
        Gated on `adapt`: a static run's report keeps the documented
        'zero when --adapt is off' contract even on workloads where the
        sim's own TS ladder fires."""
        pl = self.sim.planner
        if not self.adapt or pl is None or before is None:
            return
        w = self.env.work
        tr = get_tracer()
        factor = max(self.plan.n_seg - 1, 1)
        for dev, ((a0, b0), st) in enumerate(zip(before, pl.states)):
            da, db = st.alpha - a0, st.beta - b0
            if not (da or db):
                continue
            self._adapt["retier_events"] += 1
            self._adapt["layers_demoted"] += max(max(da, db), 0)
            self._adapt["layers_promoted"] += max(-min(da, db), 0)
            self._adapt["hbm_returned_bytes"] += max(
                (da * w.attn_block_bytes + db * w.mlp_block_bytes) * factor,
                0.0)
            if tr is not None:
                tr.instant(tr_ev.RETIER, track=tr_ev.TRACK_KV,
                           args={"dev": dev,
                                 "demoted": max(max(da, db), 0),
                                 "promoted": max(-min(da, db), 0)})

    def _sim_step(self, **kw):
        before = self._planner_snapshot()
        trace = self.sim.step_once(**kw)
        self._note_planner_delta(before)
        tr = get_tracer()
        if tr is not None:
            # StepTrace -> trace events: sim and engine render identically
            # (one "step" span per pipeline round on the "pipeline" track)
            t1 = self.sim.now
            tr.complete(tr_ev.STEP, ts=t1 - trace.latency,
                        dur=trace.latency, track=tr_ev.TRACK_PIPELINE,
                        args={"load_stall": trace.load_stall,
                              "comm_time": trace.comm_time,
                              "kv_moved_bytes": trace.kv_moved_bytes})
            if trace.planner_fired:
                tr.instant(tr_ev.PLANNER_FIRED, ts=t1,
                           track=tr_ev.TRACK_PIPELINE)
        return trace

    def reclaim_kv_pages(self, n_pages: int) -> int:
        """Scheduler pressure hook: force-advance the TS ladder (demote
        blocks ahead of their occupancy thresholds) and return the freed
        bytes as device KV pages. The simulator prices the added
        per-segment load on every subsequent step — adaptation trades
        steady-state load for preemption churn. Returns pages granted."""
        pl = self.sim.planner
        if not self.adapt or pl is None or self._pool is None:
            return 0
        pb = self._pool.cfg.page_bytes
        if pb <= 0:
            return 0
        w = self.env.work
        factor = max(self.plan.n_seg - 1, 1)
        snap = [(st.alpha, st.beta, st.plan_idx) for st in pl.states]
        adapt_snap = dict(self._adapt)
        freed = 0.0
        need = n_pages * pb
        advanced = True
        while freed < need and advanced:
            advanced = False
            for st in pl.states:
                lad = pl.ladders[st.dev_idx]
                if st.plan_idx >= len(lad):
                    continue
                step = lad[st.plan_idx]
                da, db = step.alpha - st.alpha, step.beta - st.beta
                gain = (da * w.attn_block_bytes
                        + db * w.mlp_block_bytes) * factor
                st.alpha, st.beta = step.alpha, step.beta
                st.plan_idx += 1
                advanced = True
                if gain > 0:
                    freed += gain
                    self._adapt["retier_events"] += 1
                    self._adapt["layers_demoted"] += max(max(da, db), 0)
                    self._adapt["hbm_returned_bytes"] += gain
                if freed >= need:
                    break
        pages = int(freed // pb)
        if pages <= 0:
            # nothing granted: roll the ladder (and its telemetry) back —
            # the preemption happens anyway; paying extra per-segment
            # load for zero pages would be pure loss
            for st, (a, b, i) in zip(pl.states, snap):
                st.alpha, st.beta, st.plan_idx = a, b, i
            self._adapt = adapt_snap
            return 0
        self._pool.grow(pages)
        tr = get_tracer()
        if tr is not None:
            tr.instant(tr_ev.RETIER, track=tr_ev.TRACK_KV,
                       args={"forced": True, "pages": pages})
        return pages

    @property
    def adapt_stats(self):
        return dict(self._adapt)

    def charge_transfer(self, nbytes: float) -> None:
        """Preemption spill/fetch traffic: advances the virtual clock."""
        self.sim.charge_transfer(nbytes)

    # -- serving hooks -----------------------------------------------------------
    @staticmethod
    def _prefill_span(req) -> int:
        # a recompute-resumed request re-prefills prompt + generated
        return getattr(req, "prefill_tokens", None) or req.prompt_len

    @staticmethod
    def _prefill_q(req) -> int:
        """Query positions the prefill pass actually computes: the span
        minus whatever the radix prefix cache (or a spill that kept the
        KV) already holds — Eq. 5-8 bytes and hops are priced for the
        uncached suffix only (DESIGN.md §12)."""
        span = SimBackend._prefill_span(req)
        cached = getattr(req, "cached_tokens", 0)
        return max(span - cached, 1)

    def start_batch(self, reqs: Sequence) -> List[Optional[int]]:
        out: List[Optional[int]] = []
        for slot, r in enumerate(reqs):
            self._ctx[slot] = self._prefill_span(r)
        # prefill: one pipeline pass; each micro-batch carries its own
        # uncached-suffix query count (attention still reads the full
        # span, hence ctx = the longest context in the batch)
        self._sim_step(ctx=max((self._prefill_span(r) for r in reqs),
                                   default=1),
                           n_micro=max(len(reqs), 1),
                           kv_tokens=self._planner_tokens(),
                           q_lens=[self._prefill_q(r) for r in reqs] or [1])
        for slot, r in enumerate(reqs):
            self._ctx[slot] += 1
            out.append(None)                  # sim has no real token ids
        return out

    def join(self, slot: int, req) -> Optional[int]:
        # mid-flight admission: the joiner's prefill rides one step at its
        # own prompt span before it starts decoding with the others
        span = self._prefill_span(req)
        self._ctx[slot] = span
        self._sim_step(ctx=max(span, 1), n_micro=1,
                           kv_tokens=self._planner_tokens(),
                           q_len=self._prefill_q(req))
        self._ctx[slot] += 1
        return None

    # -- chunked prefill / mixed rounds (DESIGN.md §12) --------------------------
    def attach_slot(self, slot: int, req, ctx0: int) -> None:
        """Register a slot whose prompt will drain through decode_mixed
        chunks; `ctx0` is the context already in KV (radix prefix hit or
        a spill that kept the pages)."""
        self._ctx[slot] = max(ctx0, 0)

    def decode_mixed(self, work: Dict[int, tuple]):
        """One mixed round: {slot: ("prefill", n_tokens, last_chunk) |
        ("decode",)}. Every stream rides the same weight-stream — the
        chunk's compute and hops scale with its q_len, decode streams
        with 1 (or k+1 under speculation) — so a cold prompt no longer
        stalls live decoders for a monolithic pass. Prefill slots emit
        [None] (their first token) when the last chunk lands, [] before;
        decode slots emit their committed round."""
        if not work:
            return {}
        slots = sorted(work)
        q_lens, out = [], {}
        spec_slots = []
        k = self._spec_k() if self.spec is not None else 0
        for s in slots:
            w = work[s]
            if w[0] == "prefill":
                q_lens.append(max(w[1], 1))
            elif self.spec is not None:
                q_lens.append(k + 1)
                spec_slots.append(s)
            else:
                q_lens.append(1)
        ctx = max(self._ctx[s] + (work[s][1] if work[s][0] == "prefill"
                                  else 1) for s in slots)
        self._sim_step(ctx=ctx, n_micro=len(slots),
                           kv_tokens=self._planner_tokens(), q_lens=q_lens)
        for s in slots:
            w = work[s]
            if w[0] == "prefill":
                self._ctx[s] += w[1]
                if w[2]:                      # last chunk: first token
                    self._ctx[s] += 1
                    out[s] = [None]
                else:
                    out[s] = []
            elif s in spec_slots:
                out[s] = [None] * self._spec_commit(s, k)
            else:
                self._ctx[s] += 1
                out[s] = [None]
        return out

    def _demoted_layers(self) -> int:
        """Whole-layer equivalents the TS ladder currently holds demoted
        (the sim's retier rung; max(α, β) per device, the convention
        _note_planner_delta reports in)."""
        pl = self.sim.planner
        if pl is None:
            return 0
        return sum(max(st.alpha, st.beta) for st in pl.states)

    def _resident_frac(self) -> float:
        """Live resident share: the plan's static fraction minus ladder
        demotions."""
        total = max(self.plan.layers_total(), 1)
        return min(max(self._res_frac0 - self._demoted_layers() / total,
                       0.0), 1.0)

    def _spec_acceptance(self) -> float:
        """Per-token acceptance of the model: flat for ngram/model drafts;
        for the resident self-draft it scales with the live resident
        fraction (a thinner draft stack proposes worse tokens)."""
        if self.spec.draft != "resident":
            return self.spec.acceptance
        return min(max(self.spec.acceptance * self._resident_frac(),
                       0.02), 0.98)

    def _spec_k(self) -> int:
        """Round depth: spec.k, or the DepthController's rung-adapted k
        for the resident draft (rung = ladder-demoted layers)."""
        if self._depth is None:
            return self.spec.k
        self._depth.note_rung(self._demoted_layers(),
                              prior=self._spec_acceptance())
        return self._depth.k()

    def _spec_commit(self, s: int, k: Optional[int] = None) -> int:
        """Draw one slot's committed count from the acceptance model and
        advance its context (shared by decode_active and mixed rounds)."""
        k = self.spec.k if k is None else k
        a = self._spec_acceptance()
        acc = 0
        while acc < k and self._spec_rng.random() < a:
            acc += 1
        committed = acc + 1          # accepted prefix + correction/bonus
        self._ctx[s] += committed
        self._spec_stats.rounds += 1
        self._spec_stats.drafted += k
        self._spec_stats.accepted += acc
        if self._depth is not None:
            self._depth.note_round(k, acc)
        return committed

    def decode_active(self, slots: Sequence[int]):
        if not slots:
            return {}
        ctx = max(self._ctx[s] for s in slots)
        if self.spec is not None:
            return self._decode_active_spec(slots, ctx)
        self._sim_step(ctx=ctx, n_micro=len(slots),
                           kv_tokens=self._planner_tokens())
        for s in slots:
            self._ctx[s] += 1
        return {s: None for s in slots}

    def _decode_active_spec(self, slots: Sequence[int], ctx: int):
        """One speculative round: price a (k+1)-query verify pass, then
        commit 1..k+1 tokens per slot from the acceptance model."""
        k = self._spec_k()
        self._sim_step(ctx=ctx, n_micro=len(slots),
                           kv_tokens=self._planner_tokens(), q_len=k + 1)
        return {s: [None] * self._spec_commit(s, k) for s in slots}

    @property
    def spec_stats(self):
        return self._spec_stats.to_dict() if self.spec is not None else None

    def release(self, slot: int) -> None:
        self._ctx.pop(slot, None)

    def _planner_tokens(self) -> int:
        n_micro_env = max(self.env.work.n_micro, 1)
        if self._kv_pages is not None:
            pages, ps = self._kv_pages        # real page occupancy
            return -(-(pages * ps) // n_micro_env)
        total = sum(self._ctx.values())
        return -(-total // n_micro_env)       # ceil-div


# ============================================================================
# Engine backend (real execution; single-device fallback without an engine)
# ============================================================================
class EngineBackend:
    """Wall-clock substrate over the InterleavedEngine (or the plain
    single-host decode path when engine is None — 1-device smoke runs).

    Epoch batching: cache seeding fixes batch membership, so freed slots
    pad the pipeline until the epoch drains (can_join_running = False).
    Arrival waits don't sleep — advance_to() skews the clock, so a trace
    with long idle gaps benches in real compute time while latency math
    still sees the gaps.
    """

    can_join_running = False

    def __init__(self, cfg, params, *, engine=None, n_slots: int = 0,
                 max_len: int = 512, sampler=None, prompt_seed: int = 0,
                 paged: bool = False, page_size: int = 64, spec=None,
                 prefix_cache: bool = False, prefill_chunk_tokens: int = 0,
                 cache_pages: int = 0, planner=None, refit: bool = False):
        import jax

        from repro.models import model as M
        from repro.serving.sampling import SamplerConfig

        self.cfg = cfg
        self.params = params
        self.engine = engine
        self.max_len = max_len
        # online memory adaptation (DESIGN.md §13): an OnlinePlanner walks
        # its TS ladder on the scheduler's page occupancy (note_kv_pages)
        # and fires retier events on the live engine — demoted resident
        # layers return their HBM to the admission page pool. The
        # scheduler may also force demotions (reclaim_kv_pages) before
        # preempting a request.
        self.planner = planner
        # online re-fit on the real engine (DESIGN.md §18): wall-clock
        # weight-load / stage-compute timings go in via note_load_timing
        # and fold drift back into the planner's CostEnv
        self.refit = None
        if refit and planner is not None:
            from repro.tune.refit import OnlineRefit
            if not isinstance(planner.env.devices, list):
                planner.env.devices = list(planner.env.devices)
            self.refit = OnlineRefit(planner.env, planner)
        self._pool = None                 # admission PagePool (scheduler's)
        self._grants = []                 # reclaim-driven (stage, pages)
        self._reclaim_dry = False         # retier slots too small to grant
        self._adapt = {"retier_events": 0, "layers_demoted": 0,
                       "layers_promoted": 0, "hbm_returned_bytes": 0.0}
        # radix prefix cache over the real paged pool (DESIGN.md §12):
        # prompts matched against cached pages, only the uncached suffix
        # prefilled, finished requests donate their pages back. Rides the
        # single-device paged path (with an engine, chunked prefill is
        # available via prefill_partial; page sharing needs the paged
        # pool, which the engine tier keeps per-slot-dense).
        if prefix_cache and engine is not None:
            raise NotImplementedError(
                "prefix_cache shares real KV pages through the "
                "single-device paged pool; the engine's per-stage cache "
                "layout has no shared pool to fork from")
        self.prefix_cache = prefix_cache
        self.chunk = max(int(prefill_chunk_tokens), 0)
        self._cache_pages = cache_pages   # radix headroom (0 -> one full
                                          # batch's worth of extra pages)
        self._radix = None
        self._slot_tokens = None          # per-slot donatable prompt ids
        self._slot_out = None             # per-slot committed output ids
        self._saved_tokens = 0            # prompt tokens seeded from cache
        if prefix_cache:
            paged = True
        # speculative decoding (DESIGN.md §11): real drafts, real
        # multi-token verification. The shared-pos cache layout (prompts
        # left-padded, one position counter per batch) forces lockstep
        # commits: every live slot advances by the min accepted count and
        # the rest re-verifies next round — lossless either way, since
        # re-verification redraws from the same target conditional.
        self.spec = spec
        self._ctl = None
        self._pos = 0                         # host mirror of cache pos
        # resident self-draft (DESIGN.md §14): with an engine, k tokens
        # are drafted ON the pipeline itself (draft_requests — resident
        # tier only, zero weight streaming) and the host providers are
        # skipped; without one, each slot gets a ResidentDraft over the
        # bottom spec.resident_layers of the target's own stack. Depth
        # adapts per retier rung through a DepthController.
        self._resident_engine = (spec is not None
                                 and spec.draft == "resident"
                                 and engine is not None)
        self._depth = None
        if spec is not None:
            from repro.configs.base import Family
            if cfg.family not in (Family.DENSE, Family.MOE):
                raise ValueError(
                    f"speculative decoding needs pure-KV per-layer state "
                    f"(DENSE/MOE), not {cfg.family}")
            if self._resident_engine and engine.k_res_cap == 0:
                raise ValueError(
                    "draft='resident' needs a resident tier; this "
                    "engine's plan streams every layer (k_res == 0)")
            if spec.draft == "resident" and spec.adapt_k:
                from repro.specdec import DepthController
                self._depth = DepthController(k_max=spec.k,
                                              prior=spec.acceptance)
            # verify windows must not wrap the cache ring: cap rounds at
            # the ACTUAL KV length (sliding-window caches have
            # S_c = window < max_len), not max_len. Past the ring end the
            # plain ring-aware step takes over (decode_active fallback).
            if paged and engine is None:
                self._spec_cap = max_len      # pool slots, no ring
            elif engine is not None:
                self._spec_cap = min(engine.S_c, max_len)
            else:
                self._spec_cap = min(M.kv_cache_len(cfg, max_len), max_len)
        # paged=True routes the single-device path through the paged
        # decode (block-table gather attention, kvcache/paged_decode);
        # with an engine, pass paged=True to the engine itself instead
        # (slot-level page accounting + paged seed_cache adoption).
        self.paged = paged and engine is None
        self.page_size = page_size
        self._paged_cache = None
        self.sampler = sampler if sampler is not None else SamplerConfig()
        # batch_width: what the compiled step expects (fixed); n_slots:
        # what the scheduler may co-schedule (sporadic serves 1 through a
        # wide engine — the spare slots ride as padding)
        self.batch_width = (engine.n_mb * engine.mb) if engine is not None \
            else max(n_slots or 1, 1)
        self.n_slots = min(n_slots, self.batch_width) if n_slots \
            else self.batch_width
        self._key = jax.random.PRNGKey(self.sampler.seed)
        self._prompt_rng_seed = prompt_seed
        self._prefill = jax.jit(functools.partial(M.prefill, cfg))
        self._decode = jax.jit(functools.partial(M.decode_step, cfg)) \
            if engine is None else None
        self._verify = jax.jit(functools.partial(M.verify_step, cfg)) \
            if (engine is None and not self.paged and spec is not None) \
            else None
        self._t0 = time.monotonic()
        self._skew = 0.0
        self._state = None
        self._cur = None                      # (batch_width, 1) last tokens

    # -- clock -------------------------------------------------------------------
    def now(self) -> float:
        return (time.monotonic() - self._t0) + self._skew

    def advance_to(self, t: float) -> None:
        cur = self.now()
        if t > cur:
            self._skew += t - cur

    # -- capacity ----------------------------------------------------------------
    def kv_budget_tokens(self) -> Optional[int]:
        # the engine's cache is statically shaped: max_len per slot
        return self.n_slots * self.max_len

    def kv_bytes_per_token(self) -> float:
        return 2.0 * self.cfg.n_layers * self.cfg.n_kv_heads \
            * self.cfg.head_dim * 2.0         # k+v, bf16

    def max_request_tokens(self) -> Optional[int]:
        """Per-slot ceiling: a single request's prompt + max_new must fit
        the statically-shaped cache, regardless of pooled headroom."""
        return self.max_len

    def fits_batch(self, batch: Sequence, req) -> bool:
        """Epoch-composition constraint: prompts are LEFT-padded to the
        batch max, so every co-scheduled request decodes from position
        max(prompt_len) — each one's max_prompt + own max_new must fit
        max_len or its cache writes clamp at the last row (silent
        corruption)."""
        cand = list(batch) + [req]
        mp = max(r.prompt_len for r in cand)
        return all(mp + r.max_new_tokens <= self.max_len for r in cand)

    # -- helpers -----------------------------------------------------------------
    def _materialize_prompt(self, r) -> np.ndarray:
        if r.prompt is not None:
            return np.asarray(r.prompt, np.int32)
        rng = np.random.default_rng(self._prompt_rng_seed + r.rid)
        n = max(r.prompt_len, 1)
        return rng.integers(1, self.cfg.vocab_size, size=n).astype(np.int32)

    def _pad_prompts(self, prompts: List[np.ndarray]):
        import jax.numpy as jnp
        S = max(len(p) for p in prompts)
        toks = np.zeros((len(prompts), S), np.int32)
        for i, p in enumerate(prompts):
            toks[i, S - len(p):] = p          # left-pad
        return jnp.asarray(toks)

    def _sample(self, logits):
        import jax

        from repro.serving.sampling import sample
        self._key, k = jax.random.split(self._key)
        return sample(logits, self.sampler, k, self.cfg.vocab_size)

    # -- online memory adaptation (DESIGN.md §13) --------------------------------
    def attach_page_pool(self, pool) -> None:
        """Scheduler hook: the admission PagePool that retiered weight HBM
        is credited to (grow on demote, shrink on promote)."""
        self._pool = pool

    def _page_bytes(self) -> float:
        pb = self._pool.cfg.page_bytes if self._pool is not None else 0.0
        return pb or self.kv_bytes_per_token() * self.page_size

    def _apply_retier(self, stage: int, delta: int) -> float:
        """Move `delta` slots of `stage` across the tier boundary on the
        live engine state (counter-only between epochs — init_state builds
        the demoted layout). Returns HBM bytes freed (< 0 on promote)."""
        eng = self.engine
        before = eng.demoted(stage)
        self._state, freed = eng.retier(self._state, stage, delta)
        moved = abs(eng.demoted(stage) - before)
        if moved:
            self._adapt["retier_events"] += 1
            key = "layers_demoted" if freed > 0 else "layers_promoted"
            self._adapt[key] += moved
            self._adapt["hbm_returned_bytes"] += max(freed, 0.0)
            self._sync_depth_rung()
            tr = get_tracer()
            if tr is not None:
                tr.instant(tr_ev.ENGINE_RETIER, track=tr_ev.TRACK_KV,
                           args={"stage": stage, "moved": moved,
                                 "direction": ("demote" if freed > 0
                                               else "promote"),
                                 "freed_bytes": freed})
        return freed

    def _sync_depth_rung(self) -> None:
        """Tell the DepthController the tier boundary moved: the new rung
        (total demoted slots) starts from an acceptance prior scaled by
        the LIVE resident fraction — a demotion shrinks k immediately
        instead of waiting for rejections to pile up (DESIGN.md §14)."""
        if self._depth is None or self.engine is None:
            return
        eng = self.engine
        rung = sum(eng.demoted(d) for d in range(eng.plan.n_stage))
        self._depth.note_rung(
            rung, prior=self.spec.acceptance * eng.resident_fraction())

    def _retier_to(self, stage: int, target_demoted: int) -> None:
        """Planner-driven: demote until `stage` has target_demoted slots
        streamed (whole-layer mapping of the planner's (α, β) blocks)."""
        eng = self.engine
        cap = min(eng.k_res_b[stage], eng.H)
        delta = min(target_demoted, cap) - eng.demoted(stage)
        if delta <= 0:
            return
        freed = self._apply_retier(stage, delta)
        if self._pool is not None and freed > 0:
            self._pool.grow(int(freed // self._page_bytes()))

    def note_kv_pages(self, pages_in_use: int, page_size: int) -> None:
        """Scheduler callback with page-granular KV occupancy: walk the
        planner's TS ladder (paper Eq. 5) on what admission actually
        holds, retier the live pipeline on fired plans, and promote
        pressure-driven demotions back when occupancy leaves headroom."""
        if self.engine is None:
            return
        if self.planner is not None:
            for dev, step in self.planner.on_pages(pages_in_use, page_size):
                if dev < self.engine.plan.n_stage:
                    self._retier_to(dev, max(step.alpha, step.beta))
        self._maybe_promote()

    def note_slo_pressure(self, pressure: float) -> None:
        """Scheduler callback with SLO pressure (DESIGN.md §17): forward
        to the planner so its TS ladder fires early under burn."""
        if self.planner is not None:
            self.planner.note_slo_pressure(pressure)

    def note_load_timing(self, stage: int, nbytes: float,
                         seconds: float) -> None:
        """Wall-clock weight-load observation from the engine's streaming
        path (DESIGN.md §18): feed the online re-fit and let it rebuild
        the planner's ladders if the measured bandwidth has drifted."""
        if self.refit is None:
            return
        now = time.monotonic()
        self.refit.observe_fetch(stage, nbytes, seconds, now=now)
        self.refit.maybe_refit(now)

    def reclaim_kv_pages(self, n_pages: int) -> int:
        """Scheduler pressure hook: before preempting a request, demote
        resident layers and return their HBM as device KV pages. Returns
        pages made available (0 = no retier headroom left)."""
        if self.engine is None or self._pool is None:
            return 0
        pb = self._page_bytes()
        if pb <= 0:
            return 0
        if self._reclaim_dry:
            return 0          # a slot frees < 1 page on this engine: the
        eng = self.engine     # geometry is constant, retrying just churns
        got = 0
        while got < n_pages:
            stage = max(range(eng.plan.n_stage), key=eng.demote_capacity)
            if eng.demote_capacity(stage) <= 0:
                break
            snap = dict(self._adapt)
            pages = int(self._apply_retier(stage, +1) // pb)
            if pages <= 0:
                # one slot frees less than a page: undo the demotion (a
                # grant of nothing would permanently slow the stage) and
                # its telemetry — no HBM was returned
                self._apply_retier(stage, -1)
                self._adapt = snap
                self._reclaim_dry = True
                break
            self._pool.grow(pages)
            self._grants.append((stage, pages))
            got += pages
        return got

    def _planner_demote_target(self, stage: int) -> int:
        """Slots the TS ladder currently demands demoted on `stage`."""
        if self.planner is None or stage >= len(self.planner.states):
            return 0
        st = self.planner.states[stage]
        return max(st.alpha, st.beta)

    def _maybe_promote(self) -> None:
        """Undo reclaim-driven demotions when pressure drops: withdraw the
        granted pages (only free capacity can leave the pool) and promote
        the layers back to residency. Planner-driven demotions stay — the
        TS ladder is monotone in KV growth (paper §IV-D) — so promotion
        stops at the ladder's current demote target even when a reclaim
        grant is still outstanding on that stage (retier() promotes the
        most recent demotion, which may be the planner's)."""
        while self._grants and self._pool is not None:
            stage, pages = self._grants[-1]
            if self.engine.demoted(stage) - 1 \
                    < self._planner_demote_target(stage):
                break                    # would undo a ladder demotion
            if self._pool.free_pages() < pages + 2 * self.n_slots:
                break                    # still too close to the watermark
            self._pool.shrink(pages)
            self._apply_retier(stage, -1)
            self._grants.pop()

    @property
    def adapt_stats(self):
        stats = dict(self._adapt)
        if self.engine is not None:
            stats["layers_streamed_now"] = sum(
                self.engine.demoted(d)
                for d in range(self.engine.plan.n_stage))
        return stats

    # -- radix prefix cache over real KV pages (DESIGN.md §12) -------------------
    def _engine_can_chunk(self) -> bool:
        from repro.configs.base import Family
        return self.cfg.family in (Family.DENSE, Family.MOE) \
            and self.chunk < self.engine.S_c

    def _prefix_structures(self):
        """Persistent pool + paged cache + radix tree (lazily built: they
        outlive epochs — that is the whole point of the cache)."""
        if self._radix is None:
            from repro.kvcache.paged_decode import PagedDecodeCache
            from repro.kvcache.pool import PagePool, PagedKVConfig
            from repro.prefixcache import RadixPrefixCache
            B = self.batch_width
            max_pages = -(-self.max_len // self.page_size)
            extra = self._cache_pages or B * max_pages
            pool = PagePool(PagedKVConfig(
                page_size=self.page_size,
                device_pages=B * max_pages + extra))
            self._paged_cache = PagedDecodeCache(
                self.cfg, B, self.max_len, page_size=self.page_size,
                pool=pool)
            self._radix = RadixPrefixCache(pool)
        return self._paged_cache, self._radix

    def _ensure_room(self, pc, n_new_tokens: int) -> None:
        """Free device pages for the coming growth: unpinned radix pages
        are evicted first — cached prefixes are reclaimable, live tables
        are not (the pool is sized so this always suffices)."""
        need = sum(pc.pool.pages_for(pc.pos + n_new_tokens) - len(t.pages)
                   for t in pc.tables)
        short = need - pc.pool.free_pages()
        if short > 0:
            self._radix.evict(short)

    def _start_batch_prefix(self, reqs, prompts, toks):
        """Seed the epoch from shared pages where the radix tree has them,
        then prefill only the uncached suffix (chunked when configured).
        The shared-pos cache layout forces one matched length for the
        whole batch, so hits need equal-length prompts (shared_prefix
        traffic's common case) and align on the batch-minimum match;
        unequal-length epochs run cold through the dense prefill (their
        left-padded prefixes would key pad tokens — never donated)."""
        from repro.kvcache.allocator import BlockTable
        from repro.models import model as M

        pc, radix = self._prefix_structures()
        B = self.batch_width
        pc.reset_tables()                 # radix increfs keep shared pages
        self._slot_tokens = [None] * B
        self._slot_out = [[] for _ in range(B)]
        ps = self.page_size
        lens = {len(p) for p in prompts}
        if len(lens) != 1:
            cache = M.init_cache(self.cfg, B, self.max_len)
            logits, cache = self._prefill(self.params, toks, cache)
            self._ensure_room(pc, int(cache["pos"]))
            pc.seed(cache)
            self._state = None
            return logits[:, -1]
        L = lens.pop()
        matches = [radix.match(p, max_pages=(L - 1) // ps)
                   for p in prompts]
        m = min(n for _, n in matches)    # shared pos: batch-min match
        self._saved_tokens += m * len(reqs)
        for r in reqs:                    # visibility in serving reports
            r.cached_tokens = max(getattr(r, "cached_tokens", 0), m)
        while len(matches) < B:           # padded replicas ride the last
            matches.append(matches[-1])   # request's match
        if m > 0:
            tables = []
            for pages, _ in matches:
                t = BlockTable(ps)
                for pid in pages[:m // ps]:
                    pc.pool.incref_page(pid)
                t.pages = list(pages[:m // ps])
                t.tokens = m
                tables.append(t)
            pc.adopt_tables(tables, m)
        self._ensure_room(pc, L - pc.pos)
        last = pc.prefill(self.params, np.asarray(toks)[:, pc.pos:],
                          chunk=self.chunk)
        for slot, p in enumerate(prompts):
            self._slot_tokens[slot] = [int(x) for x in p]
        self._state = None
        return last

    @property
    def prefix_stats(self):
        if self._radix is None:
            return None
        r = self._radix
        return {"prefix_lookups": r.lookups, "prefix_hits": r.hits,
                "cached_tokens": r.cached_tokens(),
                "prefix_pages": r.n_pages,
                "prefill_tokens_saved": self._saved_tokens}

    # -- serving hooks -----------------------------------------------------------
    def start_batch(self, reqs: Sequence) -> List[Optional[int]]:
        import jax.numpy as jnp

        from repro.models import model as M

        tr = get_tracer()
        t0 = tr.now() if tr is not None else 0.0
        prompts = [self._materialize_prompt(r) for r in reqs]
        toks = self._pad_prompts(prompts)
        if toks.shape[0] < self.batch_width:  # pad batch with replicas
            toks = jnp.concatenate(
                [toks, jnp.tile(toks[-1:], (self.batch_width - toks.shape[0],
                                            1))], 0)
        if self.prefix_cache:
            last = self._start_batch_prefix(reqs, prompts, toks)
        elif self.engine is not None and self.chunk \
                and self._engine_can_chunk():
            # partial-context prefill rounds through the interleaved
            # pipeline itself (DESIGN.md §12) — no separate prefill
            # program on replicated params
            state = self.engine.init_state(self.params)
            lg, self._state = self.engine.prefill_partial(
                state, toks, chunk=self.chunk)
            last = lg[:, -1]
        else:
            cache = M.init_cache(self.cfg, toks.shape[0], self.max_len)
            logits, cache = self._prefill(self.params, toks, cache)
            last = logits[:, -1]
            if self.engine is not None:
                state = self.engine.init_state(self.params)
                self._state = self.engine.seed_cache(state, cache)
            elif self.paged:
                from repro.kvcache.paged_decode import PagedDecodeCache
                if self._paged_cache is not None:
                    self._paged_cache.release()
                self._paged_cache = PagedDecodeCache(
                    self.cfg, toks.shape[0], self.max_len,
                    page_size=self.page_size)
                self._paged_cache.seed(cache)
                self._state = None
            else:
                self._state = cache
        tok = self._sample(last)
        if tr is not None:
            tr.complete(tr_ev.ENGINE_PREFILL, ts=t0, dur=tr.now() - t0,
                        track=tr_ev.TRACK_PIPELINE,
                        args={"batch": len(reqs),
                              "span": int(toks.shape[1])})
        if self.prefix_cache:
            for slot in range(len(reqs)):
                self._slot_out[slot].append(int(tok[slot]))
        self._cur = tok[:, None]
        if self.spec is not None:
            from repro.specdec import SpecDecodeController
            if self._ctl is None:
                self._ctl = SpecDecodeController(
                    self.spec, self.sampler, self.cfg, self.batch_width,
                    target_params=self.params,
                    external_drafts=self._resident_engine)
            self._pos = int(toks.shape[1])    # left-padded prompt span
            for slot, p in enumerate(prompts):
                # drafts see the real (unpadded) prompt + first token
                self._ctl.begin(slot, list(int(t) for t in p)
                                + [int(tok[slot])])
        return [int(tok[slot]) for slot in range(len(reqs))]

    def decode_active(self, slots: Sequence[int]):
        import jax.numpy as jnp
        # speculative round when a draft fits before the cache/ring end
        # (the last position is reserved for the committed-token write)
        if self.spec is not None:
            if self._depth is not None:
                self._sync_depth_rung()
            k_cap = self.spec.k if self._depth is None else self._depth.k()
            k = min(k_cap, self._spec_cap - self._pos - 1)
            if slots and k >= 1:
                return self._decode_active_spec(slots, k)
        tr = get_tracer()
        t0 = tr.now() if tr is not None else 0.0
        active = np.zeros(self.batch_width, bool)
        for s in slots:
            active[s] = True
        if self.engine is not None:
            lg, self._state = self.engine.decode_requests(
                self._state, self._cur, jnp.asarray(active))
        elif self.paged:
            if self.prefix_cache:
                self._ensure_room(self._paged_cache, 1)
            lg = self._paged_cache.step(self.params, self._cur)[:, 0]
        else:
            lg, self._state = self._decode(self.params, self._state,
                                           self._cur)
            if lg.ndim == 3:
                lg = lg[:, 0]
        tok = self._sample(lg)
        if self.prefix_cache:
            for s in slots:
                self._slot_out[s].append(int(tok[s]))
        if self.spec is not None:             # keep drafts/pos in sync on
            self._pos += 1                    # the non-spec fallback step
            for s in slots:
                self._ctl.observe(s, [int(tok[s])])
        # freed slots keep replaying their last token as pipeline padding
        self._cur = jnp.where(jnp.asarray(active)[:, None], tok[:, None],
                              self._cur)
        if tr is not None:
            tr.complete(tr_ev.ENGINE_DECODE, ts=t0, dur=tr.now() - t0,
                        track=tr_ev.TRACK_PIPELINE,
                        args={"slots": len(slots)})
        return {s: int(tok[s]) for s in slots}

    def _decode_active_spec(self, slots: Sequence[int], k: int):
        """One speculative round: propose k per live slot, verify all of
        them in ONE multi-token pass (one engine pipeline round — one
        weight-stream), commit the lockstep-min accepted prefix, roll the
        rejected suffix back (pos reset / table truncation)."""
        import jax.numpy as jnp
        tr = get_tracer()
        t0 = tr.now() if tr is not None else 0.0
        cur = np.array(self._cur, np.int32)             # (B, 1) host copy
        mat = np.tile(cur, (1, 1 + k))                  # padding: replicas
        active = np.zeros(self.batch_width, bool)
        active[list(slots)] = True
        proposals = {}
        if self._resident_engine:
            # self-draft on the pipeline: k resident-only steps (zero
            # weight streaming) batched across ALL live slots, then the
            # drafted positions roll back before the full verify pass
            draft = self._draft_resident(active, k)
            for s in slots:
                proposals[s] = (draft[s], None)         # greedy point-mass
                mat[s, 1:] = draft[s]
        else:
            for s in slots:
                toks, qp = self._ctl.propose(s, k)
                proposals[s] = (toks, qp)
                mat[s, 1:] = toks
        if self.engine is not None:
            lg, self._state = self.engine.verify_requests(
                self._state, jnp.asarray(mat), jnp.asarray(active))
        elif self.paged:
            if self.prefix_cache:
                self._ensure_room(self._paged_cache, 1 + k)
            lg = self._paged_cache.verify(self.params, mat)
        else:
            lg, self._state = self._verify(self.params, self._state,
                                           jnp.asarray(mat))
        lg = np.asarray(lg, np.float32)                 # (B, k+1, PV)
        committed = {s: self._ctl.verify(lg[s], *proposals[s])
                     for s in slots}
        # shared-pos lockstep: every live slot advances by the same count;
        # tokens past the min re-verify next round (greedy re-derives them
        # exactly; stochastic redraws from the same target conditional)
        c = min(len(v) for v in committed.values())
        for s in slots:
            # accepted AND committed drafts only (out = accepted drafts +
            # one correction/bonus; truncated tokens re-draft next round)
            self._ctl.note_round(k, min(c, len(committed[s]) - 1))
        if self._depth is not None:
            self._depth.note_round(
                k * len(slots),
                sum(min(c, len(committed[s]) - 1) for s in slots))
        committed = {s: v[:c] for s, v in committed.items()}
        new_pos = self._pos + c
        if self.engine is not None:
            self._state = self.engine.rollback(self._state, new_pos)
            self.engine.note_committed(new_pos, active)
        elif self.paged:
            self._paged_cache.commit(c)
        else:
            self._state = dict(self._state)
            self._state["pos"] = jnp.asarray(new_pos, jnp.int32)
        self._pos = new_pos
        for s in slots:
            self._ctl.observe(s, committed[s])
            cur[s, 0] = committed[s][-1]
            if self.prefix_cache:
                # spec commit boundary (DESIGN.md §12): several tokens
                # landed at once — donate freshly-completed pages so
                # concurrent same-prefix traffic hits mid-flight
                self._slot_out[s].extend(int(t) for t in committed[s])
                self._donate_slot(s)
        self._cur = jnp.asarray(cur)
        if tr is not None:
            tr.complete(tr_ev.ENGINE_VERIFY, ts=t0, dur=tr.now() - t0,
                        track=tr_ev.TRACK_PIPELINE,
                        args={"k": k, "committed": c,
                              "slots": len(slots)})
        return committed

    def _draft_resident(self, active: np.ndarray, k: int) -> np.ndarray:
        """Propose k greedy tokens per slot via the engine's resident-only
        step (DESIGN.md §14): the draft rides the live tier boundary and
        the real slot caches, then rolls back to self._pos so the verify
        pass overwrites every drafted position. Returns (B, k) int32."""
        import jax.numpy as jnp
        tr = get_tracer()
        t0 = tr.now() if tr is not None else 0.0
        eng = self.engine
        act = jnp.asarray(active)
        st = self._state
        cur = jnp.asarray(np.array(self._cur, np.int32))
        out = np.empty((self.batch_width, k), np.int32)
        for i in range(k):
            lg, st = eng.draft_requests(st, cur, act)
            cur = jnp.argmax(lg[:, :self.cfg.vocab_size],
                             -1)[:, None].astype(jnp.int32)
            out[:, i] = np.asarray(cur)[:, 0]
        self._state = eng.rollback(st, self._pos)
        if tr is not None:
            tr.complete(tr_ev.ENGINE_DRAFT, ts=t0, dur=tr.now() - t0,
                        track=tr_ev.TRACK_PIPELINE, args={"k": k})
        return out

    @property
    def spec_stats(self):
        return self._ctl.stats.to_dict() if self._ctl is not None else None

    def join(self, slot: int, req) -> Optional[int]:
        raise NotImplementedError(
            "engine batches are fixed at cache-seed time")

    def _donate_slot(self, slot: int) -> None:
        """Insert `slot`'s committed pages (prompt + sampled output so
        far) into the radix tree. Slots whose prompt rode left-padding
        have _slot_tokens None — their early positions hold pad KV, so
        they never donate."""
        if self._radix is None or self._slot_tokens is None \
                or self._slot_tokens[slot] is None:
            return
        toks = self._slot_tokens[slot] + self._slot_out[slot]
        table = self._paged_cache.tables[slot]
        self._radix.insert(toks, table.pages,
                           n_tokens=min(len(toks), table.tokens))

    def release(self, slot: int) -> None:
        # the slot keeps padding the fixed batch until the epoch drains
        # (see decode_active); with a paged engine its pages are freed now
        if self.prefix_cache:
            # insert on finish: the request's committed pages become
            # future prefix hits (the table itself lives until the next
            # epoch's reset_tables — the tree's increfs carry them on)
            self._donate_slot(slot)
        if self.engine is not None and getattr(self.engine, "paged", False):
            self.engine.free_slot(slot)
