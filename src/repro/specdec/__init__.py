"""Lossless speculative decoding (DESIGN.md §11).

Draft cheap, verify exact: a draft provider proposes k tokens, one
multi-token pass of the target model scores all of them (one pipeline
round — one weight-stream — in the interleaved engine), and an
acceptance-rejection sampler commits a prefix whose distribution provably
equals autoregressive sampling from the target. The rejected suffix rolls
back by resetting the decode position (dense caches) or truncating block
tables (paged KV).

  draft.py          pluggable proposers: n-gram/prompt-lookup self-draft
                    (no extra weights), small-model draft (any registered
                    config)
  resident_draft.py resident-tier self-draft (DESIGN.md §14): truncated
                    forward through the target's own resident layers +
                    DepthController (retier-adaptive k)
  sampler.py        exact greedy + stochastic acceptance-rejection
  controller.py     SpecConfig + the per-slot propose/verify/commit loop
"""
from repro.specdec.controller import (SpecConfig,  # noqa: F401
                                      SpecDecodeController, SpecStats)
from repro.specdec.draft import (NgramDraft, SmallModelDraft,  # noqa: F401
                                 make_draft_provider)
from repro.specdec.resident_draft import (DepthController,  # noqa: F401
                                          ResidentDraft,
                                          default_resident_ids)
from repro.specdec.sampler import (greedy_verify,  # noqa: F401
                                   rejection_verify, target_probs)
