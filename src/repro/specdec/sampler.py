"""Lossless acceptance-rejection verification (DESIGN.md §11).

Given the target model's logits for q_len = k+1 positions (position i is
the distribution of the token that drafted token i claims to be; the last
row is the bonus position past the draft), commit a token sequence whose
distribution is EXACTLY what autoregressive sampling would have produced:

  greedy      temperature = 0: accept drafted token i iff it is the
              argmax; the first mismatch commits the argmax instead
              (that is the token sequential decode would have emitted) and
              stops. Full acceptance commits the bonus argmax. Trivially
              lossless — every committed token is the sequential argmax.
  stochastic  temperature > 0: classic rejection sampling [Leviathan'23,
              Chen'23]. Draft token x ~ q is accepted with probability
              min(1, p(x)/q(x)); on rejection the committed token is drawn
              from the residual max(p - q, 0) renormalized, and the round
              stops. The committed marginal is exactly p at every
              position, for ANY proposal q — including the point-mass q of
              deterministic drafts (n-gram lookup), where acceptance
              degenerates to probability p(x̂).

The target distribution p is `target_probs`: softmax over the SAME
filtered logits `serving.sampling.sample()` draws from (temperature /
top-k / top-p), so "lossless" means lossless w.r.t. the serving sampler,
not just the raw softmax. Everything here is host-side numpy — the
accept/reject walk is a few scalar comparisons per round and sits between
device steps, where python is free.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.serving.sampling import SamplerConfig, filter_logits

_EPS = 1e-12


def target_probs(logits, cfg: SamplerConfig, real_vocab: int) -> np.ndarray:
    """logits: (..., PV) array-like -> (..., real_vocab) float64 rows
    summing to 1: the serving sampler's exact token distribution."""
    import jax.numpy as jnp
    lv = np.asarray(filter_logits(jnp.asarray(logits), cfg, real_vocab),
                    np.float64)
    lv -= lv.max(axis=-1, keepdims=True)
    p = np.exp(lv)
    return p / p.sum(axis=-1, keepdims=True)


def greedy_verify(logits: np.ndarray, draft: np.ndarray,
                  real_vocab: int) -> List[int]:
    """logits: (k+1, PV); draft: (k,) proposed tokens. Returns the
    committed tokens (1..k+1 of them): the accepted prefix, then either
    the correcting argmax at the first mismatch or the bonus argmax after
    full acceptance."""
    am = np.asarray(logits)[:, :real_vocab].argmax(axis=-1)
    out: List[int] = []
    for i, d in enumerate(np.asarray(draft)):
        if int(am[i]) != int(d):
            out.append(int(am[i]))
            return out
        out.append(int(d))
    out.append(int(am[len(draft)]))
    return out


def rejection_verify(rng: np.random.Generator, p: np.ndarray,
                     draft: np.ndarray,
                     q: Optional[np.ndarray] = None) -> List[int]:
    """p: (k+1, V) target probabilities (target_probs output); draft: (k,)
    proposed tokens; q: (k, V) proposal probabilities, or None for a
    point-mass draft (q(draft[i]) = 1). Returns committed tokens
    (1..k+1): accepted prefix + residual sample at the first rejection,
    or + bonus sample after full acceptance."""
    p = np.asarray(p, np.float64)
    draft = np.asarray(draft)
    out: List[int] = []
    for i, d in enumerate(draft):
        d = int(d)
        pi = p[i]
        qi_d = 1.0 if q is None else float(q[i][d])
        if rng.random() < min(1.0, pi[d] / max(qi_d, _EPS)):
            out.append(d)
            continue
        # rejected: sample from the residual max(p - q, 0), renormalized —
        # the distribution that makes accepted + rejected mix back to p
        if q is None:
            resid = pi.copy()
            resid[d] = 0.0
        else:
            resid = np.maximum(pi - np.asarray(q[i], np.float64), 0.0)
        z = resid.sum()
        if z <= _EPS:          # p ≡ q at this position: acceptance was
            resid, z = pi, pi.sum()   # certain; defensive fallback
        out.append(int(rng.choice(len(pi), p=resid / z)))
        return out
    out.append(int(rng.choice(p.shape[-1], p=p[len(draft)])))
    return out
