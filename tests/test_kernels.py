"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All kernels run in interpret mode on CPU (TPU is the compile target; the
kernel bodies execute in Python here, which checks indexing/masking/online
softmax semantics exactly).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.rwkv6_scan.ops import wkv
from repro.kernels.rwkv6_scan.ref import wkv_scan_ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ----------------------------------------------------------------------------
# flash attention (prefill/train)
# ----------------------------------------------------------------------------
FLASH_CASES = [
    # B, Sq, Skv, H, KV, dh, causal, window, dtype
    (2, 64, 64, 4, 2, 64, True, None, jnp.bfloat16),
    (1, 128, 128, 8, 8, 128, True, None, jnp.bfloat16),
    (2, 64, 64, 4, 1, 32, True, 16, jnp.bfloat16),
    (1, 100, 100, 4, 2, 80, True, None, jnp.float32),     # unaligned dims
    (1, 64, 64, 4, 2, 64, False, None, jnp.float32),      # bidirectional
    (1, 96, 192, 3, 1, 64, True, None, jnp.bfloat16),     # Sq != Skv, odd H
    (1, 32, 32, 2, 2, 256, True, 8, jnp.float32),         # gemma3 head_dim
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=lambda c: f"B{c[0]}S{c[1]}x{c[2]}H{c[3]}kv{c[4]}d{c[5]}")
def test_flash_attention_vs_ref(case):
    B, Sq, Skv, H, KV, dh, causal, window, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, Sq, H, dh), dtype)
    k = _rand(ks[1], (B, Skv, KV, dh), dtype)
    v = _rand(ks[2], (B, Skv, KV, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 0.04 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_traced_window():
    """The window arrives via scalar prefetch -> usable under scan/vmap."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (1, 64, 2, 64), jnp.float32)
    k = _rand(ks[1], (1, 64, 2, 64), jnp.float32)
    v = _rand(ks[2], (1, 64, 2, 64), jnp.float32)

    def f(w):
        return flash_attention(q, k, v, causal=True, window=w,
                               block_q=32, block_k=32, interpret=True)
    for w in (8, 32):
        out = jax.jit(f)(jnp.int32(w))
        ref = flash_attention_ref(q, k, v, causal=True, window=w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)


# ----------------------------------------------------------------------------
# decode attention
# ----------------------------------------------------------------------------
DECODE_CASES = [
    # B, S_c, H, KV, dh, pos, window, ring
    (2, 64, 4, 2, 64, 40, None, False),
    (1, 128, 8, 1, 128, 127, None, False),
    (2, 100, 4, 4, 80, 60, 32, False),
    (1, 64, 4, 2, 64, 200, None, True),
    (1, 64, 4, 2, 64, 200, 48, True),
    (3, 96, 6, 2, 32, 10, None, False),     # mostly-empty cache
]


@pytest.mark.parametrize("case", DECODE_CASES,
                         ids=lambda c: f"S{c[1]}H{c[2]}kv{c[3]}p{c[5]}{'r' if c[7] else ''}")
def test_decode_attention_vs_ref(case):
    B, S_c, H, KV, dh, pos, window, ring = case
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (B, 1, H, dh), jnp.bfloat16)
    k = _rand(ks[1], (B, S_c, KV, dh), jnp.bfloat16)
    v = _rand(ks[2], (B, S_c, KV, dh), jnp.bfloat16)
    if ring:
        base = pos - S_c + 1
        ids = (jnp.arange(S_c) - (base % S_c)) % S_c + base
    else:
        ids = jnp.where(jnp.arange(S_c) <= pos, jnp.arange(S_c), -1)
    ids = ids.astype(jnp.int32)
    out = decode_attention(q, k, v, ids, jnp.int32(pos), window=window,
                           block_k=32, interpret=True)
    ref = decode_attention_ref(q, k, v, ids, jnp.int32(pos), window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=0.04)


# ----------------------------------------------------------------------------
# rwkv6 wkv scan
# ----------------------------------------------------------------------------
WKV_CASES = [
    (2, 32, 4, 64, 8), (1, 64, 2, 32, 16), (2, 50, 3, 64, 16),
    (1, 16, 1, 128, 16), (1, 7, 2, 64, 4),
]


@pytest.mark.parametrize("case", WKV_CASES,
                         ids=lambda c: f"B{c[0]}S{c[1]}H{c[2]}d{c[3]}bt{c[4]}")
def test_wkv_vs_ref(case):
    B, S, H, dh, bt = case
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (B, S, H, dh)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, dh)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, dh)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, dh))) * 0.9 + 0.05
    u = jax.random.normal(ks[4], (H, dh)) * 0.1
    s0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    out, sT = wkv(r, k, v, w, u, s0, block_t=bt, interpret=True)
    ref_o, ref_s = wkv_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_o), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(ref_s), atol=1e-4)


def test_wkv_state_chaining():
    """Splitting a sequence across two kernel calls == one call."""
    B, S, H, dh = 1, 32, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r = jax.random.normal(ks[0], (B, S, H, dh)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, dh)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, dh)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, dh))) * 0.9 + 0.05
    u = jax.random.normal(ks[4], (H, dh)) * 0.1
    s0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    full, s_full = wkv(r, k, v, w, u, s0, block_t=8, interpret=True)
    h1, s1 = wkv(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u, s0,
                 block_t=8, interpret=True)
    h2, s2 = wkv(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u, s1,
                 block_t=8, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


# ----------------------------------------------------------------------------
# mamba selective scan (hymba SSM heads)
# ----------------------------------------------------------------------------
SSM_CASES = [
    (2, 32, 4, 64, 16, 8), (1, 50, 2, 32, 8, 16), (1, 16, 3, 128, 16, 16),
    (2, 24, 5, 64, 16, 8),
]


@pytest.mark.parametrize("case", SSM_CASES,
                         ids=lambda c: f"B{c[0]}S{c[1]}H{c[2]}d{c[3]}N{c[4]}")
def test_ssm_scan_vs_ref(case):
    from repro.kernels.ssm_scan.ops import ssm_scan
    from repro.kernels.ssm_scan.ref import ssm_scan_ref
    B, S, H, dh, N, bt = case
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    xh = jax.random.normal(ks[0], (B, S, H, dh)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bm = jax.random.normal(ks[2], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    A = -jnp.exp(jax.random.normal(ks[0], (H,)) * 0.3)
    s0 = jnp.zeros((B, H, N, dh), jnp.float32)
    y, sT = ssm_scan(xh, dt, Bm, Cm, A, s0, block_t=bt, interpret=True)
    ry, rs = ssm_scan_ref(xh, dt, Bm, Cm, A, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ry), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(rs), atol=1e-4)


def test_ssm_scan_state_chaining():
    from repro.kernels.ssm_scan.ops import ssm_scan
    B, S, H, dh, N = 1, 32, 2, 64, 8
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    xh = jax.random.normal(ks[0], (B, S, H, dh)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bm = jax.random.normal(ks[2], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    A = -jnp.exp(jax.random.normal(ks[0], (H,)) * 0.3)
    s0 = jnp.zeros((B, H, N, dh), jnp.float32)
    full, s_full = ssm_scan(xh, dt, Bm, Cm, A, s0, block_t=8, interpret=True)
    h1, s1 = ssm_scan(xh[:, :16], dt[:, :16], Bm[:, :16], Cm[:, :16], A, s0,
                      block_t=8, interpret=True)
    h2, s2 = ssm_scan(xh[:, 16:], dt[:, 16:], Bm[:, 16:], Cm[:, 16:], A, s1,
                      block_t=8, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


def test_hymba_forward_pallas_matches_ref():
    from repro.configs.registry import get_smoke_config
    from repro.models import model as M
    cfg = get_smoke_config("hymba-1.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    lr, _ = M.forward(cfg, params, tokens, impl="ref")
    lp, _ = M.forward(cfg, params, tokens, impl="pallas")
    err = float(jnp.abs(lr.astype(jnp.float32) - lp.astype(jnp.float32)).max())
    assert err < 0.15, err
