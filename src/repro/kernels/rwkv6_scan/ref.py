"""Pure-jnp oracle for the RWKV6 WKV kernel — re-export of the model's
sequential `lax.scan` recurrence (single source of truth for semantics)."""
from repro.models.ssm import wkv_scan_ref  # noqa: F401
