"""Paged flash-decode GQA attention: gather K/V through a block table.

The contiguous decode kernel (kernel.py) streams one request's cache as a
single slab. Under the paged KV subsystem (repro.kvcache, DESIGN.md §10)
a request's cache is `page_size`-token pages scattered anywhere in a
shared pool, named by a per-request block table. This kernel walks the
table: grid (B, KV, max_pages), and the *index map* of the K/V operands
reads the scalar-prefetched block table to DMA the right physical page
for each (request, page) grid step — the gather costs nothing over the
contiguous kernel because the page id is known before the block loads.

Layouts (arranged by the public wrapper):
  q            (B, KV, G, dh)        G = H/KV query heads per KV group
  k/v pool     (P, KV, page_size, dh) physical pages, any owner
  block table  (B, max_pages) int32  physical page per logical page,
                                     -1 = unallocated (masked out)
  ctx_lens     (B,) int32            tokens live per request

Validity per slot is positional: slot j of logical page ip holds absolute
token ip*page_size + j, live iff < ctx_lens[b] (and within the sliding
window). A partially-filled last page and garbage in unallocated pages
are therefore masked identically to the contiguous kernel's pos_ids mask.

`paged_decode_attention_ref` is the pure-jnp oracle: the same blocked
online-softmax walk, page by page, in the same operation order. The
bit-wise contract (test_kvcache.py) is two-fold: the kernel equals this
reference bit-for-bit at the model's cache dtype (bf16), and equals the
*contiguous* decode kernel on the gathered cache bit-for-bit at every
dtype — so the block-table gather is provably lossless, not just close.
(f32 kernel-vs-jnp-ref is ulp-level: XLA lowers the eager reference and
the jitted interpreter graph through different dot shapes.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e30
GLOBAL_WINDOW = 2 ** 30


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ============================================================================
# Pallas kernel
# ============================================================================
def _paged_decode_kernel(bt_ref, lens_ref, win_ref,     # SMEM scalar prefetch
                         q_ref, k_ref, v_ref,           # VMEM blocks
                         o_ref,                         # VMEM out
                         m_ref, l_ref, acc_ref,         # VMEM scratch
                         *, dh_real: int, page_size: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)           # (page_size, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (dh_real ** -0.5)                     # (G, page_size)

    ctx = lens_ref[b]
    window = win_ref[0]
    allocated = bt_ref[b, ip] >= 0
    t = ip * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size),
                                                  1)[0]
    valid = allocated & (t < ctx) & ((ctx - 1 - t) < window)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(ip == n_pages - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention_kernel(q, k_pool, v_pool, block_tables, ctx_lens,
                                  window, *, dh_real: int,
                                  interpret: bool = False):
    """q: (B, KV, G, dh); k/v_pool: (P, KV, page_size, dh);
    block_tables: (B, max_pages) int32 (-1 = unallocated); ctx_lens: (B,)
    int32; window: int32 scalar. dh % 128 == 0, page_size % 8 == 0.
    Returns (B, KV, G, dh)."""
    B, KV, G, dh = q.shape
    page_size = k_pool.shape[2]
    max_pages = block_tables.shape[1]
    grid = (B, KV, max_pages)

    kernel = functools.partial(_paged_decode_kernel, dh_real=dh_real,
                               page_size=page_size)
    # unallocated entries are masked in-kernel; the index map only needs a
    # resident page to (harmlessly) load, so clamp -1 -> page 0
    def kv_index(b, h, ip, bt, lens, win):
        return (jnp.maximum(bt[b, ip], 0), h, 0, 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, dh),
                             lambda b, h, ip, bt, lens, win: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, page_size, dh), kv_index),
                pl.BlockSpec((1, 1, page_size, dh), kv_index),
            ],
            out_specs=pl.BlockSpec((1, 1, G, dh),
                                   lambda b, h, ip, bt, lens, win:
                                   (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, dh), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), ctx_lens.astype(jnp.int32),
      jnp.asarray(window, jnp.int32)[None], q, k_pool, v_pool)


# ============================================================================
# Pure-jnp blocked oracle (bit-wise contract with the kernel)
# ============================================================================
def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, ctx_lens, *,
                               window=None):
    """Same layouts as the public wrapper: q (B, 1, H, dh); k/v_pool
    (P, page_size, KV, dh); block_tables (B, max_pages); ctx_lens (B,).
    Walks pages with the kernel's exact online-softmax arithmetic (same
    dot_generals, masking, and final division), so interpret-mode kernel
    output must equal this bit-for-bit. Returns (B, 1, H, dh)."""
    B, _, H, dh = q.shape
    page_size, KV = k_pool.shape[1], k_pool.shape[2]
    G = H // KV
    max_pages = block_tables.shape[1]
    if window is None:
        window = GLOBAL_WINDOW

    qg = q.reshape(B, KV, G, dh).astype(jnp.float32)
    kt = jnp.moveaxis(k_pool, 2, 1)               # (P, KV, page_size, dh)
    vt = jnp.moveaxis(v_pool, 2, 1)
    safe_bt = jnp.maximum(block_tables, 0)
    ctx = ctx_lens.astype(jnp.int32)

    # per-(b, kv-head) 2D dots, exactly one per kernel grid step, with the
    # G dim padded to the 8-row sublane tile the kernel's blocks occupy —
    # batched matmuls (and M=1 gemv lowerings) reduce in a different order
    # than the tiled gemm, an ulp-level drift that would break the
    # bit-wise contract
    Gp = max(G, 8)

    def _dot(a2, c2, contract):
        a2 = jnp.pad(a2, ((0, Gp - G), (0, 0)))
        out = jax.lax.dot_general(a2, c2, (((1,), (contract,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        return out[:G]

    def dot_qk(a, c):
        return jnp.stack([jnp.stack([_dot(a[b, h], c[b, h], 1)
                                     for h in range(KV)]) for b in range(B)])

    def dot_pv(a, c):
        return jnp.stack([jnp.stack([_dot(a[b, h], c[b, h], 0)
                                     for h in range(KV)]) for b in range(B)])

    m = jnp.full((B, KV, G, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((B, KV, G, 1), jnp.float32)
    acc = jnp.zeros((B, KV, G, dh), jnp.float32)
    for ip in range(max_pages):
        k = kt[safe_bt[:, ip]].astype(jnp.float32)   # (B, KV, ps, dh)
        v = vt[safe_bt[:, ip]].astype(jnp.float32)
        s = dot_qk(qg, k) * (dh ** -0.5)             # (B, KV, G, ps)
        t = ip * page_size + jnp.arange(page_size)
        valid = (block_tables[:, ip] >= 0)[:, None] \
            & (t[None, :] < ctx[:, None]) \
            & ((ctx[:, None] - 1 - t[None, :]) < window)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1, keepdims=True)
        acc = acc * corr + dot_pv(p, v)
        m = m_new
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l).astype(q.dtype).reshape(B, 1, H, dh)


# ============================================================================
# Public wrapper (model layout in)
# ============================================================================
@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, ctx_lens, *,
                           window=None, interpret=None):
    """q: (B, 1, H, dh); k/v_pool: (P, page_size, KV, dh); block_tables:
    (B, max_pages) int32 (-1 pads); ctx_lens: (B,) int32
    -> (B, 1, H, dh). Pads dh to the 128-lane tile; page_size must be a
    multiple of 8 (f32 sublane tile)."""
    if interpret is None:
        interpret = _auto_interpret()
    B, _, H, dh = q.shape
    page_size, KV = k_pool.shape[1], k_pool.shape[2]
    G = H // KV
    if window is None:
        window = GLOBAL_WINDOW
    assert page_size % 8 == 0, f"page_size {page_size} not sublane-aligned"

    pad_d = (-dh) % 128
    qk = q.reshape(B, KV, G, dh)
    kt = jnp.moveaxis(k_pool, 2, 1)               # (P, KV, page_size, dh)
    vt = jnp.moveaxis(v_pool, 2, 1)
    if pad_d:
        qk = jnp.pad(qk, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, 0), (0, pad_d)))

    out = paged_decode_attention_kernel(qk, kt, vt, block_tables, ctx_lens,
                                        window, dh_real=dh,
                                        interpret=interpret)
    return out[..., :dh].reshape(B, 1, H, dh)


def gather_page_row(pool, table_row):
    """Materialize one request's cache contiguously: pool (P, page_size,
    KV, dh), table_row (max_pages,) -> (max_pages*page_size, KV, dh).
    Unallocated (-1) entries gather page 0 — callers mask by position
    exactly like the kernel does. Oracle-side helper for tests/adoption."""
    pages = pool[jnp.maximum(table_row, 0)]        # (max_pages, ps, KV, dh)
    return pages.reshape(-1, *pool.shape[2:])
