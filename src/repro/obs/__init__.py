"""repro.obs: flight-recorder tracing, metrics registry, structured
logging (DESIGN.md §15), and the online SLO engine (DESIGN.md §17).

  trace          ring-buffered Tracer + the stable event vocabulary; zero
                 cost when no tracer is installed (get_tracer() -> None)
  exporters      Chrome trace-event JSON (Perfetto) + JSONL round-trip +
                 schema validation
  metrics        MetricsRegistry (counters/gauges/histograms) behind the
                 scheduler's stats — ServingReport is a derived view
  log            level-gated structured logger (quiet under pytest)
  sketch         bounded streaming instruments: ReservoirSketch (mergeable
                 quantiles with a documented rank-error bound), P2Quantile,
                 EWMA, WindowedCounter
  slo            declarative SLO targets, multi-window burn-rate alerts,
                 live health the router/planner consume
  critical_path  per-round latency attribution (compute / weight-stall /
                 hop / kv-migration / bubble) + per-request waterfalls
  dashboard      periodic text/JSON snapshots, live or offline from JSONL
"""
from repro.obs.critical_path import (CriticalPathReport,  # noqa: F401
                                     analyze, analyze_all, analyze_jsonl)
from repro.obs.dashboard import Dashboard, render_offline  # noqa: F401
from repro.obs.exporters import (export_chrome, export_jsonl,  # noqa: F401
                                 read_jsonl, to_chrome, validate_chrome,
                                 validate_chrome_file)
from repro.obs.log import get_logger  # noqa: F401
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry)
from repro.obs.sketch import (EWMA, P2Quantile,  # noqa: F401
                              ReservoirSketch, WindowedCounter,
                              reservoir_rank_error)
from repro.obs.slo import (SLOEngine, SLOTarget,  # noqa: F401
                           default_targets)
from repro.obs.trace import (Tracer, get_tracer,  # noqa: F401
                             set_tracer, tracing)
