"""Autotuner tests: MeasuredProfile JSON, TuneCache keys, kernel-config
resolution, the microbenchmark clock, and the online re-fit loop.

The sweep itself (timing real Pallas kernels) lives in CI's dry-run and
bench_autotune — here we pin the contracts everything else builds on:
round-trips are exact, cache keys are stable across processes, resolve
precedence is override > table > default, and drift actually rebuilds
the planner without touching anything when within tolerance.
"""
import dataclasses
import json
import math

import pytest

from repro.configs.registry import get_config
from repro.core.cost_model import CostEnv, Workload
from repro.core.offline_scheduler import allocate, allocate_with_retry
from repro.core.online_planner import OnlinePlanner
from repro.core.profiles import (AGX_ORIN_32, TPU_V5E, XAVIER_NX_16,
                                 env_E3, mbps)
from repro.kernels import tuning
from repro.tune.cache import TuneCache
from repro.tune.profiles import MeasuredProfile, from_analytic
from repro.tune.refit import OnlineRefit, RefitConfig


@pytest.fixture(autouse=True)
def _clean_tuning_table():
    """resolve() consults process-wide state; never leak it across tests."""
    saved = tuning.get_tuning_table()
    yield
    tuning.set_tuning_table(saved)


# ----------------------------------------------------------------------------
# MeasuredProfile JSON round-trip (NaN -> null convention)
# ----------------------------------------------------------------------------
def test_measured_profile_json_roundtrip_exact():
    p = from_analytic(TPU_V5E, device_kind="tpu-v5e", source="measured",
                      load_bw=1.5e9)
    # unmeasured fields carry NaN confidence; overridden ones are exact
    assert math.isnan(p.confidence["flops"])
    assert p.confidence["load_bw"] == 0.0
    text = p.to_json()
    assert "NaN" not in text and "null" in text
    q = MeasuredProfile.from_dict(json.loads(text))
    # NaN != NaN, so compare through to_dict (NaN -> None on both sides)
    assert q.to_dict() == p.to_dict()
    assert isinstance(q, MeasuredProfile) and q.load_bw == 1.5e9
    assert math.isnan(q.confidence["mem_bw"])


def test_measured_profile_extras_nan_roundtrip():
    p = from_analytic(AGX_ORIN_32, device_kind="orin", source="measured")
    p = dataclasses.replace(
        p, extras={"decode_tok_s": 12.5, "insert_bw": float("nan")})
    q = MeasuredProfile.from_dict(json.loads(p.to_json()))
    assert q.extras["decode_tok_s"] == 12.5
    assert math.isnan(q.extras["insert_bw"])


def test_from_analytic_keeps_unmeasured_fields():
    p = from_analytic(XAVIER_NX_16, device_kind="nx", flops=2e12)
    assert p.flops == 2e12
    assert p.mem_bytes == XAVIER_NX_16.mem_bytes
    assert p.mem_bw == XAVIER_NX_16.mem_bw
    assert p.name == XAVIER_NX_16.name
    # still a DeviceProfile: flows through CostEnv / allocate unchanged
    env = CostEnv([p, p], mbps(200),
                  Workload(get_config("llama2-13b"), mb=1, ctx=256))
    r = allocate(env, 40, n_emp=256)
    assert r.feasible or r.reason


# ----------------------------------------------------------------------------
# sanity guard: measured vs analytic > 3x warns and reports
# ----------------------------------------------------------------------------
def test_check_sane_flags_only_3x_deviations():
    p = from_analytic(TPU_V5E, device_kind="t",
                      flops=TPU_V5E.flops * 4.0,        # 4x: flagged
                      load_bw=TPU_V5E.load_bw * 0.2,    # 5x slow: flagged
                      mem_bw=TPU_V5E.mem_bw * 2.0)      # 2x: fine
    bad = p.check_sane(TPU_V5E)
    assert set(bad) == {"flops", "load_bw"}
    assert bad["flops"] == pytest.approx(4.0)
    assert bad["load_bw"] == pytest.approx(0.2)
    # within-tolerance profile is silent
    ok = from_analytic(TPU_V5E, device_kind="t")
    assert ok.check_sane(TPU_V5E) == {}


# ----------------------------------------------------------------------------
# cache keys: shape buckets and save/load stability
# ----------------------------------------------------------------------------
def test_shape_bucket_stable_and_padded():
    assert tuning.shape_bucket(2048, 64) == "s2048_d128"
    assert tuning.shape_bucket(1500, 128) == "s2048_d128"
    assert tuning.shape_bucket(2049, 130) == "s4096_d256"
    assert tuning.shape_bucket(1, 1) == "s8_d128"
    # deterministic: same inputs, same key, every call
    assert all(tuning.shape_bucket(512, 64) == "s512_d128"
               for _ in range(3))


def test_tune_cache_roundtrip_and_key_stability(tmp_path):
    c = TuneCache()
    c.put_profile(from_analytic(TPU_V5E, device_kind="cpu"))
    c.put_kernel("cpu", "decode_attention", "s2048_d128",
                 {"block_k": 2048}, speedup=2.99, us=123.4)
    c.put_kernel("cpu", "flash_attention", "s2048_d128",
                 {"block_q": 256, "block_k": 2048}, speedup=2.01)
    path = str(tmp_path / "tc.json")
    c.save(path)
    d = TuneCache.load(path)
    assert d.kernels == c.kernels          # keys and rows survive exactly
    assert d.get_profile("cpu").to_dict() == c.get_profile("cpu").to_dict()
    # a second save/load cycle is a fixed point
    path2 = str(tmp_path / "tc2.json")
    d.save(path2)
    assert TuneCache.load(path2).kernels == c.kernels
    # kernel_table strips _meta but keeps every block param
    table = d.kernel_table("cpu")
    assert table["decode_attention"]["s2048_d128"] == {"block_k": 2048}
    assert table["flash_attention"]["s2048_d128"] == {"block_q": 256,
                                                      "block_k": 2048}


def test_tune_cache_tolerates_missing_and_corrupt(tmp_path):
    assert TuneCache.load(str(tmp_path / "nope.json")).kernels == {}
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert TuneCache.load(str(bad)).profiles == {}
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({"version": 999, "kernels": {"x": {}}}))
    assert TuneCache.load(str(stale)).kernels == {}


# ----------------------------------------------------------------------------
# resolve precedence: override > installed table > historical default
# ----------------------------------------------------------------------------
def test_resolve_precedence():
    tuning.set_tuning_table(None)
    assert tuning.resolve("decode_attention", 2048, 64, "block_k") == \
        tuning.DEFAULTS["decode_attention"]["block_k"]
    c = TuneCache()
    c.put_kernel("cpu", "decode_attention", "s2048_d128",
                 {"block_k": 1024}, speedup=2.0)
    assert c.install("cpu") == 1
    assert tuning.resolve("decode_attention", 2048, 64, "block_k") == 1024
    # nearby shape, same bucket -> same winner; other bucket -> default
    assert tuning.resolve("decode_attention", 1500, 100, "block_k") == 1024
    assert tuning.resolve("decode_attention", 4096, 64, "block_k") == 512
    # explicit caller override always wins
    assert tuning.resolve("decode_attention", 2048, 64, "block_k",
                          override=256) == 256
    # empty cache installs nothing (defaults stay untouched)
    tuning.set_tuning_table(None)
    assert TuneCache().install("cpu") == 0
    assert tuning.get_tuning_table() is None


# ----------------------------------------------------------------------------
# microbenchmark clock
# ----------------------------------------------------------------------------
def test_timeit_median_counts_and_shape():
    from repro.tune.measure import timeit_median
    calls = []
    med, cov = timeit_median(lambda: calls.append(1), reps=4, warmup=2)
    assert len(calls) == 6          # warmup runs execute but aren't timed
    assert med >= 0.0 and cov >= 0.0


def test_measure_stream_bw_smoke():
    from repro.tune.measure import measure_stream_bw
    bw = measure_stream_bw(mb=1, reps=2)
    for d in ("h2d", "d2h"):
        v, cov = bw[d]
        assert v > 0 and math.isfinite(v)
        # a CPU "copy" that aliased the buffer would report PB/s
        assert v < 1e15, f"{d} bandwidth {v:.3g} B/s is not a real copy"


# ----------------------------------------------------------------------------
# launch-time feasibility retry (shared by serve.py for measured profiles)
# ----------------------------------------------------------------------------
def test_allocate_with_retry_relaxes_until_feasible():
    cfg = get_config("llama2-13b")

    def mk_env(scale):
        devs = [XAVIER_NX_16.scaled_mem(0.25 * scale) for _ in range(2)]
        return CostEnv(devs, mbps(200), Workload(cfg, mb=1, ctx=1024))

    r0 = allocate(mk_env(1.0), cfg.n_layers, n_emp=1024)
    assert not r0.feasible          # the premise: 1.0 is too tight
    r, env, scale = allocate_with_retry(mk_env, cfg.n_layers, n_emp=1024)
    assert r.feasible and scale > 1.0
    assert env.mem_ok(r.plan, 1024)


# ----------------------------------------------------------------------------
# online re-fit
# ----------------------------------------------------------------------------
def _offload_env_and_planner():
    """A fleet that must stream weights (the refit path only matters when
    load_bw prices something): E3 at 0.45x memory under llama3.3-70b."""
    cfg = get_config("llama3.3-70b")
    devs = [dataclasses.replace(d, mem_bytes=int(d.mem_bytes * 0.45))
            for d in env_E3()]
    env = CostEnv(devs, mbps(200), Workload(cfg, mb=1, ctx=512))
    r = allocate(env, cfg.n_layers, n_emp=512)
    assert r.feasible, r.reason
    assert any(d.off_layers_seg() > 0 for d in r.plan.devices)
    return env, OnlinePlanner(env, r.plan, horizon_tokens=2 ** 16)


def test_refit_quiet_within_tolerance():
    env, pl = _offload_env_and_planner()
    rf = OnlineRefit(env, pl, config=RefitConfig(min_samples=2,
                                                 cooldown_s=0.0))
    planned = [d.load_bw for d in env.devices]
    for t in range(4):
        for i, bw in enumerate(planned):
            rf.observe_fetch(i, nbytes=bw * 0.01, seconds=0.01,
                             now=float(t))
    assert rf.maybe_refit(now=5.0) == []
    assert pl.rebuilds == 0
    assert [d.load_bw for d in env.devices] == planned


def test_refit_drift_updates_env_and_rebuilds_ladder():
    env, pl = _offload_env_and_planner()
    chunk0 = pl.chunk
    rf = OnlineRefit(env, pl, config=RefitConfig(min_samples=2,
                                                 cooldown_s=0.0))
    planned = [d.load_bw for d in env.devices]
    # every loader actually delivers half the knob
    for t in range(4):
        for i, bw in enumerate(planned):
            rf.observe_fetch(i, nbytes=bw * 0.5 * 0.01, seconds=0.01,
                             now=float(t))
    fired = rf.maybe_refit(now=5.0)
    assert fired and rf.n_refits == len(fired)
    assert all(ev.field == "load_bw" for ev in fired)
    for i, bw in enumerate(planned):
        assert env.devices[i].load_bw == pytest.approx(bw * 0.5, rel=1e-6)
    assert pl.rebuilds == 1
    # slower loader -> smaller demotion chunks (scaled by measured/planned)
    assert pl.chunk == max(32, int(round(chunk0 * 0.5)))
    # planner ladders still monotone after the rebuild
    for lad in pl.ladders:
        ts = [s.threshold_tokens for s in lad]
        assert ts == sorted(ts)
    # cooldown: an immediate second call is a no-op
    assert rf.maybe_refit(now=5.0 + 0.5) == []


def test_refit_compute_drift_scales_flops():
    env, pl = _offload_env_and_planner()
    rf = OnlineRefit(env, pl, config=RefitConfig(min_samples=2,
                                                 cooldown_s=0.0))
    flops0 = env.devices[0].flops
    # device 0 computes 2x slower than planned (planned/observed = 0.5)
    for t in range(4):
        rf.observe_compute(0, seconds=0.02, planned_seconds=0.01,
                           now=float(t))
    fired = rf.maybe_refit(now=5.0)
    assert [ev.field for ev in fired] == ["flops"]
    assert env.devices[0].flops == pytest.approx(flops0 * 0.5, rel=1e-6)


def test_refit_needs_min_samples():
    env, pl = _offload_env_and_planner()
    rf = OnlineRefit(env, pl, config=RefitConfig(min_samples=4,
                                                 cooldown_s=0.0))
    bw = env.devices[0].load_bw
    rf.observe_fetch(0, nbytes=bw * 0.1 * 1.0, seconds=1.0, now=0.0)
    assert rf.drift(0) == {}
    assert rf.maybe_refit(now=1.0) == []
