"""Measured-profile autotuner: does measuring actually buy anything?
(EXPERIMENTS.md §Autotune, DESIGN.md §18.)

Three exit-enforced claims, one per stage of the measure -> plan ->
re-fit pipeline:

  plan    On a heterogeneous fleet whose *actual* weight-stream
          bandwidths differ from the analytic knobs (one device's SSD is
          far slower than the datasheet), the plan allocated from
          measured profiles beats the plan allocated from analytic
          profiles on p50 step latency when both execute under the true
          rates. FAIL if the measured plan is not strictly faster.

  sweep   The Pallas block-size sweep (interpret mode on CPU: grid-step
          count is the cost driver; VMEM residency on TPU) finds a
          config >= 1.2x faster than the historical default for at least
          one (kernel, shape-bucket). FAIL otherwise.

  refit   A serving run whose loader bandwidth is throttled 2x mid-run:
          with --refit the EWMA estimators detect the drift, fold the
          measured bandwidth into the planned CostEnv, and rebuild the
          TS ladders — without preempting more requests than the same
          run without re-fit. FAIL if no rebuild fires or preemptions
          increase.

  python benchmarks/bench_autotune.py
  python benchmarks/bench_autotune.py --skip-sweep --out /tmp/at.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))


# ---------------------------------------------------------------------------
# Part 1: measured plan vs analytic plan under true pricing
# ---------------------------------------------------------------------------
def _hetero_envs(args):
    """(analytic_env, measured_env, true_env): same memory everywhere;
    the analytic knobs assume a uniform loader, the truth is lopsided,
    the measured profiles report the truth (as the harness would)."""
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.profiles import env_E3, mbps
    from repro.configs.registry import get_config
    from repro.tune.profiles import from_analytic

    cfg = get_config(args.arch)
    w = Workload(cfg, mb=1, ctx=args.prompt_len, n_micro=1)
    base = [dataclasses.replace(d, mem_bytes=int(d.mem_bytes * args.mem_frac))
            for d in env_E3()]
    # the truth: device 1's SSD delivers a fraction of the knob, device 0
    # over-delivers — exactly the lopsidedness a datasheet never shows
    true_bw = [args.fast_factor, args.slow_factor, 1.0, 1.0]
    true_devs = [dataclasses.replace(d, load_bw=d.load_bw * true_bw[i])
                 for i, d in enumerate(base)]
    measured_devs = [from_analytic(base[i], device_kind="bench",
                                   load_bw=true_devs[i].load_bw)
                     for i in range(len(base))]
    mk = lambda devs: CostEnv(list(devs), mbps(args.bw_mbps), w)
    return mk(base), mk(measured_devs), mk(true_devs)


def run_plan_comparison(args) -> dict:
    from repro.core.offline_scheduler import allocate
    from repro.core.pipeline_sim import InterleavedPipelineSim
    from repro.configs.registry import get_config

    cfg = get_config(args.arch)
    analytic_env, measured_env, true_env = _hetero_envs(args)

    out = {}
    for label, env in (("analytic", analytic_env), ("measured",
                                                    measured_env)):
        r = allocate(env, cfg.n_layers, n_emp=args.prompt_len + args.tokens)
        if not r.feasible:
            return {"error": f"{label} allocation infeasible: {r.reason}"}
        sim = InterleavedPipelineSim(env, r.plan,
                                     prompt_tokens=args.prompt_len,
                                     true_env=true_env)
        res = sim.run(args.tokens)
        lats = sorted(t.latency for t in res.per_token)
        out[label] = {
            "plan_k_res": r.plan.k_res_list,
            "plan_k_off": r.plan.k_off_list,
            "n_seg": r.plan.n_seg,
            "p50_s": lats[len(lats) // 2],
            "mean_s": sum(lats) / len(lats),
            "stall_s": sum(t.load_stall for t in res.per_token),
        }
    out["p50_gain"] = (out["analytic"]["p50_s"]
                       / max(out["measured"]["p50_s"], 1e-12))
    return out


# ---------------------------------------------------------------------------
# Part 2: kernel block-size sweep
# ---------------------------------------------------------------------------
def run_kernel_sweep(args) -> dict:
    from repro.tune.cache import TuneCache
    from repro.tune.sweep import run_sweep

    cache = TuneCache()
    results = run_sweep(args.sweep_kernels.split(","), cache=cache,
                        device_kind="bench", reps=args.sweep_reps)
    rows = [r.to_dict() for r in results]
    best = max(results, key=lambda r: r.speedup)
    return {"rows": rows,
            "best_kernel": best.kernel,
            "best_bucket": best.bucket,
            "best_cfg": best.best_cfg,
            "best_speedup": best.speedup}


# ---------------------------------------------------------------------------
# Part 3: online re-fit under injected bandwidth drift
# ---------------------------------------------------------------------------
def _drift_run(args, refit: bool) -> dict:
    from repro.core.cost_model import CostEnv
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               SimBackend, cli_arrivals,
                               requests_from_arrivals, summarize)

    analytic_env, _, _ = _hetero_envs(args)
    backend = SimBackend(analytic_env, n_slots=args.slots,
                         prompt_tokens=args.prompt_len, refit=refit)
    budget = int(args.budget_factor * (args.prompt_len + args.max_new))
    sched = ContinuousBatchingScheduler(backend, SchedulerConfig(
        kv_budget_tokens=budget, kv_policy="paged",
        page_size=args.page_size, preempt="recompute"))
    arrivals = cli_arrivals("bursty", args.n_requests, seed=args.seed,
                            prompt_len=args.prompt_len,
                            max_new_tokens=args.max_new, gap_s=args.gap_s,
                            burst_size=args.slots)
    reqs = requests_from_arrivals(arrivals)

    env = backend.env
    drifted = CostEnv([dataclasses.replace(d, load_bw=d.load_bw
                                           * args.drift_factor)
                       for d in env.devices], env.bw_net, env.work,
                      env.net_latency)
    sched.begin(reqs)
    steps = 0
    while sched.step():
        steps += 1
        if steps == args.drift_after_steps:
            backend.sim.set_true_env(drifted)   # the SSD throttles NOW
    served = sched.finish_run()
    rep = summarize(served, pattern="bursty",
                    backend=f"sim/{'refit' if refit else 'static'}",
                    stats=sched.stats).to_dict()
    pl = backend.sim.planner
    return {"refit": refit,
            "p50_s": rep["latency_p50_s"],
            "n_preempted": rep["n_preempted"],
            "rebuilds": pl.rebuilds if pl else 0,
            "refit_events": backend.refit.n_refits if backend.refit else 0,
            "ladder_chunk": pl.chunk if pl else None}


def run_refit_drift(args) -> dict:
    static = _drift_run(args, refit=False)
    refit = _drift_run(args, refit=True)
    return {"static": static, "refit": refit}


# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="llama3.3-70b",
                    help="needs to overflow the fleet so weights stream")
    ap.add_argument("--mem-frac", type=float, default=0.45,
                    help="shrink E3 memory so the plan offloads")
    ap.add_argument("--bw-mbps", type=float, default=200.0)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=96,
                    help="decode steps for the plan comparison")
    ap.add_argument("--slow-factor", type=float, default=0.3,
                    help="device 1's true load_bw vs the analytic knob")
    ap.add_argument("--fast-factor", type=float, default=2.0,
                    help="device 0's true load_bw vs the analytic knob")
    # sweep
    ap.add_argument("--sweep-kernels",
                    default="decode_attention,flash_attention,"
                            "mq_decode_attention")
    ap.add_argument("--sweep-reps", type=int, default=3)
    ap.add_argument("--skip-sweep", action="store_true")
    # refit drift
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--gap-s", type=float, default=30.0)
    ap.add_argument("--budget-factor", type=float, default=6.0)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--drift-factor", type=float, default=0.5)
    ap.add_argument("--drift-after-steps", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    plan = run_plan_comparison(args)
    sweep = None if args.skip_sweep else run_kernel_sweep(args)
    drift = run_refit_drift(args)
    payload = {"config": vars(args), "plan": plan, "sweep": sweep,
               "refit": drift}
    text = json.dumps(payload, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")

    rc = 0
    if "error" in plan:
        print(f"# FAIL: {plan['error']}", file=sys.stderr)
        rc = 1
    else:
        print(f"# plan: measured p50 {plan['measured']['p50_s']:.3f}s vs "
              f"analytic {plan['analytic']['p50_s']:.3f}s "
              f"({plan['p50_gain']:.2f}x) under true rates",
              file=sys.stderr)
        if plan["p50_gain"] <= 1.0:
            print("# FAIL: measured-profile plan did not beat the "
                  "analytic plan under true pricing", file=sys.stderr)
            rc = 1
    if sweep is not None:
        print(f"# sweep: best {sweep['best_kernel']}@{sweep['best_bucket']} "
              f"{sweep['best_cfg']} = {sweep['best_speedup']:.2f}x over "
              f"default", file=sys.stderr)
        if sweep["best_speedup"] < 1.2:
            print("# FAIL: kernel sweep found no config >= 1.2x over the "
                  "historical default", file=sys.stderr)
            rc = 1
    s, r = drift["static"], drift["refit"]
    print(f"# refit: {r['rebuilds']} ladder rebuild(s), "
          f"{r['refit_events']} env update(s), chunk {r['ladder_chunk']}; "
          f"preemptions {r['n_preempted']} vs static {s['n_preempted']}",
          file=sys.stderr)
    if r["rebuilds"] < 1:
        print("# FAIL: injected drift never triggered a ladder rebuild",
              file=sys.stderr)
        rc = 1
    if r["n_preempted"] > s["n_preempted"]:
        print("# FAIL: re-fit run preempted more requests than static",
              file=sys.stderr)
        rc = 1
    return rc


def run():
    """benchmarks.run harness hook: the exit-enforced default scenario
    (sweep trimmed to one kernel to keep the suite fast)."""
    class _Row:
        def __init__(self, name, ms):
            self.name, self.ms = name, ms

        def csv(self):
            return f"autotune,{self.name},{self.ms:.1f},ok"

    rc = main(["--sweep-kernels", "decode_attention", "--sweep-reps", "2"])
    if rc:
        raise SystemExit("bench_autotune failed")
    return [_Row("measure_plan_sweep_refit", 0.0)]


if __name__ == "__main__":
    raise SystemExit(main())
