"""FleetRouter: scored request placement across pipeline replicas
(DESIGN.md §16).

Placement is a pure function of (request, replica states, router state),
so the same stream against the same fleet always routes identically —
the determinism property tests/test_fleet.py asserts. Four policies:

  roundrobin  cycle over non-draining replicas (baseline)
  random      seeded uniform choice (baseline the bench beats)
  sticky      session affinity + load (no token inspection)
  prefix      the full score (default):

    score(r) = w_prefix * overlap(r) + w_sticky * [home(session) == r]
             - w_queue * queue_depth(r)/n_slots - w_kv * (1 - free_kv(r))
             - w_health * (1 - health(r))

  health(r) is the replica's SLO health (DESIGN.md §17): 1.0 when no
  SLOEngine is attached or every target holds, falling toward 0 under
  burn — traffic sheds away from a breaching replica before its queue
  compounds the breach.

  overlap(r) is the matched-prefix *fraction* of the prompt against
  replica r's digest — the live radix summary unioned with an
  *optimistic* digest of prompts already routed there (so the second
  request of a template sticks before the first one finishes).

Two stabilizers keep the score from thrashing:

  hysteresis  a sticky session moves off its incumbent replica only when
              a challenger beats the incumbent's score by `hysteresis` —
              near-ties don't flap a conversation between replicas (each
              flap abandons cached KV).
  spillover   when the chosen replica is saturated (queue_depth >=
              saturation_queue) the request spills to the least-loaded
              live replica instead — affinity is a latency optimization,
              not a correctness constraint, and a saturated favorite
              would cost more in queueing than the prefix hit saves.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.obs import trace as tr_ev
from repro.obs.trace import get_tracer
from repro.prefixcache.digest import PrefixDigest
from repro.serving.scheduler import Request

from repro.fleet.replica import Replica

POLICIES = ("prefix", "sticky", "random", "roundrobin")


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    policy: str = "prefix"        # one of POLICIES
    w_prefix: float = 1.0         # per unit matched-prefix fraction
    w_sticky: float = 0.5         # incumbent-home bonus
    w_queue: float = 0.25         # per queued request (slot-normalized)
    w_kv: float = 0.25            # per unit KV fullness
    w_health: float = 1.0         # per unit SLO unhealth (1 - health)
    saturation_queue: int = 8     # spillover threshold (queue depth)
    hysteresis: float = 0.15      # margin to move a sticky session
    seed: int = 0                 # random policy / any future jitter

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown router policy {self.policy!r}; "
                             f"have {POLICIES}")


class FleetRouter:
    """Stateful placement: score table + session homes + optimistic
    digests. One instance per fleet."""

    def __init__(self, config: RouterConfig = RouterConfig()):
        self.config = config
        self._rng = np.random.default_rng(config.seed)
        self._rr = 0                                  # roundrobin cursor
        self._home: Dict[int, str] = {}               # session -> replica
        self._optimistic: Dict[str, PrefixDigest] = {}
        self.stats: Dict[str, float] = {
            "routed": 0, "spillover": 0, "sticky_kept": 0,
            "sticky_moved": 0, "prefix_matched": 0, "no_replica": 0,
        }

    # -- scoring -----------------------------------------------------------------
    def _overlap(self, req: Request, rep: Replica) -> float:
        """Matched-prefix fraction of the prompt on `rep` (live digest
        unioned with optimistically-routed prompts)."""
        if req.prompt is None or req.prompt_len <= 0:
            return 0.0
        matched = 0
        d = rep.digest()
        if d is not None:
            matched = d.match_tokens(req.prompt)
        opt = self._optimistic.get(rep.name)
        if opt is not None:
            matched = max(matched, opt.match_tokens(req.prompt))
        return matched / req.prompt_len

    def score(self, req: Request, rep: Replica) -> float:
        cfg = self.config
        s = 0.0
        if cfg.policy == "prefix":
            s += cfg.w_prefix * self._overlap(req, rep)
        if req.session_id is not None \
                and self._home.get(req.session_id) == rep.name:
            s += cfg.w_sticky
        s -= cfg.w_queue * rep.queue_depth / max(rep.backend.n_slots, 1)
        s -= cfg.w_kv * (1.0 - rep.free_kv_frac())
        s -= cfg.w_health * (1.0 - rep.health())
        return s

    # -- placement ---------------------------------------------------------------
    def route(self, req: Request,
              replicas: List[Replica]) -> Optional[Replica]:
        """Pick the replica for `req`, or None when no live non-draining
        replica exists. Updates session homes / optimistic digests."""
        cfg = self.config
        cands = sorted((r for r in replicas if r.live and not r.draining),
                       key=lambda r: r.index)
        if not cands:
            self.stats["no_replica"] += 1
            return None
        spilled = False
        if cfg.policy == "roundrobin":
            pick = cands[self._rr % len(cands)]
            self._rr += 1
        elif cfg.policy == "random":
            pick = cands[int(self._rng.integers(0, len(cands)))]
        else:                                   # scored: sticky | prefix
            scores = {r.name: self.score(req, r) for r in cands}
            pick = max(cands, key=lambda r: (scores[r.name], -r.index))
            home = self._home.get(req.session_id) \
                if req.session_id is not None else None
            if home is not None and home != pick.name:
                inc = next((r for r in cands if r.name == home), None)
                if inc is not None and scores[pick.name] \
                        < scores[inc.name] + cfg.hysteresis:
                    pick = inc                  # challenger inside margin
                    self.stats["sticky_kept"] += 1
                else:
                    self.stats["sticky_moved"] += 1
            elif home is not None:
                self.stats["sticky_kept"] += 1
            if pick.queue_depth >= cfg.saturation_queue:
                alt = min(cands, key=lambda r: (r.queue_depth, r.index))
                if alt is not pick \
                        and alt.queue_depth < cfg.saturation_queue:
                    pick, spilled = alt, True
                    self.stats["spillover"] += 1
            if cfg.policy == "prefix" and self._overlap(req, pick) > 0:
                self.stats["prefix_matched"] += 1
        # bookkeeping: the session now lives where the request landed, and
        # (prefix policy) the routed prompt's chain is optimistically
        # assumed cached there
        if req.session_id is not None and cfg.policy in ("prefix",
                                                         "sticky"):
            self._home[req.session_id] = pick.name
        if cfg.policy == "prefix" and req.prompt is not None:
            opt = self._optimistic.get(pick.name)
            if opt is None:
                opt = self._optimistic[pick.name] = \
                    PrefixDigest(pick.page_size)
            opt.add_prompt(req.prompt,
                           max_pages=(req.prompt_len - 1) // pick.page_size)
        self.stats["routed"] += 1
        tr = get_tracer()
        if tr is not None:
            tr.instant(tr_ev.FLEET_ROUTE, ts=req.arrival_s,
                       track=tr_ev.TRACK_ROUTER,
                       args={"rid": req.rid, "to": pick.name,
                             "policy": cfg.policy, "spillover": spilled})
        return pick

    def forget(self, name: str) -> None:
        """Drop a retired replica from router state (drain completion):
        its sessions re-home on their next turn, its optimistic digest
        dies with its cache."""
        self._optimistic.pop(name, None)
        self._home = {s: n for s, n in self._home.items() if n != name}
