"""Qwen3-32B — paper Tab. III row 2 (64L, hidden 5120, 64H, kv=8)."""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="qwen3-32b", family=Family.DENSE,
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
    d_ff=25600, vocab_size=151936, head_dim=128,
    attn_kind=AttnKind.FULL, rope_theta=1_000_000.0,
    source="LIME paper Tab. III / Qwen3 [arXiv:2505.09388]",
)
