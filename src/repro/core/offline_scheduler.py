"""Fine-grained offline allocation scheduler (paper §IV-C, Alg. 1).

Three phases, exactly as the paper orders them:

  1. Greedy resident fill (Alg. 1 lines 28-31): every device takes as many
     fully-resident layers as its memory allows (after reserving KV-cache
     room for the empirical sequence length `n_emp` and the per-segment
     offload load buffer).
  2. For each feasible segment count #Seg (line 32): per-segment DP
     (SegmentAllocation, lines 1-11) assigns the remaining layers' *loads*
     to devices minimizing accumulated uncovered delay:
         F_allo(l, i) = min_k max(0, F_allo(l-k, i-1) + load_i(k) - T_i^idle)
     with backtracking through P_pre.
  3. Fine-grained block refinement (lines 12-27): while the bottleneck
     device has leftover memory for an MHA or MLP block, pin that block
     resident so only the complement is re-loaded each segment. Pinning a
     block costs (#Seg - 1) extra copies of it (one per segment beyond the
     load buffer — Eq. 7's (#Seg-1) factor; Alg. 1 line 16 under-counts its
     own Eq. 7, we keep the self-consistent version, DESIGN.md §8).

The best (#Seg, allocation) under T_comp + T_comm + T_uncover wins (lines
33-39). Complexity O(|L_left|² · |D|) per #Seg, as the paper states.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.core.cost_model import CostEnv, ExecutionPlan, StageAlloc

INF = float("inf")


@dataclasses.dataclass
class ScheduleResult:
    plan: Optional[ExecutionPlan]
    feasible: bool
    reason: str = ""
    candidates: Tuple = ()      # (n_seg, t_total) for every evaluated #Seg


# ----------------------------------------------------------------------------
# Phase 1: greedy resident fill
# ----------------------------------------------------------------------------
def _greedy_fill(env: CostEnv, n_layers: int, n_emp: int,
                 reserve_buffer: bool) -> Tuple[List[int], int]:
    """Resident layer counts per device (Alg. 1 line 28), filling the
    *fastest* devices first so leftover layers (whose loads the DP must
    cover) land where the most idle time exists; returns (res, left)."""
    w = env.work
    kv_per_layer = n_emp * w.kv_bytes_per_token_layer()
    order = sorted(range(len(env.devices)),
                   key=lambda i: w.comp_layer(env.devices[i]))
    res = [0] * len(env.devices)
    left = n_layers
    for i in order:
        mem = env.devices[i].mem_bytes
        if reserve_buffer:
            mem -= w.l_size          # one-layer load buffer for offloading
        cap = int(mem // (w.l_size + kv_per_layer))
        take = max(min(cap, left), 0)
        res[i] = take
        left -= take
    return res, left


def _balance_residents(env: CostEnv, n_layers: int, n_emp: int
                       ) -> Optional[List[int]]:
    """No-offload path: compute-balanced layer counts under memory caps.

    The paper's Alg. 1 is memory-greedy because it targets the offload
    regime; when the model fits outright, a deployment-grade scheduler
    balances stages by compute (bursty throughput is gated by the slowest
    stage). Flagged as a beyond-paper refinement in DESIGN.md §8 — disable
    with allocate(..., balance=False) for the strictly-literal behaviour.
    """
    w = env.work
    kv_per_layer = n_emp * w.kv_bytes_per_token_layer()
    caps = [int(d.mem_bytes // (w.l_size + kv_per_layer))
            for d in env.devices]
    if sum(caps) < n_layers:
        return None
    speeds = [1.0 / w.comp_layer(d) for d in env.devices]
    tot = sum(speeds)
    alloc = [min(int(round(n_layers * s / tot)), c)
             for s, c in zip(speeds, caps)]
    diff = n_layers - sum(alloc)
    k = 0
    order = sorted(range(len(alloc)), key=lambda i: speeds[i], reverse=True)
    while diff != 0 and k < 8 * len(alloc):
        i = order[k % len(alloc)]
        step = 1 if diff > 0 else -1
        if 0 <= alloc[i] + step <= caps[i]:
            alloc[i] += step
            diff -= step
        k += 1
    return alloc if diff == 0 else None


# ----------------------------------------------------------------------------
# Phase 2: per-segment DP (Alg. 1 SegmentAllocation, lines 1-11)
# ----------------------------------------------------------------------------
def _offload_cap(env: CostEnv, plan: ExecutionPlan, i: int,
                 n_emp: int) -> int:
    """Max offloaded layers (per segment) device i can take: each costs a
    load-buffer slot (1 copy of weights) plus n_seg segments' worth of KV."""
    w = env.work
    d = plan.stages[i]
    kv_layer = n_emp * w.kv_bytes_per_token_layer()
    used = (d.resident_total * (w.l_size + kv_layer))
    free = env.devices[i].mem_bytes - used
    per_off = w.l_size + plan.n_seg * kv_layer
    return max(int(free // per_off), 0)


def _segment_dp(env: CostEnv, plan: ExecutionPlan, n_left_seg: int,
                n_emp: int) -> Optional[List[int]]:
    """Assign `n_left_seg` offloaded layers (one segment's worth) to devices.
    Returns per-device counts k_i (sum = n_left_seg) minimizing accumulated
    uncovered delay, or None if memory-infeasible everywhere."""
    D = len(env.devices)
    w = env.work
    idle = [env.idle_seg(plan, i) for i in range(D)]
    load1 = [env.load_time(i, w.l_size) for i in range(D)]
    caps = [_offload_cap(env, plan, i, n_emp) for i in range(D)]

    # F[l][i]: min accumulated uncovered delay, first l layers on first i+1 devs
    F = [[INF] * D for _ in range(n_left_seg + 1)]
    P = [[0] * D for _ in range(n_left_seg + 1)]
    for l in range(n_left_seg + 1):                       # device 0 (Eq. 3)
        if l <= caps[0]:
            F[l][0] = max(0.0, l * load1[0] - idle[0])
            P[l][0] = l
    for i in range(1, D):                                 # Eq. 4
        for l in range(n_left_seg + 1):
            for k in range(min(l, caps[i]) + 1):
                prev = F[l - k][i - 1]
                if prev == INF:
                    continue
                t_cur = max(0.0, prev + k * load1[i] - idle[i])
                if t_cur <= F[l][i]:
                    F[l][i] = t_cur
                    P[l][i] = k
    if F[n_left_seg][D - 1] == INF:
        return None
    counts = [0] * D
    l = n_left_seg
    for i in range(D - 1, -1, -1):
        counts[i] = P[l][i]
        l -= counts[i]
    return counts


# ----------------------------------------------------------------------------
# Phase 3: fine-grained block refinement (Alg. 1 lines 12-27)
# ----------------------------------------------------------------------------
def _refine_blocks(env: CostEnv, plan: ExecutionPlan, n_emp: int) -> None:
    """Pin MHA/MLP blocks of offloaded layers resident on the bottleneck
    device while memory allows, shaving its per-segment load time."""
    w = env.work
    n_seg = plan.n_seg

    def free_mem(i: int) -> float:
        d = plan.stages[i]
        used = (d.resident_bytes(w, n_seg)
                + env.kv_reserve_bytes(d.layers_total(n_seg), n_emp))
        return env.devices[i].mem_bytes - used

    def uncovered(i: int) -> float:
        d = plan.stages[i]
        return max(env.load_time(i, d.load_bytes_seg(w))
                   - env.idle_seg(plan, i), 0.0)

    while True:
        # bottleneck device = max uncovered load (the term T_uncover tracks)
        order = sorted(range(len(plan.stages)), key=uncovered, reverse=True)
        i = order[0]
        if uncovered(i) <= 0.0:
            break
        d = plan.stages[i]
        mem = free_mem(i)
        extra = n_seg - 1          # pinned block copies beyond the load buffer
        # prefer pinning the bigger block (bigger load shaved per byte of
        # leftover: both shave proportionally, bigger block = bigger shave)
        if d.off_full_seg >= 1 and mem >= extra * w.mlp_block_bytes \
                and w.p_M >= w.p_A:
            d.off_full_seg -= 1
            d.off_attn_only_seg += 1        # MLP pinned, MHA still loaded
        elif d.off_full_seg >= 1 and mem >= extra * w.attn_block_bytes:
            d.off_full_seg -= 1
            d.off_mlp_only_seg += 1         # MHA pinned, MLP still loaded
        elif d.off_full_seg >= 1 and mem >= extra * w.mlp_block_bytes:
            d.off_full_seg -= 1
            d.off_attn_only_seg += 1
        elif d.off_attn_only_seg >= 1 and mem >= extra * w.attn_block_bytes:
            # complete the layer: pin the remaining MHA -> fully resident
            d.off_attn_only_seg -= 1
            d.resident_total += n_seg       # one layer per segment now resident
        elif d.off_mlp_only_seg >= 1 and mem >= extra * w.mlp_block_bytes:
            d.off_mlp_only_seg -= 1
            d.resident_total += n_seg
        else:
            break                  # bottleneck can't improve: optimal bound


# ----------------------------------------------------------------------------
# Entry point (Alg. 1 main, lines 28-39)
# ----------------------------------------------------------------------------
def allocate(env: CostEnv, n_layers: int, *, n_emp: int = 512,
             max_seg: Optional[int] = None,
             balance: bool = True) -> ScheduleResult:
    """Run Alg. 1 for `n_layers` decoder layers on `env.devices`."""
    D = len(env.devices)
    # No-offload path first: if the model + KV reserve fits outright, a
    # resident pipeline strictly dominates any offloading plan (zero load).
    res2 = _balance_residents(env, n_layers, n_emp) if balance else None
    if res2 is None:
        res2, left2 = _greedy_fill(env, n_layers, n_emp, reserve_buffer=False)
        if left2:
            res2 = None
    if res2 is not None:
        plan = ExecutionPlan(n_seg=1, stages=[StageAlloc(r) for r in res2])
        env.evaluate(plan)
        if env.mem_ok(plan, n_emp):
            return ScheduleResult(plan, True, "fits without offloading",
                                  ((1, plan.t_total),))
    res, left = _greedy_fill(env, n_layers, n_emp, reserve_buffer=True)

    if left > 0 and all(r == 0 for r in res) and left > n_layers:
        return ScheduleResult(None, False, "devices cannot hold any layer")

    # Offloading path: evaluate every feasible segment count (line 32).
    hi = max_seg or max(2, min(left, math.ceil(n_layers / max(D, 1))))
    hi = max(hi, 2)
    best: Optional[ExecutionPlan] = None
    cands = []
    for n_seg in range(2, hi + 1):
        per_seg = math.ceil(left / n_seg)   # even split; short last segment
        plan = ExecutionPlan(n_seg=n_seg,
                             stages=[StageAlloc(r) for r in res],
                    off_trim=per_seg * n_seg - left)
        counts = _segment_dp(env, plan, per_seg, n_emp)
        if counts is None:
            continue
        for i, k in enumerate(counts):
            plan.stages[i].off_full_seg = k
        # memory feasibility: load buffer sized by the DP result
        if not env.mem_ok(plan, n_emp):
            continue
        _refine_blocks(env, plan, n_emp)
        env.evaluate(plan)
        # exact layer count: trim the padding overshoot into the cost
        cands.append((n_seg, plan.t_total))
        if best is None or plan.t_total < best.t_total:
            best = plan
    if best is None:
        return ScheduleResult(None, False,
                              "no feasible (#Seg, allocation) found",
                              tuple(cands))
    return ScheduleResult(best, True, "", tuple(cands))


def allocate_with_retry(mk_env, n_layers: int, *, n_emp: int = 512,
                        max_seg: Optional[int] = None, balance: bool = True,
                        factor: float = 1.4, max_scale: float = 16.0
                        ) -> Tuple[ScheduleResult, CostEnv, float]:
    """allocate() under a feasibility-relaxation ladder (the launcher's
    historical retry loop, now shared with the measured-profile path):
    `mk_env(scale)` builds the CostEnv at a memory relaxation `scale`,
    starting at 1.0 and multiplying by `factor` until allocate() finds a
    feasible plan or `scale` exceeds `max_scale`. Returns (result, env,
    scale) — `result.feasible` is False only if even max_scale failed."""
    scale = 1.0
    env = mk_env(scale)
    r = allocate(env, n_layers, n_emp=n_emp, max_seg=max_seg,
                 balance=balance)
    while not r.feasible and scale < max_scale:
        scale *= factor
        env = mk_env(scale)
        r = allocate(env, n_layers, n_emp=n_emp, max_seg=max_seg,
                     balance=balance)
    return r, env, scale
