"""Fleet layer: multi-replica routing vs a single pipeline
(EXPERIMENTS.md §Fleet).

Three headline claims, exit-code enforced on the paper's 4-device
heterogeneous testbed (E3) over the discrete-event substrate:

  goodput   at an arrival rate that saturates ONE pipeline, a 4-replica
            fleet sustains >= 3x the single-replica aggregate goodput
            (tokens/s over the arrival->last-completion span) — the
            router spreads load instead of queueing it
  affinity  on shared-prefix traffic, prefix-affinity routing beats
            seeded-random routing on BOTH p50 TTFT and radix hit rate:
            same-template requests concentrate where the pages already
            are instead of warming four separate caches
  drain     draining a replica mid-stream drops zero in-flight requests:
            everything routed to it before the drain finishes, it
            receives nothing after, and it retires

  python benchmarks/bench_fleet.py
  python benchmarks/bench_fleet.py --scenario affinity --n-requests 64
  python benchmarks/bench_fleet.py --out benchmarks/baselines/fleet_sim.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))


def make_replica(args, index: int, *, prefix: bool):
    from repro.configs.registry import get_config
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.profiles import env_E1, env_E2, env_E3, mbps
    from repro.fleet import Replica
    from repro.serving import SchedulerConfig, SimBackend

    fleets = {"E1": env_E1, "E2": env_E2, "E3": env_E3}
    cfg = get_config(args.arch)
    w = Workload(cfg, mb=1, ctx=args.prompt_len, n_micro=args.slots)
    env = CostEnv(fleets[args.fleet](), mbps(args.bw_mbps), w)
    backend = SimBackend(env, n_slots=args.slots,
                         prompt_tokens=args.prompt_len)
    scfg = SchedulerConfig(kv_policy="paged", page_size=args.page_size,
                           prefix_cache=prefix)
    return Replica(index, backend, scfg)


def build_fleet(args, n: int, policy: str, *, prefix: bool):
    from repro.fleet import Fleet, RouterConfig
    reps = [make_replica(args, i, prefix=prefix) for i in range(n)]
    return Fleet(reps, config=RouterConfig(policy=policy, seed=args.seed))


def run_goodput(args) -> dict:
    """Same saturating poisson stream through 1 replica and through 4."""
    from repro.serving import cli_arrivals, requests_from_arrivals

    arrivals = cli_arrivals("poisson", args.goodput_requests,
                            seed=args.seed, prompt_len=args.prompt_len,
                            max_new_tokens=args.max_new,
                            rate_rps=args.rate_rps)
    reports = {}
    for n in (1, args.replicas):
        fleet = build_fleet(args, n, "prefix", prefix=False)
        res = fleet.run(requests_from_arrivals(arrivals, seed=args.seed))
        reports[n] = res.report(pattern="poisson",
                                backend=f"sim/fleet{n}").to_dict()
    single = reports[1]["aggregate"]
    multi = reports[args.replicas]["aggregate"]
    ratio = multi["throughput_tok_s"] / max(single["throughput_tok_s"],
                                            1e-12)
    return {"scenario": "goodput",
            "single": reports[1], "fleet": reports[args.replicas],
            "goodput_single_tok_s": single["throughput_tok_s"],
            "goodput_fleet_tok_s": multi["throughput_tok_s"],
            "goodput_ratio": ratio,
            "ttft_p99_single_s": single["ttft_p99_s"],
            "ttft_p99_fleet_s": multi["ttft_p99_s"]}


def run_affinity(args) -> dict:
    """Shared-prefix traffic: prefix-affinity routing vs seeded random."""
    from repro.serving import cli_arrivals, requests_from_arrivals

    arrivals = cli_arrivals("shared_prefix", args.n_requests,
                            seed=args.seed, prompt_len=args.prompt_len,
                            max_new_tokens=args.max_new,
                            rate_rps=args.affinity_rate_rps,
                            n_templates=args.n_templates,
                            prefix_len=args.prefix_len)
    reports = {}
    for policy in ("prefix", "random"):
        fleet = build_fleet(args, args.replicas, policy, prefix=True)
        res = fleet.run(requests_from_arrivals(arrivals, seed=args.seed))
        reports[policy] = res.report(pattern="shared_prefix",
                                     backend=f"sim/{policy}").to_dict()
    pa, ra = reports["prefix"]["aggregate"], reports["random"]["aggregate"]
    return {"scenario": "affinity",
            "prefix": reports["prefix"], "random": reports["random"],
            "ttft_p50_prefix_s": pa["ttft_p50_s"],
            "ttft_p50_random_s": ra["ttft_p50_s"],
            "hit_rate_prefix": pa["prefix_hit_rate"],
            "hit_rate_random": ra["prefix_hit_rate"]}


def run_drain(args) -> dict:
    """Drain one replica mid-stream; count its in-flight to completion."""
    from repro.serving import cli_arrivals, requests_from_arrivals

    arrivals = cli_arrivals("poisson", args.n_requests, seed=args.seed,
                            prompt_len=args.prompt_len,
                            max_new_tokens=args.max_new,
                            rate_rps=args.rate_rps)
    drain_at = arrivals[len(arrivals) // 2].time_s
    fleet = build_fleet(args, args.replicas, "prefix", prefix=False)
    victim = fleet.replicas[-1].name
    fleet.drain(victim, at_s=drain_at)
    res = fleet.run(requests_from_arrivals(arrivals, seed=args.seed))
    rep = res.report(pattern="poisson", backend="sim/drain")
    vrecs = res.per_replica[victim]
    dropped = [r for r in vrecs if not r.done]
    late = [r for r in vrecs if r.arrival_s > drain_at]
    mem = rep.membership[victim]
    return {"scenario": "drain", "report": rep.to_dict(),
            "victim": victim, "drain_at_s": drain_at,
            "victim_routed": mem["routed"],
            "victim_dropped": len(dropped),
            "victim_admits_after_drain": len(late),
            "victim_retired_s": mem["retired_s"],
            "fleet_done": sum(r.done for r in res.requests),
            "fleet_total": len(res.requests)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", choices=("goodput", "affinity", "drain",
                                           "all"), default="all")
    ap.add_argument("--arch", default="llama2-13b")
    ap.add_argument("--fleet", default="E3", choices=("E1", "E2", "E3"))
    ap.add_argument("--bw-mbps", type=float, default=200.0)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--n-requests", type=int, default=48)
    ap.add_argument("--goodput-requests", type=int, default=96,
                    help="stream length for the goodput scenario — long "
                         "enough that the drain tail (one replica "
                         "finishing last while others idle) amortizes")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--prefix-len", type=int, default=192,
                    help="shared template span (affinity scenario)")
    ap.add_argument("--n-templates", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--rate-rps", type=float, default=2.0,
                    help="poisson arrival rate — default saturates even "
                         "the 4-replica fleet (goodput/drain scenarios)")
    ap.add_argument("--affinity-rate-rps", type=float, default=1.0,
                    help="arrival rate for the affinity scenario — "
                         "moderate load, where routing quality (not raw "
                         "queueing) dominates TTFT")
    ap.add_argument("--page-size", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    results = []
    comparison = {}
    rc = 0
    if args.scenario in ("goodput", "all"):
        g = run_goodput(args)
        results.append(g)
        comparison["goodput_ratio"] = g["goodput_ratio"]
        print(f"# goodput: {args.replicas}-replica "
              f"{g['goodput_fleet_tok_s']:.2f} tok/s vs single "
              f"{g['goodput_single_tok_s']:.2f} tok/s "
              f"({g['goodput_ratio']:.2f}x); TTFT p99 "
              f"{g['ttft_p99_fleet_s']:.1f}s vs "
              f"{g['ttft_p99_single_s']:.1f}s", file=sys.stderr)
        if g["goodput_ratio"] < 3.0:
            print(f"# WARNING: {args.replicas}-replica goodput below 3x "
                  f"single-replica — router not spreading load",
                  file=sys.stderr)
            rc = 1
    if args.scenario in ("affinity", "all"):
        a = run_affinity(args)
        results.append(a)
        comparison["affinity"] = {
            "ttft_p50_prefix_s": a["ttft_p50_prefix_s"],
            "ttft_p50_random_s": a["ttft_p50_random_s"],
            "hit_rate_prefix": a["hit_rate_prefix"],
            "hit_rate_random": a["hit_rate_random"]}
        print(f"# affinity: TTFT p50 {a['ttft_p50_prefix_s']:.2f}s "
              f"(prefix) vs {a['ttft_p50_random_s']:.2f}s (random); "
              f"hit rate {a['hit_rate_prefix']:.2f} vs "
              f"{a['hit_rate_random']:.2f}", file=sys.stderr)
        if a["ttft_p50_prefix_s"] >= a["ttft_p50_random_s"]:
            print("# WARNING: prefix routing did not beat random on "
                  "p50 TTFT", file=sys.stderr)
            rc = 1
        if a["hit_rate_prefix"] <= a["hit_rate_random"]:
            print("# WARNING: prefix routing did not beat random on "
                  "radix hit rate", file=sys.stderr)
            rc = 1
    if args.scenario in ("drain", "all"):
        d = run_drain(args)
        results.append(d)
        comparison["drain"] = {
            "victim_routed": d["victim_routed"],
            "victim_dropped": d["victim_dropped"],
            "victim_admits_after_drain": d["victim_admits_after_drain"]}
        print(f"# drain: {d['victim']} had {d['victim_routed']} routed, "
              f"{d['victim_dropped']} dropped, "
              f"{d['victim_admits_after_drain']} admits after drain; "
              f"retired at {d['victim_retired_s']:.1f}s; fleet finished "
              f"{d['fleet_done']}/{d['fleet_total']}", file=sys.stderr)
        if d["victim_dropped"] or d["victim_admits_after_drain"]:
            print("# WARNING: drain dropped in-flight requests or kept "
                  "admitting", file=sys.stderr)
            rc = 1
        if d["victim_retired_s"] is None \
                or d["fleet_done"] != d["fleet_total"]:
            print("# WARNING: drain never completed or fleet shed "
                  "requests", file=sys.stderr)
            rc = 1

    from repro.serving.metrics import SCHEMA_VERSION
    payload = {"schema_version": SCHEMA_VERSION, "config": vars(args),
               "results": results, "comparison": comparison}
    text = json.dumps(payload, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return rc


def run():
    """benchmarks.run harness hook: fast sim-only smoke."""
    class _Row:
        def __init__(self, name, ms):
            self.name, self.ms = name, ms

        def csv(self):
            return f"fleet,{self.name},{self.ms:.1f},ok"

    rc = main(["--n-requests", "32", "--goodput-requests", "64",
               "--prompt-len", "128", "--prefix-len", "64",
               "--max-new", "8", "--rate-rps", "4.0"])
    if rc:
        raise SystemExit("bench_fleet smoke failed")
    return [_Row("goodput_affinity_drain", 0.0)]


if __name__ == "__main__":
    raise SystemExit(main())
