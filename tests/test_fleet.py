"""Fleet layer (DESIGN.md §16): router determinism, elastic drain/join,
exact metrics aggregation, the prefix-digest == radix-tree contract, the
resumable scheduler surface the fleet co-steps on, and the per-replica
trace namespacing the Chrome exporter renders as process groups."""
import json
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.core.cost_model import CostEnv, Workload
from repro.core.profiles import env_E3, mbps
from repro.fleet import (Fleet, FleetRouter, POLICIES, Replica,
                         RouterConfig)
from repro.kvcache import BlockTable, PagedKVConfig, PagePool
from repro.obs.exporters import to_chrome, validate_chrome
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import EVT_TRACK, Tracer, set_tracer
from repro.prefixcache import PrefixDigest, RadixPrefixCache
from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                           SimBackend, make_arrivals,
                           requests_from_arrivals)
from repro.serving.metrics import SCHEMA_VERSION, percentile


# ----------------------------------------------------------------------------
# rig: sim replicas over the E3 fleet (the serving tests' standard backend)
# ----------------------------------------------------------------------------
def _backend(slots=2, prompt=64):
    cfg = get_config("llama2-13b")
    w = Workload(cfg, mb=1, ctx=prompt, n_micro=slots)
    return SimBackend(CostEnv(env_E3(), mbps(200), w), n_slots=slots,
                      prompt_tokens=prompt)


def _replica(i, slots=2, prefix=False, page=16):
    scfg = SchedulerConfig(kv_policy="paged", page_size=page,
                           prefix_cache=True) if prefix \
        else SchedulerConfig()
    return Replica(i, _backend(slots), scfg)


def _fleet(n, policy, *, seed=0, slots=2, prefix=None):
    if prefix is None:
        prefix = policy == "prefix"
    reps = [_replica(i, slots=slots, prefix=prefix) for i in range(n)]
    return Fleet(reps, config=RouterConfig(policy=policy, seed=seed))


def _reqs(pattern, n, *, seed=0, **kw):
    return requests_from_arrivals(
        make_arrivals(pattern, n, seed=seed, **kw), vocab_size=4096)


def _partition(result):
    """name -> sorted rids, only replicas that served anything."""
    return {name: sorted(r.rid for r in recs)
            for name, recs in result.per_replica.items() if recs}


# ----------------------------------------------------------------------------
# routing: determinism, stickiness, spillover, error paths
# ----------------------------------------------------------------------------
@settings(max_examples=5, deadline=None)
@given(st.sampled_from(POLICIES), st.integers(min_value=0, max_value=999),
       st.integers(min_value=6, max_value=12))
def test_placement_deterministic_property(policy, seed, n):
    """Same stream + same fleet config => identical placement AND
    identical per-request timings, for every policy."""
    outs = []
    for _ in range(2):
        fleet = _fleet(3, policy, seed=seed)
        res = fleet.run(_reqs("shared_prefix", n, seed=seed, prompt_len=64,
                              prefix_len=48, n_templates=2,
                              max_new_tokens=4, rate_rps=2.0))
        outs.append((_partition(res),
                     {r.rid: (r.ttft_s, r.finish_s) for r in res.requests},
                     dict(fleet.router.stats)))
    assert outs[0] == outs[1]


def test_scored_policies_balance_under_load():
    """Load terms actually spread traffic: a scored 3-replica fleet under
    poisson load leaves no replica idle and no replica owning the stream."""
    fleet = _fleet(3, "sticky")
    res = fleet.run(_reqs("poisson", 18, prompt_len=64,
                          max_new_tokens=16, rate_rps=2.0))
    counts = {name: len(recs) for name, recs in res.per_replica.items()}
    assert sum(counts.values()) == 18
    assert all(c > 0 for c in counts.values())
    assert max(counts.values()) < 18


def test_sticky_sessions_never_split():
    """Multiturn sessions route to exactly one replica each under the
    sticky policy (hysteresis holds at moderate load), and every turn
    carries the session_id the router keyed on."""
    reqs = _reqs("multiturn", 9, prompt_len=32, max_new_tokens=4,
                 turns=3, rate_rps=0.3)
    assert all(r.session_id is not None for r in reqs)
    assert len({r.session_id for r in reqs}) == 3
    fleet = _fleet(2, "sticky")
    res = fleet.run(reqs)
    homes = {}
    for name, recs in res.per_replica.items():
        for r in recs:
            homes.setdefault(r.session_id, set()).add(name)
    assert all(len(v) == 1 for v in homes.values())
    assert fleet.router.stats["sticky_kept"] > 0
    assert fleet.router.stats["sticky_moved"] == 0


def test_prefix_policy_reuses_template_homes():
    """Shared-prefix traffic under the prefix policy: requests of the
    same template co-locate (optimistic digest makes even the second
    request stick before the first finishes), driving radix hits."""
    reqs = _reqs("shared_prefix", 12, prompt_len=96, prefix_len=64,
                 n_templates=2, max_new_tokens=4, rate_rps=1.0)
    fleet = _fleet(3, "prefix")
    res = fleet.run(reqs)
    assert fleet.router.stats["prefix_matched"] > 0
    rep = res.report(pattern="shared_prefix", backend="sim3")
    assert rep.aggregate.prefix_hit_rate > 0


def test_router_error_paths():
    with pytest.raises(ValueError):
        RouterConfig(policy="bogus")
    with pytest.raises(ValueError):
        Fleet([_replica(0), Replica(1, _backend(), name="r0")])
    fleet = _fleet(2, "roundrobin")
    with pytest.raises(KeyError):
        fleet.drain("nope")
    with pytest.raises(ValueError):
        fleet.join(_replica(0), at_s=1.0)       # name r0 already present
    # all replicas draining -> route() sheds instead of crashing
    fleet.drain("r0")
    fleet.drain("r1")
    res = fleet.run(_reqs("poisson", 3, prompt_len=32, max_new_tokens=2,
                          rate_rps=1.0))
    assert len(res.shed) == 3
    assert all(r.rejected for r in res.shed)
    assert fleet.router.stats["no_replica"] == 3


# ----------------------------------------------------------------------------
# elastic membership: drain / join
# ----------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=99),
       st.integers(min_value=9, max_value=15),
       st.floats(min_value=0.3, max_value=0.7))
def test_drain_property(seed, n, frac):
    """drain(r) at any mid-stream time => r takes ZERO admits at or after
    the drain, every request already routed to it finishes, and the
    replica retires once its last request drains."""
    reqs = _reqs("poisson", n, seed=seed, prompt_len=64, max_new_tokens=4,
                 rate_rps=2.0)
    drain_at = sorted(r.arrival_s for r in reqs)[int(frac * n)]
    fleet = _fleet(3, "roundrobin", seed=seed)
    fleet.drain("r2", at_s=drain_at)
    res = fleet.run(reqs)
    victim = res.per_replica["r2"]
    assert all(r.arrival_s < drain_at for r in victim)   # no late admits
    assert all(r.done and not r.rejected for r in victim)
    rep = fleet.replica("r2")
    assert not rep.live and rep.draining
    assert rep.retired_s is not None
    done = [r for r in res.requests if r.done]
    assert len(done) == n and not res.shed               # zero dropped


def test_join_receives_traffic_within_k_admits():
    """join(r) mid-stream: the empty newcomer's load advantage pulls
    traffic onto it within K admits of the join."""
    reqs = _reqs("poisson", 20, prompt_len=64, max_new_tokens=16,
                 rate_rps=2.0)
    t_join = sorted(r.arrival_s for r in reqs)[10]
    fleet = _fleet(2, "sticky")
    fleet.join(_replica(2), at_s=t_join)
    res = fleet.run(reqs)
    joiner = fleet.replica("r2")
    assert joiner.live and joiner.joined_s == t_join
    assert joiner.routed >= 1
    first = min(r.arrival_s for r in res.per_replica["r2"])
    k = sum(1 for r in reqs if t_join <= r.arrival_s < first)
    assert k <= 4                       # traffic within K=4 admits
    assert len([r for r in res.requests if r.done]) == 20


def test_drain_then_join_membership_in_report():
    reqs = _reqs("poisson", 12, prompt_len=64, max_new_tokens=16,
                 rate_rps=2.0)
    mid = sorted(r.arrival_s for r in reqs)[6]
    fleet = _fleet(2, "sticky")
    fleet.drain("r1", at_s=mid)
    fleet.join(_replica(2), at_s=mid)
    res = fleet.run(reqs)
    rep = res.report(pattern="poisson", backend="sim")
    assert rep.n_replicas == 3          # retired members still reported
    m = rep.membership
    assert m["r1"]["retired_s"] is not None and not m["r1"]["live"]
    assert m["r2"]["joined_s"] == mid and m["r2"]["routed"] >= 1
    assert sum(v["routed"] for v in m.values()) == 12
    # a drained replica's sessions/digest leave the router
    assert "r1" not in fleet.router._optimistic
    assert "r1" not in fleet.router._home.values()


# ----------------------------------------------------------------------------
# exact aggregation: MetricsRegistry.merge + FleetReport
# ----------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=1e3), min_size=1,
                max_size=40),
       st.lists(st.floats(min_value=0.0, max_value=1e3), min_size=0,
                max_size=40))
def test_merge_percentiles_equal_pooled_property(xs, ys):
    """merge() concatenates raw histogram samples, so merged percentiles
    equal percentiles over the pooled observations EXACTLY (nearest-rank,
    same convention as serving.metrics.percentile); counters sum and
    gauges take the max of value and peak."""
    a, b = MetricsRegistry(), MetricsRegistry()
    for v in xs:
        a.observe("lat", v)
        a.inc("tokens", v)
        a.set_gauge("peak_active", v)
    for v in ys:
        b.observe("lat", v)
        b.inc("tokens", v)
        b.set_gauge("peak_active", v)
    merged = MetricsRegistry().merge(a).merge(b)
    pooled = xs + ys
    for p in (0, 50, 90, 99, 100):
        got = merged.histogram("lat").percentile(p)
        want = percentile(pooled, p)
        assert got == want or (math.isnan(got) and math.isnan(want))
    assert merged.counter("tokens").value == pytest.approx(sum(pooled))
    assert merged.gauge("peak_active").peak == max(pooled)


def test_merge_returns_self_and_chains():
    a = MetricsRegistry()
    a.observe("h", 1.0)
    b = MetricsRegistry()
    b.observe("h", 2.0)
    out = MetricsRegistry().merge(a).merge(b)
    assert out.histogram("h").values == [1.0, 2.0]


def test_fleet_report_aggregate_is_exact():
    """The aggregate ServingReport comes from the POOLED request records
    (not averaged replica percentiles): counts add up, percentiles equal
    nearest-rank over the union, and the JSON round-trips with the
    current schema."""
    fleet = _fleet(3, "prefix")
    res = fleet.run(_reqs("shared_prefix", 12, prompt_len=64,
                          prefix_len=48, n_templates=2, max_new_tokens=4,
                          rate_rps=2.0))
    rep = res.report(pattern="shared_prefix", backend="sim3")
    assert rep.schema_version == SCHEMA_VERSION
    assert rep.aggregate.n_requests == 12
    assert sum(r.n_requests for r in rep.replicas.values()) == 12
    ttfts = [r.ttft_s for r in res.requests if r.ttft_s is not None]
    assert rep.aggregate.ttft_p50_s == pytest.approx(percentile(ttfts, 50))
    d = json.loads(rep.to_json())
    assert d["schema_version"] == SCHEMA_VERSION
    assert set(d["replicas"]) == {"r0", "r1", "r2"}
    assert d["router"]["routed"] == 12


# ----------------------------------------------------------------------------
# prefix digest: the router-side radix summary is exact
# ----------------------------------------------------------------------------
def _pool(ps=4, dev=32, host=8):
    return PagePool(PagedKVConfig(page_size=ps, device_pages=dev,
                                  host_pages=host, page_bytes=8.0))


def _insert(pool, tree, toks):
    t = BlockTable(pool.page_size)
    pool.extend_table(t, len(toks))
    tree.insert(toks, t.pages)


def test_digest_matches_tree_match():
    pool = _pool()
    tree = RadixPrefixCache(pool)
    base = list(range(100, 116))                 # 16 toks = 4 pages
    _insert(pool, tree, base)
    probes = [base, base[:10], base[:7] + [999], [1, 2, 3],
              base + [7, 8, 9, 10, 11]]
    d = tree.digest()
    for probe in probes:
        assert d.match_tokens(probe) == tree.match(probe)[1]


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=50), min_size=4,
                max_size=32),
       st.integers(min_value=0, max_value=32),
       st.integers(min_value=0, max_value=50))
def test_digest_matches_tree_property(base, cut, tail_tok):
    """Any inserted chain, any probe that diverges anywhere: the chain-
    hash digest and the radix tree agree on matched token count."""
    pool = _pool()
    tree = RadixPrefixCache(pool)
    _insert(pool, tree, base)
    probe = base[:min(cut, len(base))] + [tail_tok] * 3
    d = tree.digest()
    assert d.match_tokens(probe) == tree.match(probe)[1]
    assert d.match_tokens(base) == tree.match(base)[1]


def test_digest_tracks_eviction():
    """Dropping tree nodes shrinks the digest: no stale router affinity
    toward pages the cache no longer holds."""
    pool = _pool()
    tree = RadixPrefixCache(pool)
    _insert(pool, tree, list(range(16)))
    d0 = tree.digest()
    assert len(d0) == tree.n_pages > 0
    tree.release_all()
    assert len(tree.digest()) == 0
    # the old snapshot still matches (it is a copy), the fresh one doesn't
    assert d0.match_tokens(list(range(16))) == 16
    assert tree.digest().match_tokens(list(range(16))) == 0


def test_digest_standalone_optimistic():
    """PrefixDigest without a tree (the router's optimistic digests):
    add_prompt with max_pages caps exactly like radix admission."""
    d = PrefixDigest(page_size=4)
    toks = list(range(12))
    d.add_prompt(toks, max_pages=2)              # 8 of 12 tokens
    assert d.match_tokens(toks) == 8
    assert d.match_tokens(toks[:4]) == 4
    assert d.match_tokens([99] + toks) == 0


# ----------------------------------------------------------------------------
# resumable scheduler surface (what the fleet co-steps on)
# ----------------------------------------------------------------------------
def test_stepwise_scheduler_equals_serve():
    """begin/step/finish_run produces bit-identical results to the
    monolithic serve() loop on a fresh backend."""
    kw = dict(prompt_len=64, max_new_tokens=4, rate_rps=2.0)
    a = ContinuousBatchingScheduler(_backend(), SchedulerConfig())
    done_a = a.serve(_reqs("poisson", 8, **kw))
    b = ContinuousBatchingScheduler(_backend(), SchedulerConfig())
    b.begin(_reqs("poisson", 8, **kw))
    steps = 0
    while b.step():
        steps += 1
        assert steps < 10_000           # the loop terminates
    done_b = b.finish_run()

    def key(rs):
        return sorted((r.rid, r.ttft_s, r.finish_s) for r in rs)
    assert key(done_a) == key(done_b)


def test_submit_mid_run_and_load_signals():
    sched = ContinuousBatchingScheduler(_backend(slots=2),
                                        SchedulerConfig())
    reqs = _reqs("poisson", 8, prompt_len=64, max_new_tokens=4,
                 rate_rps=4.0)
    sched.begin(reqs[:4])
    assert sched.outstanding == 4 and sched.next_pending_s is not None
    for _ in range(3):
        sched.step()
    for r in reqs[4:]:                  # late submissions keep time order
        sched.submit(r)
    while sched.step():
        pass
    assert not sched.has_live_work and sched.next_pending_s is None
    assert sched.queue_depth == 0 and sched.in_flight == 0
    done = sched.finish_run()
    assert len(done) == 8 and all(r.done for r in done)


# ----------------------------------------------------------------------------
# observability: per-replica trace namespace -> Perfetto process groups
# ----------------------------------------------------------------------------
def test_tracer_namespace_rewrites_tracks():
    tr = Tracer(clock=lambda: 0.0, namespace="r2")
    tr.instant("x")                               # default track "sched"
    tr.complete("y", ts=0.0, dur=1.0, track="req:5")
    assert [e[EVT_TRACK] for e in tr.events()] == ["r2:sched", "r2:req:5"]
    tr.namespace = None                           # the fleet restores it
    tr.instant("z", track="router")
    assert tr.events()[-1][EVT_TRACK] == "router"


def test_chrome_export_groups_replicas_into_processes():
    tr = Tracer(clock=lambda: 0.0)
    tr.instant("fleet.route", track="router")
    for ns in ("r0", "r1"):
        tr.namespace = ns
        tr.instant("sched.admit")
        tr.complete("req.decode", ts=0.0, dur=0.5, track="req:3")
    tr.namespace = None
    doc = to_chrome(tr)
    assert validate_chrome(doc) == []
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("name") == "process_name"}
    assert {"replica r0", "replica r1", "router"} <= names
    pid_of = {e["args"]["name"]: e["pid"] for e in doc["traceEvents"]
              if e.get("name") == "thread_name"}
    assert pid_of["r0:sched"] == pid_of["r0:req:3"]       # same process
    assert pid_of["r0:sched"] != pid_of["r1:sched"]       # per replica
    assert pid_of["router"] not in (pid_of["r0:sched"], pid_of["r1:sched"])


def test_fleet_run_emits_namespaced_trace():
    tr = Tracer(clock=lambda: 0.0)
    set_tracer(tr)
    try:
        fleet = _fleet(2, "sticky")
        fleet.drain("r1", at_s=2.0)
        fleet.run(_reqs("poisson", 6, prompt_len=32, max_new_tokens=2,
                        rate_rps=2.0))
    finally:
        set_tracer(None)
    tracks = {e[EVT_TRACK] for e in tr.events()}
    names = {e[0] for e in tr.events()}
    assert any(t.startswith("r0:") for t in tracks)
    assert "router" in tracks
    assert {"fleet.route", "fleet.drain", "fleet.drained"} <= names
    assert tr.namespace is None                   # restored after run
    assert validate_chrome(to_chrome(tr)) == []


# ----------------------------------------------------------------------------
# session ids: traffic -> Request -> router key
# ----------------------------------------------------------------------------
def test_multiturn_session_ids_stable():
    evs = make_arrivals("multiturn", 12, seed=3, prompt_len=32,
                        max_new_tokens=4, turns=3, rate_rps=0.5)
    assert all(ev.session_id is not None for ev in evs)
    assert len({ev.session_id for ev in evs}) == 4    # ceil(12/3) sessions
    reqs = requests_from_arrivals(evs, vocab_size=4096)
    assert [r.session_id for r in reqs] == [ev.session_id for ev in evs]
    # non-session patterns stay unkeyed
    assert all(r.session_id is None
               for r in _reqs("poisson", 4, prompt_len=16,
                              max_new_tokens=2, rate_rps=1.0))


def test_router_scores_are_pure():
    """score() has no side effects: calling it repeatedly (or in any
    order) never changes placement — the determinism property's local
    form."""
    router = FleetRouter(RouterConfig(policy="prefix"))
    reps = [_replica(i, prefix=True) for i in range(3)]
    req = _reqs("shared_prefix", 1, prompt_len=64, prefix_len=48,
                n_templates=1, max_new_tokens=2, rate_rps=1.0)[0]
    before = [router.score(req, r) for r in reps]
    for _ in range(3):
        assert [router.score(req, r) for r in reps] == before
    pick = router.route(req, reps)
    assert pick.name == "r0"            # equal scores -> lowest index
