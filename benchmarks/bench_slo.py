"""Online SLO engine benchmark: sketch accuracy, burn-rate shedding,
critical-path conservation, and observability overhead
(EXPERIMENTS.md §SLO, DESIGN.md §17).

Four exit-code-enforced properties:

  sketch        reservoir percentiles over pooled fleet samples (4
                bounded registries merged) stay within the documented
                rank-error bound eps = 2/sqrt(capacity) of the exact
                nearest-rank answer.
  overload      a 2-replica fleet with one degraded replica (10x slower
                compute/memory/load) under tight TPOT targets: the
                degraded replica fires a burn-rate breach (slo.breach
                tracer event), its health drops below 1, and
                health-weighted routing sheds load off it — it receives
                strictly fewer requests than the same run scored with
                w_health = 0.
  conservation  critical-path buckets of every traced pipeline round sum
                to the measured round time within 1% (memory-constrained
                70B run, so the weight-stall bucket is actually
                exercised).
  overhead      tracer + bounded histograms + SLO engine all on moves
                the sim's *virtual* ms/token by < 5% vs everything off
                (the bench_obs convention: observability must not
                perturb the discrete-event clock).

  python benchmarks/bench_slo.py
  python benchmarks/bench_slo.py --scenario overload
  python benchmarks/bench_slo.py --out benchmarks/baselines/slo_sim.json
"""
from __future__ import annotations

import argparse
import bisect
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

OVERHEAD_TOL = 0.05              # 5% virtual ms/token budget
CONSERVATION_TOL = 0.01          # buckets must sum to round time +-1%


# ----------------------------------------------------------------------------
# scenario: sketch accuracy on pooled fleet samples
# ----------------------------------------------------------------------------
def run_sketch(args) -> dict:
    import numpy as np

    from repro.obs.metrics import MetricsRegistry
    from repro.obs.sketch import reservoir_rank_error

    rng = np.random.default_rng(args.seed)
    cap = args.sketch_capacity
    pooled: list = []
    merged = MetricsRegistry(hist_capacity=cap, seed=99)
    for i in range(4):
        # each replica sees a different latency regime, so the pooled
        # population is multi-modal — the case naive per-replica
        # percentile averaging gets wrong and reservoir merging must not
        m = MetricsRegistry(hist_capacity=cap, seed=i)
        vals = rng.lognormal(mean=-1.0 + 0.5 * i, sigma=0.6,
                             size=args.sketch_samples)
        for v in vals:
            m.observe("lat", float(v))
        pooled.extend(vals.tolist())
        merged.merge(m)
    xs = sorted(pooled)
    n = len(xs)
    eps = reservoir_rank_error(cap)
    out = {"scenario": "sketch", "capacity": cap, "pooled_samples": n,
           "eps_bound": eps, "percentiles": {}}
    worst = 0.0
    for p in (50, 90, 99):
        est = merged.histogram("lat").percentile(p)
        rank_err = abs(bisect.bisect_left(xs, est) / n - p / 100.0)
        out["percentiles"][f"p{p}"] = {"estimate": est,
                                       "rank_error": rank_err}
        worst = max(worst, rank_err)
    out["worst_rank_error"] = worst
    out["ok"] = worst <= eps
    return out


# ----------------------------------------------------------------------------
# scenario: induced overload -> breach -> health-weighted shedding
# ----------------------------------------------------------------------------
def _mk_backend(args, slow: bool):
    from repro.configs.registry import get_config
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.profiles import env_E3, mbps
    from repro.serving import SimBackend

    cfg = get_config(args.arch)
    w = Workload(cfg, mb=1, ctx=args.prompt_len, n_micro=args.slots)
    devs = env_E3()
    if slow:
        devs = [dataclasses.replace(d, flops=d.flops / 10.0,
                                    mem_bw=d.mem_bw / 10.0,
                                    load_bw=d.load_bw / 10.0)
                for d in devs]
    env = CostEnv(devs, mbps(20.0 if slow else args.bw_mbps), w)
    return SimBackend(env, n_slots=args.slots,
                      prompt_tokens=args.prompt_len)


def _overload_targets():
    from repro.obs.slo import SLOTarget
    # tight TPOT objective with short windows so a benchmark-length run
    # exercises breach promptly: the degraded replica's ~4 s/token blows
    # the 1 s threshold on every finish (burn 2.0 >= threshold 1.5)
    return [SLOTarget("tpot_p50", "tpot", threshold_s=1.0, target=0.5,
                      fast_window_s=10.0, slow_window_s=30.0,
                      burn_threshold=1.5)]


def _run_overload_once(args, w_health: float) -> dict:
    from repro.fleet import Fleet, Replica, RouterConfig
    from repro.obs.slo import SLOEngine
    from repro.obs.trace import tracing
    from repro.serving import (SchedulerConfig, cli_arrivals,
                               requests_from_arrivals)

    reps = [Replica(0, _mk_backend(args, slow=False), SchedulerConfig()),
            Replica(1, _mk_backend(args, slow=True), SchedulerConfig())]
    for r in reps:
        r.sched.attach_slo(SLOEngine(_overload_targets()))
    fleet = Fleet(reps, config=RouterConfig(policy="prefix",
                                            seed=args.seed,
                                            w_health=w_health))
    arrivals = cli_arrivals("poisson", args.overload_requests,
                            seed=args.seed, prompt_len=args.prompt_len,
                            max_new_tokens=4, rate_rps=2.0)
    with tracing(clock=reps[0].now) as tr:
        res = fleet.run(requests_from_arrivals(arrivals, seed=args.seed))
        breach_ts = [e[2] for e in tr.events() if e[0] == "slo.breach"]
    slow_rep = res.replicas[1]
    snap = slow_rep.sched.slo.snapshot(slow_rep.now())
    fast_snap = res.replicas[0].sched.slo.snapshot(res.replicas[0].now())
    return {"w_health": w_health,
            "routed": {r.name: r.routed for r in res.replicas},
            "slow_breaches": snap["targets"]["tpot_p50"]["breaches"],
            "fast_breaches": fast_snap["targets"]["tpot_p50"]["breaches"],
            "slow_health": slow_rep.health(),
            "first_breach_s": min(breach_ts) if breach_ts else None}


def run_overload(args) -> dict:
    shed = _run_overload_once(args, w_health=2.0)
    ctrl = _run_overload_once(args, w_health=0.0)
    slow_on = shed["routed"]["r1"]
    slow_off = ctrl["routed"]["r1"]
    return {"scenario": "overload", "health_on": shed, "health_off": ctrl,
            "slow_routed_health_on": slow_on,
            "slow_routed_health_off": slow_off,
            "ok": (shed["slow_breaches"] >= 1
                   and shed["first_breach_s"] is not None
                   and shed["slow_health"] < 1.0
                   and slow_on < slow_off)}


# ----------------------------------------------------------------------------
# scenario: critical-path conservation on a traced stall-heavy run
# ----------------------------------------------------------------------------
def run_conservation(args) -> dict:
    from repro.configs.registry import get_config
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.profiles import env_lowmem, mbps
    from repro.obs import critical_path as cp
    from repro.obs.trace import tracing
    from repro.serving import (ContinuousBatchingScheduler,
                               SchedulerConfig, SimBackend, cli_arrivals,
                               requests_from_arrivals)

    # memory-constrained 70B: weights stream every round, so the
    # weight-stall bucket is nonzero and conservation is tested against
    # a timeline with every bucket class present
    cfg = get_config("llama3.3-70b")
    w = Workload(cfg, mb=1, ctx=512, n_micro=2)
    env = CostEnv(env_lowmem(1), mbps(args.bw_mbps), w)
    backend = SimBackend(env, n_slots=2, prompt_tokens=512)
    arrivals = cli_arrivals("bursty", 4, seed=args.seed, prompt_len=512,
                            max_new_tokens=4, gap_s=5.0, burst_size=2)
    with tracing(capacity=1 << 18) as tr:
        sched = ContinuousBatchingScheduler(backend, SchedulerConfig())
        sched.serve(requests_from_arrivals(arrivals, seed=args.seed))
        rep = cp.analyze(tr.events())
    err = rep.conservation_error()
    fr = rep.fractions
    return {"scenario": "conservation", "n_rounds": len(rep.rounds),
            "round_time_s": rep.round_time_s,
            "fractions": fr, "bottlenecks": rep.bottlenecks,
            "conservation_error": err,
            "ok": (len(rep.rounds) > 0
                   and err < CONSERVATION_TOL
                   and fr.get("weight_stall", 0.0) > 0.0
                   and fr.get("compute", 0.0) > 0.0)}


# ----------------------------------------------------------------------------
# scenario: observability overhead on the virtual clock
# ----------------------------------------------------------------------------
def _serve_ms_per_token(args, observed: bool) -> float:
    from repro.obs.slo import SLOEngine
    from repro.obs.trace import tracing
    from repro.serving import (ContinuousBatchingScheduler,
                               SchedulerConfig, cli_arrivals,
                               requests_from_arrivals, summarize)

    backend = _mk_backend(args, slow=False)
    arrivals = cli_arrivals("bursty", 8, seed=args.seed,
                            prompt_len=args.prompt_len, max_new_tokens=16,
                            gap_s=4.0, burst_size=args.slots)
    reqs = requests_from_arrivals(arrivals, seed=args.seed)
    scfg = SchedulerConfig(hist_capacity=1024) if observed \
        else SchedulerConfig()
    if observed:
        with tracing(capacity=1 << 16):
            sched = ContinuousBatchingScheduler(backend, scfg)
            sched.attach_slo(SLOEngine())      # default (loose) targets
            done = sched.serve(reqs)
    else:
        sched = ContinuousBatchingScheduler(backend, scfg)
        done = sched.serve(reqs)
    return summarize(done, pattern="bursty", backend="sim",
                     stats=sched.stats).ms_per_token


def run_overhead(args) -> dict:
    base = _serve_ms_per_token(args, observed=False)
    full = _serve_ms_per_token(args, observed=True)
    rel = abs(full - base) / max(base, 1e-12)
    return {"scenario": "overhead", "ms_per_token_off": base,
            "ms_per_token_on": full, "rel_delta": rel,
            "budget": OVERHEAD_TOL, "ok": rel < OVERHEAD_TOL}


# ----------------------------------------------------------------------------
SCENARIOS = {"sketch": run_sketch, "overload": run_overload,
             "conservation": run_conservation, "overhead": run_overhead}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", default="all",
                    choices=tuple(SCENARIOS) + ("all",))
    ap.add_argument("--arch", default="llama2-13b")
    ap.add_argument("--bw-mbps", type=float, default=200.0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--overload-requests", type=int, default=120)
    ap.add_argument("--sketch-capacity", type=int, default=1024)
    ap.add_argument("--sketch-samples", type=int, default=20000,
                    help="per-replica sample count (4 replicas pooled)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    names = list(SCENARIOS) if args.scenario == "all" else [args.scenario]
    results = []
    rc = 0
    for name in names:
        r = SCENARIOS[name](args)
        results.append(r)
        if name == "sketch":
            print(f"# sketch: worst rank error "
                  f"{r['worst_rank_error']:.4f} vs bound "
                  f"{r['eps_bound']:.4f} over {r['pooled_samples']} "
                  f"pooled samples", file=sys.stderr)
        elif name == "overload":
            print(f"# overload: slow replica breached "
                  f"{r['health_on']['slow_breaches']}x at "
                  f"t={r['health_on']['first_breach_s']:.1f}s, health "
                  f"{r['health_on']['slow_health']:.2f}; routed "
                  f"{r['slow_routed_health_on']} (health-weighted) vs "
                  f"{r['slow_routed_health_off']} (w_health=0)",
                  file=sys.stderr)
        elif name == "conservation":
            fr = r["fractions"]
            print(f"# conservation: {r['n_rounds']} rounds, max error "
                  f"{r['conservation_error']:.2e}; compute "
                  f"{fr['compute']:.0%} stall {fr['weight_stall']:.0%} "
                  f"hop {fr['act_hop']:.0%} bubble {fr['bubble']:.0%}",
                  file=sys.stderr)
        elif name == "overhead":
            print(f"# overhead: ms/token off={r['ms_per_token_off']:.3f} "
                  f"on={r['ms_per_token_on']:.3f} (rel "
                  f"{r['rel_delta'] * 100:.2f}%, budget "
                  f"{r['budget'] * 100:.0f}%)", file=sys.stderr)
        if not r["ok"]:
            print(f"# WARNING: scenario {name} failed its enforcement",
                  file=sys.stderr)
            rc = 1

    from repro.serving.metrics import SCHEMA_VERSION
    payload = {"schema_version": SCHEMA_VERSION, "config": vars(args),
               "results": results}
    text = json.dumps(payload, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return rc


def run():
    """benchmarks.run harness hook: the full enforcement, sim-only."""
    class _Row:
        def __init__(self, name):
            self.name = name

        def csv(self):
            return f"slo,{self.name},0.0,ok"

    rc = main(["--overload-requests", "80", "--sketch-samples", "8000"])
    if rc:
        raise SystemExit("bench_slo enforcement failed")
    return [_Row("sketch_overload_conservation_overhead")]


if __name__ == "__main__":
    raise SystemExit(main())
