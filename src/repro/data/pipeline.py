"""Synthetic corpus + packing pipeline.

Offline training data for the examples and the train_4k input shape. The
"corpus" is a deterministic markov-ish token stream with local structure
(n-gram regularities) so a ~100M model's loss visibly decreases — enough to
demonstrate the training stack end-to-end without shipping a dataset.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    vocab_size: int
    seed: int = 0
    order: int = 1              # markov order (1 = bigram: fast to learn,
                                # right for smoke tests; raise for harder)
    branch: int = 8             # candidates per context
    zipf_a: float = 2.0         # candidate skew (higher = more predictable)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._table = rng.integers(
            0, self.vocab_size, size=(4096, self.branch)).astype(np.int32)
        self._mix = rng.integers(1, 2 ** 31 - 1, size=self.order,
                                 dtype=np.int64)

    def stream(self, seed: int = 0) -> Iterator[int]:
        rng = np.random.default_rng(seed + 1)
        ctx = [int(rng.integers(self.vocab_size))
               for _ in range(self.order)]
        while True:
            h = 0
            for c, m in zip(ctx, self._mix):
                h = (h * 1315423911 + c * int(m)) % 4096
            # zipf-ish pick within the context's candidate row
            r = min(int(rng.zipf(self.zipf_a)) - 1, self.branch - 1)
            tok = int(self._table[h, r])
            yield tok
            ctx = ctx[1:] + [tok]


@dataclasses.dataclass
class PackedBatches:
    """Packs a token stream into (tokens, labels, mask) batches.

    Documents are delimited every `doc_len` tokens with a BOS reset (id 0);
    labels are next-token; mask zeroes the cross-document boundary.
    """
    corpus: SyntheticCorpus
    batch: int
    seq_len: int
    doc_len: int = 1024

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        streams = [self.corpus.stream(seed=i) for i in range(self.batch)]
        pos = [0] * self.batch
        while True:
            toks = np.zeros((self.batch, self.seq_len + 1), np.int32)
            mask = np.ones((self.batch, self.seq_len), np.float32)
            for b, s in enumerate(streams):
                for t in range(self.seq_len + 1):
                    if pos[b] % self.doc_len == 0:
                        toks[b, t] = 0                     # BOS
                        if 0 < t <= self.seq_len:
                            mask[b, t - 1] = 0.0
                    else:
                        toks[b, t] = next(s)
                    pos[b] += 1
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                   "mask": mask}


def make_batches(vocab_size: int, batch: int, seq_len: int, *,
                 seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    return iter(PackedBatches(SyntheticCorpus(vocab_size, seed),
                              batch, seq_len))
