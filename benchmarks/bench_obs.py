"""Observability smoke: flight-recorder overhead + export validity
(DESIGN.md §15, EXPERIMENTS.md §Tracing).

Runs the same sim serving workload twice — tracer off, tracer on — and
enforces the two properties the tracing subsystem promises:

  1. zero-cost semantics: the simulator's *virtual* ms/token is computed
     on the discrete-event clock, which the tracer must never perturb —
     the traced run's ms/token must stay within 5% of the untraced run
     (in practice they are bit-identical; 5% leaves room for future
     instrumentation that legitimately consults the clock). Wall-clock
     delta is reported informationally — it measures host speed, not the
     recorder.
  2. export validity: the emitted file is Chrome trace-event JSON that
     Perfetto will load (schema-checked), and carries the core lifecycle
     vocabulary (req.span / req.queue / step) a trace without which is
     useless.

Exit-code enforced so CI catches a tracer that slows the sim or an
exporter that drifts off the Chrome schema:

  python benchmarks/bench_obs.py
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

OVERHEAD_TOL = 0.05              # 5% virtual ms/token budget


def run_once(trace_out=None, out_json=None):
    import bench_serving
    argv = ["--pattern", "bursty", "--backend", "sim",
            "--n-requests", "8", "--max-new", "16",
            "--kv-policy", "paged", "--out", out_json]
    if trace_out:
        argv += ["--trace", trace_out]
    t0 = time.perf_counter()
    rc = bench_serving.main(argv)
    wall = time.perf_counter() - t0
    assert rc == 0, f"bench_serving exited {rc}"
    with open(out_json) as f:
        return json.load(f), wall


def main() -> int:
    from repro.obs.exporters import validate_chrome_file

    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    trace_path = os.path.join(tmp, "trace.json")
    off, wall_off = run_once(out_json=os.path.join(tmp, "off.json"))
    on, wall_on = run_once(trace_out=trace_path,
                           out_json=os.path.join(tmp, "on.json"))

    ok = True

    # 1. overhead on the virtual clock
    base, traced = off["ms_per_token"], on["ms_per_token"]
    rel = abs(traced - base) / max(base, 1e-12)
    print(f"ms/token: off={base:.3f} on={traced:.3f} "
          f"(rel delta {rel * 100:.2f}%, budget {OVERHEAD_TOL * 100:.0f}%)")
    print(f"# wall-clock (informational): off={wall_off:.2f}s "
          f"on={wall_on:.2f}s", file=sys.stderr)
    if rel > OVERHEAD_TOL:
        print(f"FAIL: tracer perturbs the sim clock by {rel * 100:.2f}%",
              file=sys.stderr)
        ok = False

    # 2. the export is a valid, non-trivial Chrome trace
    problems = validate_chrome_file(trace_path)
    if problems:
        print(f"FAIL: chrome validation: {problems}", file=sys.stderr)
        ok = False
    else:
        print(f"chrome schema: OK ({trace_path})")
    with open(trace_path) as f:
        names = {e["name"] for e in json.load(f)["traceEvents"]}
    for required in ("req.span", "req.queue", "step"):
        if required not in names:
            print(f"FAIL: trace missing lifecycle event {required!r}",
                  file=sys.stderr)
            ok = False
    if ok:
        print(f"events: {len(names)} distinct names, lifecycle present")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
