"""Flash-decode GQA attention for TPU (Pallas).

One query token per sequence attends to a long KV cache. This is LIME's
per-autoregressive-step compute hot spot: the op is memory-bound (read the
whole cache, O(1) FLOPs per byte), so the kernel's job is to stream K/V
through VMEM exactly once at full HBM bandwidth while the online softmax
state stays in scratch.

Layout (arranged by ops.py): q (B, KV, G, dh) — the G = H/KV query heads of
one KV group form the MXU's M dimension; k/v (B, KV, S_c, dh); pos_ids
(1, S_c) int32. Grid (B, KV, n_kv_blocks); the kv-block dimension is
sequential, carrying (m, l, acc) scratch like the prefill kernel. Slot
validity (ring buffers, empty slots, sliding window) is computed from
pos_ids against the [pos, window] scalar-prefetch operands, so the same
kernel serves contiguous caches, gemma3 ring buffers, and hymba sliding
windows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e30


def _decode_kernel(scalars_ref,                   # SMEM: [pos, window]
                   q_ref, k_ref, v_ref, ids_ref,  # VMEM blocks
                   o_ref,                         # VMEM out
                   m_ref, l_ref, acc_ref,         # VMEM scratch
                   *, dh_real: int, block_k: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)           # (block_k, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (dh_real ** -0.5)                     # (G, block_k)

    pos = scalars_ref[0]
    window = scalars_ref[1]
    ids = ids_ref[0]                              # (block_k,) int32
    valid = (ids >= 0) & (ids <= pos) & ((pos - ids) < window)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, pos_ids, pos, window, *, dh_real: int,
                            block_k: int = 512, interpret: bool = False):
    """q: (B, KV, G, dh); k, v: (B, KV, S_c, dh); pos_ids: (1, S_c) int32;
    pos, window: int32 scalars. S_c % block_k == 0, dh % 128 == 0.
    Returns (B, KV, G, dh)."""
    B, KV, G, dh = q.shape
    S_c = k.shape[2]
    block_k = min(block_k, S_c)
    grid = (B, KV, S_c // block_k)
    scalars = jnp.stack([jnp.asarray(pos, jnp.int32),
                         jnp.asarray(window, jnp.int32)])

    kernel = functools.partial(_decode_kernel, dh_real=dh_real,
                               block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, dh),
                             lambda b, h, ik, sc: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, dh),
                             lambda b, h, ik, sc: (b, h, ik, 0)),
                pl.BlockSpec((1, 1, block_k, dh),
                             lambda b, h, ik, sc: (b, h, ik, 0)),
                pl.BlockSpec((1, block_k),
                             lambda b, h, ik, sc: (0, ik)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, dh),
                                   lambda b, h, ik, sc: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, dh), q.dtype),
        interpret=interpret,
    )(scalars, q, k, v, pos_ids)
