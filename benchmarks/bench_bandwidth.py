"""Paper Fig. 18: latency under randomly varying bandwidth (50-250 Mbps,
re-drawn every ~50 tokens) on Qwen3-32B."""
import random

from benchmarks.common import run_scenario, speedup_table
from repro.configs.registry import get_config
from repro.core.profiles import env_E2, mbps


def schedule(tok: int) -> float:
    rnd = random.Random(tok // 50)          # piecewise-constant, seeded
    return mbps(rnd.uniform(50, 250))


def run():
    cfg = get_config("qwen3-32b")
    rows = []
    for pattern, nm in (("sporadic", 1), ("bursty", 3)):
        sc = f"varbw/{pattern}"
        rows.extend(run_scenario(sc, env_E2(), cfg, bw_mbps=150,
                                 pattern=pattern, n_micro=nm,
                                 bandwidth_schedule=schedule))
    for sc, t in speedup_table(rows).items():
        lime = next(r for r in rows
                    if r.scenario == sc and r.method == "LIME")
        print(f"{sc}: LIME {lime.ms_per_token:.0f} ms/tok | "
              + " ".join(f"{m}={v}" for m, v in t.items() if m != "LIME"))
    return rows


if __name__ == "__main__":
    run()
