"""Runtime registry for measured kernel block-size configs (DESIGN.md §18).

Every Pallas wrapper in this package historically hardcoded its block
sizes (``block_k=512``, ``block_t=256``) — numbers nobody ever swept.
The autotuner (``repro.tune.sweep``) times real candidates per device
kind and shape bucket and persists the winners; this module is the
*consultation point*: wrappers now default their block argument to
``None``, and ``resolve(...)`` answers with the tuned value when a table
is installed, or the historical default when none is — so behaviour is
bit-identical to the pre-autotune repo until a sweep has actually run.

Layering: ``repro.kernels`` must not depend on ``repro.tune`` (the tuner
imports the kernels it sweeps), so the table lives here as plain data —
``{kernel: {bucket: {param: value}}}`` — and ``repro.tune.cache`` only
*fills* it.

Shape bucketing: tuned configs are keyed by the power-of-two bucket of
the blocked axis (KV span for attention, time for the scans) and the
lane-padded head dim — close shapes share a winner, and the key is
stable across runs/processes (tested in test_tune.py).

Install-before-trace: jit caches key on the *resolved* static block
values only through the wrapper's ``None`` sentinel, so a table
installed after a shape was already traced does not retrace it. The
launchers install the table at startup, before any model code runs; the
sweep itself always passes explicit block values.
"""
from __future__ import annotations

from typing import Dict, Optional

# historical hardcoded defaults, one row per sweepable kernel entry point
DEFAULTS: Dict[str, Dict[str, int]] = {
    "flash_attention": {"block_q": 128, "block_k": 512},
    "decode_attention": {"block_k": 512},
    "mq_decode_attention": {"block_k": 512},
    "paged_decode_attention": {"page_size": 64},   # pool-level knob
    "mq_paged_decode_attention": {"page_size": 64},
    "rwkv6_scan": {"block_t": 256},
    "ssm_scan": {"block_t": 256},
}

_table: Optional[Dict[str, Dict[str, Dict[str, int]]]] = None


def _pow2_at_least(n: int, floor: int = 8) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


def shape_bucket(span: int, dh: int) -> str:
    """Stable cache key for a kernel shape: power-of-two bucket of the
    blocked axis (ceil) x the 128-lane-padded head/feature dim."""
    lanes = max(-(-dh // 128) * 128, 128)
    return f"s{_pow2_at_least(max(span, 1))}_d{lanes}"


def set_tuning_table(table) -> None:
    """Install (or clear, with None) the process-wide tuned-config table:
    ``{kernel: {bucket: {param: int}}}``. Wrappers consult it at trace
    time, so installing a table invalidates nothing — jit caches key on
    the resolved static values."""
    global _table
    _table = table


def get_tuning_table():
    return _table


def resolve(kernel: str, span: int, dh: int, param: str,
            override: Optional[int] = None) -> int:
    """The wrapper-facing lookup: explicit caller override wins, then the
    installed table's (kernel, bucket) entry, then the historical
    default. `span` is the size of the axis the kernel blocks over."""
    if override is not None:
        return override
    if _table is not None:
        cfg = _table.get(kernel, {}).get(shape_bucket(span, dh))
        if cfg and param in cfg:
            return int(cfg[param])
    return DEFAULTS[kernel][param]
