"""Sharding rules + roofline parsers (pure host-side logic)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.launch.roofline import (_shape_bytes, collective_inventory,
                                   decode_terms, train_terms, prefill_terms)
from repro.configs.registry import INPUT_SHAPES, get_config
from repro.sharding.rules import spec_for


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_spec_for_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # 4 heads on a 16-way model axis -> replicated, MLP still shards
    assert spec_for((2048, 4, 256), (None, "heads", None), mesh) == P()
    assert spec_for((2048, 6912), ("embed", "ffn"), mesh) == P(None, "model")


def test_spec_for_batch_two_axes():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    s = spec_for((256, 4096), ("batch", "seq"), mesh)
    assert s == P(("pod", "data"))


def test_shape_bytes_parser():
    assert _shape_bytes("bf16[4,8]") == 64
    assert _shape_bytes("f32[10] bf16[2,2]") == 48
    assert _shape_bytes("pred[]") == 1   # scalar => one element


def test_collective_inventory_trip_multiplication():
    hlo = """
HloModule m

%cond.1 (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(24)
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

%body.1 (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[4] all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[4]) tuple(%i, %ar)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[8] all-gather(%y), dimensions={0}
  ROOT %r = f32[4] get-tuple-element(%w), index=1
}
"""
    inv = collective_inventory(hlo)
    assert inv["bytes"]["all-reduce"] == 16 * 24      # inside the loop
    assert inv["bytes"]["all-gather"] == 32           # outside
    assert inv["counts"]["all-reduce"] == 1


# ----------------------------------------------------------------------------
# analytic roofline sanity
# ----------------------------------------------------------------------------
MESH = {"data": 16, "model": 16}


def test_train_terms_scale_with_batch():
    cfg = get_config("internlm2-1.8b")
    t1 = train_terms(cfg, INPUT_SHAPES["train_4k"], MESH)
    import dataclasses
    small = dataclasses.replace(INPUT_SHAPES["train_4k"], global_batch=128)
    t2 = train_terms(cfg, small, MESH)
    assert t1.flops == pytest.approx(2 * t2.flops, rel=0.01)
    assert t1.compute_s > 0 and t1.memory_s > 0


def test_decode_terms_fetch_mode_monotone():
    cfg = get_config("kimi-k2-1t-a32b")
    kw = dict(n_seg=2, k_res=1, k_off=1, n_mb=16, mb=8)
    slot = decode_terms(cfg, INPUT_SHAPES["decode_32k"], MESH,
                        fetch_mode="slot", **kw)
    step = decode_terms(cfg, INPUT_SHAPES["decode_32k"], MESH,
                        fetch_mode="step", **kw)
    # per-step restore moves each streamed byte once; per-slot re-fetches
    assert slot.wire_bytes_per_dev > 5 * step.wire_bytes_per_dev
    assert slot.dominant == "collective"


def test_moe_flops_use_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    t = prefill_terms(kimi, INPUT_SHAPES["prefill_32k"], MESH)
    dense_equiv = 2.0 * kimi.total_params() * 32 * 32768
    assert t.flops < 0.15 * dense_equiv      # 32B active of 1T total


@given(st.sampled_from(["internlm2-1.8b", "gemma3-1b", "rwkv6-3b",
                        "deepseek-moe-16b"]),
       st.sampled_from(list(INPUT_SHAPES)))
@settings(max_examples=16, deadline=None)
def test_terms_always_finite_positive(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.mode == "train":
        t = train_terms(cfg, shape, MESH)
    elif shape.mode == "prefill":
        t = prefill_terms(cfg, shape, MESH)
    else:
        t = decode_terms(cfg, shape, MESH, n_seg=1, k_res=2, k_off=0,
                         n_mb=16, mb=max(shape.global_batch // 16, 1),
                         long_mode=shape.name == "long_500k")
    assert t.flops > 0 and t.hbm_bytes > 0
    assert np.isfinite(t.compute_s + t.memory_s + t.collective_s)
    assert t.dominant in ("compute", "memory", "collective")
