"""Online SLO engine: declarative targets, multi-window burn-rate alerts,
and a live health signal (DESIGN.md §17).

PR 7's flight recorder *records* what happened; this module *judges* it
while serving. The model is the SRE burn-rate alert (Google SRE workbook
ch. 5), adapted to the backend clock so the same engine evaluates a
discrete-event sim run and a wall-clock engine run identically:

  target      a declarative objective: "metric M must be good for at
              least `target` of events", where good is `value <= threshold`
              for latency metrics and non-occurrence for event metrics
              (reject). The error budget is 1 - target.
  burn rate   bad_fraction(window) / error_budget: 1.0 means the budget
              is being spent exactly at sustainable pace, B means B x
              faster. Evaluated over TWO windows (fast + slow): the fast
              window makes alerts prompt, the slow window makes them
              *sticky to real trouble* — a single bad request in an idle
              second spikes the fast burn but not the slow one, so no
              alert. Breach fires when BOTH windows burn above
              `burn_threshold`; recovery requires the fast window back
              under threshold x recovery_frac (hysteresis, no flapping).
  health      1.0 while every target holds; a breaching target pulls
              health toward 0 as 1/(1 + excess burn). The FleetRouter
              subtracts w_health x (1 - health) from a replica's score —
              traffic sheds away from a breaching replica — and backends
              forward (1 - health) to the OnlinePlanner as pressure,
              which scales its TS thresholds so weight demotion frees KV
              *before* the next admission would queue.

All state is bounded: per-target one WindowedCounter ring (sized to the
slow window) and one ReservoirSketch for the dashboard's live percentile
readout. Nothing here retains per-request records.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.obs import trace as tr_ev
from repro.obs.trace import get_tracer
from repro.obs.sketch import ReservoirSketch, WindowedCounter

# metric vocabulary: how a request record maps to per-event observations
#   ttft     first_token_s - arrival_s        (seconds; threshold-judged)
#   tpot     (finish-first)/(generated-1)     (seconds/token; threshold)
#   latency  finish_s - arrival_s             (seconds; threshold-judged)
#   goodput  finished within latency threshold (same observation stream as
#            latency — a separate target name for a separate budget)
#   reject   request shed at intake           (occurrence is bad)
METRICS = ("ttft", "tpot", "latency", "goodput", "reject")


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """One declarative objective, burn-rate evaluated."""
    name: str                     # "ttft_p99" — report/alert key
    metric: str                   # one of METRICS
    threshold_s: float = 0.0      # good iff value <= threshold (latency
                                  # metrics; unused for "reject")
    target: float = 0.99          # required good fraction (p99 -> 0.99)
    fast_window_s: float = 30.0   # prompt-alert window
    slow_window_s: float = 300.0  # sustained-burn window
    burn_threshold: float = 4.0   # budget multiple that trips the alert
    recovery_frac: float = 0.5    # fast burn must drop below
                                  # burn_threshold x this to recover

    def __post_init__(self):
        if self.metric not in METRICS:
            raise ValueError(f"unknown SLO metric {self.metric!r}; "
                             f"have {METRICS}")
        if not 0.0 < self.target < 1.0:
            raise ValueError(f"target must be in (0,1): {self.target}")
        if self.fast_window_s > self.slow_window_s:
            raise ValueError("fast window must not exceed slow window")

    @property
    def budget(self) -> float:
        return 1.0 - self.target


def default_targets(*, ttft_p99_s: float = 8.0, tpot_p50_s: float = 1.0,
                    latency_p95_s: float = 30.0,
                    reject_target: float = 0.95,
                    fast_window_s: float = 30.0,
                    slow_window_s: float = 300.0,
                    burn_threshold: float = 4.0) -> List[SLOTarget]:
    """The serving defaults --slo enables: TTFT p99, TPOT p50, goodput
    (latency p95), and reject rate. Thresholds are CLI-tunable; the
    shipped numbers suit the sim's E3 fleet at benchmark scale."""
    w = dict(fast_window_s=fast_window_s, slow_window_s=slow_window_s,
             burn_threshold=burn_threshold)
    return [
        SLOTarget("ttft_p99", "ttft", ttft_p99_s, target=0.99, **w),
        SLOTarget("tpot_p50", "tpot", tpot_p50_s, target=0.50, **w),
        SLOTarget("goodput_p95", "goodput", latency_p95_s, target=0.95,
                  **w),
        SLOTarget("reject_rate", "reject", target=reject_target, **w),
    ]


class _TargetState:
    """Mutable evaluation state for one target."""
    __slots__ = ("target", "window", "sketch", "breached", "breaches",
                 "recoveries", "breach_s", "last_fast_burn",
                 "last_slow_burn")

    def __init__(self, t: SLOTarget, sketch_capacity: int, seed: int):
        self.target = t
        # one ring sized to the slow window answers both windows
        self.window = WindowedCounter(t.slow_window_s, n_buckets=60)
        self.sketch = ReservoirSketch(sketch_capacity, seed=seed)
        self.breached = False
        self.breaches = 0
        self.recoveries = 0
        self.breach_s: Optional[float] = None
        self.last_fast_burn = 0.0
        self.last_slow_burn = 0.0


class SLOEngine:
    """Evaluates a set of SLOTargets over a live request stream.

    Clock-explicit: every entry point takes `now` on the backend clock.
    The scheduler calls observe_request / observe_reject at completion
    and shedding; evaluate() (called after each observation, and by the
    dashboard on its render tick) rolls the windows, flips breach states,
    and emits slo.breach / slo.recover tracer instants."""

    def __init__(self, targets: Optional[List[SLOTarget]] = None, *,
                 sketch_capacity: int = 1024, seed: int = 0):
        self.targets = list(targets) if targets is not None \
            else default_targets()
        names = [t.name for t in self.targets]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO target names: {names}")
        self._states: Dict[str, _TargetState] = {
            t.name: _TargetState(t, sketch_capacity, seed=seed + i)
            for i, t in enumerate(self.targets)}
        self.health = 1.0

    # -- observation --------------------------------------------------------------
    def _metric_values(self, req) -> Dict[str, float]:
        """Extract per-metric observations from a finished request
        record (anything with the Request timestamp attributes)."""
        out: Dict[str, float] = {}
        if req.first_token_s is not None:
            out["ttft"] = req.first_token_s - req.arrival_s
        if req.finish_s is not None:
            out["latency"] = req.finish_s - req.arrival_s
            out["goodput"] = out["latency"]
            gen = getattr(req, "generated", 0)
            if req.first_token_s is not None and gen > 1:
                out["tpot"] = (req.finish_s - req.first_token_s) \
                    / (gen - 1)
        return out

    def observe_request(self, req, now: float) -> None:
        """One finished request: judge it against every latency target
        and count it as a good (non-)rejection."""
        vals = self._metric_values(req)
        for st in self._states.values():
            t = st.target
            if t.metric == "reject":
                st.window.add(now, good=1.0)
                continue
            v = vals.get(t.metric)
            if v is None:
                continue
            st.sketch.observe(v)
            good = v <= t.threshold_s
            st.window.add(now, good=float(good), bad=float(not good))
        self.evaluate(now)

    def observe_reject(self, req, now: float) -> None:
        for st in self._states.values():
            if st.target.metric == "reject":
                st.window.add(now, bad=1.0)
        self.evaluate(now)

    # -- evaluation ---------------------------------------------------------------
    def burn_rates(self, name: str, now: float) -> tuple:
        """(fast, slow) burn rates for one target: bad fraction over the
        window divided by the error budget."""
        st = self._states[name]
        t = st.target
        fast = st.window.bad_fraction(t.fast_window_s, now) / t.budget
        slow = st.window.bad_fraction(t.slow_window_s, now) / t.budget
        return fast, slow

    def evaluate(self, now: float) -> List[str]:
        """Roll windows, flip breach states, emit tracer events; returns
        the names of targets that changed state this call. Also refreshes
        `health`."""
        changed: List[str] = []
        tr = get_tracer()
        health = 1.0
        for st in self._states.values():
            t = st.target
            fast, slow = self.burn_rates(t.name, now)
            st.last_fast_burn, st.last_slow_burn = fast, slow
            if not st.breached:
                # both windows must burn: prompt AND sustained
                if fast >= t.burn_threshold and slow >= t.burn_threshold:
                    st.breached = True
                    st.breaches += 1
                    st.breach_s = now
                    changed.append(t.name)
                    if tr is not None:
                        tr.instant(tr_ev.SLO_BREACH, ts=now,
                                   track=tr_ev.TRACK_SLO,
                                   args={"target": t.name,
                                         "fast_burn": fast,
                                         "slow_burn": slow,
                                         "threshold": t.burn_threshold})
            else:
                if fast < t.burn_threshold * t.recovery_frac:
                    st.breached = False
                    st.recoveries += 1
                    st.breach_s = None
                    changed.append(t.name)
                    if tr is not None:
                        tr.instant(tr_ev.SLO_RECOVER, ts=now,
                                   track=tr_ev.TRACK_SLO,
                                   args={"target": t.name,
                                         "fast_burn": fast})
            if st.breached:
                # health decays with excess burn past the threshold:
                # breach at exactly threshold -> 0.5, runaway burn -> 0
                excess = max(fast, 1e-9) / t.burn_threshold
                health = min(health, 1.0 / (1.0 + excess))
        self.health = health
        return changed

    # -- signals ------------------------------------------------------------------
    @property
    def breaching(self) -> List[str]:
        return [n for n, st in self._states.items() if st.breached]

    def pressure(self) -> float:
        """1 - health: what backends forward to the OnlinePlanner so the
        TS ladder fires early under SLO stress (0 when healthy)."""
        return 1.0 - self.health

    # -- reporting ----------------------------------------------------------------
    def snapshot(self, now: float) -> dict:
        """JSON-able state for the dashboard / bench reports."""
        self.evaluate(now)
        out: Dict[str, dict] = {}
        for name, st in self._states.items():
            t = st.target
            out[name] = {
                "metric": t.metric,
                "threshold_s": t.threshold_s,
                "target": t.target,
                "fast_burn": st.last_fast_burn,
                "slow_burn": st.last_slow_burn,
                "burn_threshold": t.burn_threshold,
                "breached": st.breached,
                "breaches": st.breaches,
                "recoveries": st.recoveries,
                "observed": st.sketch.count,
                # None, not NaN, when nothing observed: NaN is not valid
                # JSON and json.dumps would emit a non-portable literal
                "p50": (st.sketch.quantile(50) if st.sketch.count else
                        None),
                "p99": (st.sketch.quantile(99) if st.sketch.count else
                        None),
            }
        return {"health": self.health, "targets": out,
                "breaching": self.breaching}
