import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST run before any jax import (jax locks the device
count at first init); do not move them. This module is the only place that
forces 512 host devices — tests and benches see the real device count.

For each assigned architecture and input shape this builds the appropriate
step on the production mesh, lowers with ShapeDtypeStruct stand-ins (no
allocation), compiles, and reports:

  * memory_analysis()  — per-device bytes (proves the sharding fits HBM)
  * cost_analysis()    — FLOPs / bytes for EXPERIMENTS.md §Roofline
  * collective bytes   — parsed from the compiled HLO (§Roofline's third
    term; cost_analysis does not cover collectives)

Usage:
  python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""
import argparse
import json
import math
import sys
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import Family, InputShape, ModelConfig
from repro.configs.registry import INPUT_SHAPES, get_config, dryrun_pairs
from repro.core.engine import InterleavedEngine, UniformPlan
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import spec as pspec
from repro.optim.adamw import AdamW, constant_schedule
from repro.sharding import rules
from repro.training.trainer import make_train_step, zero1_sharding


# ============================================================================
# input_specs: ShapeDtypeStruct stand-ins per (arch, shape)
# ============================================================================
def batch_sharding(mesh: Mesh, all_axes: bool = False) -> NamedSharding:
    names = ("pod", "data", "model") if all_axes else ("pod", "data")
    axes = tuple(a for a in names if a in mesh.shape)
    ba = axes if len(axes) > 1 else (axes[0] if axes else None)
    return NamedSharding(mesh, P(ba))


def input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                all_axes_batch: bool = False) -> Dict[str, Any]:
    """Training / prefill batch stand-ins, batch-sharded over (pod, data)."""
    B, S = shape.global_batch, shape.seq_len
    bs = batch_sharding(mesh, all_axes_batch)
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bs)
    out = {"tokens": tok}
    if shape.mode == "train":
        out["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bs)
        out["mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32, sharding=bs)
    if cfg.frontend_tokens:
        # modality stub (assignment carve-out): precomputed patch/frame
        # embeddings of the right shape stand in for the ViT/conv frontend
        fe = jax.ShapeDtypeStruct((B, cfg.frontend_tokens, cfg.d_model),
                                  jnp.bfloat16, sharding=bs)
        if cfg.family == Family.ENCDEC:
            out["frontend_embeds"] = fe
        else:
            out["frontend_embeds"] = fe
    return out


def param_specs_sharded(cfg: ModelConfig, mesh: Mesh):
    specs = M.build_param_specs(cfg)
    sh = rules.shardings(specs, mesh)
    shapes = pspec.shapes(specs)
    return jax.tree.map(
        lambda s, n: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=n),
        shapes, sh, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ============================================================================
# per-shape step builders (lowered, no execution)
# ============================================================================
def lower_train(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                impl: str = "ref", strategy: str = "tp"):
    """strategy='tp': Megatron weights over 'model' + DP over (pod, data).
    strategy='dp': weights replicated over 'model', batch over ALL axes —
    wins for small models where TP allreduces dominate (§Perf/H2)."""
    model_par = mesh.shape.get("model", 1)
    n_dev = int(np.prod(list(mesh.shape.values())))
    if strategy == "tp" and cfg.total_params() * 2 / model_par > 8e9:
        strategy = "fsdp"      # weights exceed the HBM budget model-sharded
    # AdamW fp32 state = 12 B/param; above ~6 GB/chip use Adafactor
    factored = cfg.total_params() * 12 / n_dev > 6e9
    if factored:
        from repro.optim.adafactor import Adafactor
        opt = Adafactor(lr=constant_schedule(1e-4))
    else:
        opt = AdamW(lr=constant_schedule(1e-4))
    step = make_train_step(cfg, opt, mesh, impl=impl, remat=True)
    rl = {"dp": rules.dp_rules(), "fsdp": rules.fsdp_rules()}.get(strategy)
    specs = M.build_param_specs(cfg)
    sh = rules.shardings(specs, mesh, rl)
    shapes = pspec.shapes(specs)
    is_sds = lambda x: isinstance(x, jax.ShapeDtypeStruct)
    p_specs = jax.tree.map(
        lambda s, n: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=n),
        shapes, sh, is_leaf=is_sds)
    if factored:
        opt_specs = opt.state_specs(p_specs)
    else:
        z1 = zero1_sharding(None, mesh,
                            over=("pod", "data", "model")
                            if strategy == "dp" else ("pod", "data"))
        m_specs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.float32,
                sharding=z1(s.sharding, s.shape)),
            p_specs, is_leaf=is_sds)
        from repro.optim.adamw import AdamWState
        opt_specs = AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                               m_specs, m_specs, m_specs)
    batch = input_specs(cfg, shape, mesh,
                        all_axes_batch=(strategy == "dp"))
    fn = jax.jit(step, donate_argnums=(0, 1))
    if strategy == "dp":
        with M.batch_axes(("pod", "data", "model")):
            return fn.lower(p_specs, opt_specs, batch)
    if strategy == "fsdp":
        with M.seq_shard(True):     # remat carries must also shard (kimi)
            return fn.lower(p_specs, opt_specs, batch)
    return fn.lower(p_specs, opt_specs, batch)


def lower_prefill(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                  impl: str = "ref"):
    """Prefill: fill the KV cache for `seq_len` under GSPMD batch+tensor
    sharding (the engine serves decode; prefill is throughput-bound and
    data-parallel like training)."""
    B, S = shape.global_batch, shape.seq_len
    model_par = mesh.shape.get("model", 1)
    fsdp = cfg.total_params() * 2 / model_par > 8e9
    specs_ = M.build_param_specs(cfg)
    sh_ = rules.shardings(specs_, mesh,
                          rules.fsdp_rules() if fsdp else None)
    is_sds = lambda x: isinstance(x, jax.ShapeDtypeStruct)
    p_specs = jax.tree.map(
        lambda s, n: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=n),
        pspec.shapes(specs_), sh_, is_leaf=is_sds)
    batch = input_specs(cfg, shape, mesh)
    bs = batch_sharding(mesh)
    cs = M.cache_specs(cfg, B, S)
    cache_specs = {}
    for k, v in cs.items():
        parts = [None] * len(v.shape)
        if v.shape and v.shape[0] == cfg.n_layers and len(v.shape) > 1:
            parts[1] = bs.spec[0]          # batch dim of (L, B, ...)
            if len(v.shape) > 2 and "model" in mesh.shape \
                    and v.shape[2] % mesh.shape["model"] == 0:
                parts[2] = "model"
        cache_specs[k] = jax.ShapeDtypeStruct(
            v.shape, v.dtype, sharding=NamedSharding(mesh, P(*parts)))

    enc = cfg.family == Family.ENCDEC

    def prefill_step(params, tokens, cache, frontend_embeds=None):
        enc_out = None
        if enc:
            enc_out = M.encode(cfg, params, frontend_embeds, mesh=mesh,
                               impl=impl)
            cache = M.seed_cross_kv(cfg, params, cache, enc_out)
            fe = None
        else:
            fe = frontend_embeds
        logits, new_cache = M.prefill(cfg, params, tokens, cache,
                                      frontend_embeds=fe, mesh=mesh,
                                      impl=impl, enc_out=enc_out)
        return logits, new_cache

    args = [p_specs, batch["tokens"], cache_specs]
    if cfg.frontend_tokens:
        args.append(batch["frontend_embeds"])
    if fsdp:
        with M.seq_shard(True):
            return jax.jit(prefill_step).lower(*args)
    return jax.jit(prefill_step).lower(*args)


def decode_plan(cfg: ModelConfig, n_stage: int) -> UniformPlan:
    """Uniform LIME plan for serving: segments chosen so each stage's
    resident share fits the HBM weight budget, one streamed layer per chunk
    when offloading is needed (k_off=1 keeps the all_to_all slab ~l_size,
    mirroring the paper's per-segment single-extra-load property)."""
    l_bytes = cfg.layer_params() * 2
    budget = 16e9 * 0.45                  # weights' share of HBM per chip
    model_par = 16
    per_stage_resident = cfg.n_layers / n_stage * l_bytes / model_par
    L_pad = math.ceil(cfg.n_layers / n_stage) * n_stage
    if per_stage_resident <= budget:
        return UniformPlan(n_stage, 1, L_pad // n_stage, 0)
    # offload: choose n_seg = ceil(L / (n_stage * k)) with k = k_res + 1
    for n_seg in range(2, max(cfg.n_layers // n_stage, 2) + 1):
        k = math.ceil(cfg.n_layers / (n_seg * n_stage))
        res_bytes = (k - 1) * n_seg * l_bytes / model_par
        if res_bytes <= budget and k >= 1:
            return UniformPlan(n_stage, n_seg, k - 1, 1)
    return UniformPlan(n_stage, max(cfg.n_layers // n_stage, 2), 0, 1)


def lower_decode(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                 impl: str = "ref", fetch_mode: str = "step"):
    """serve_step: ONE new token against a seq_len KV cache via the LIME
    interleaved engine ('data' axis = pipeline stages)."""
    n_stage = mesh.shape["data"]
    B = shape.global_batch
    long_mode = shape.name == "long_500k"
    if B >= n_stage:
        n_mb, mb = n_stage, B // n_stage      # bursty: fill the pipeline
    else:
        n_mb, mb = 1, B                       # sporadic
    plan = decode_plan(cfg, n_stage)
    eng = InterleavedEngine(cfg, mesh, plan, n_mb=n_mb, mb=mb,
                            max_len=shape.seq_len, long_mode=long_mode,
                            fetch_mode=fetch_mode, impl=impl,
                            enc_len=cfg.frontend_tokens or 0)
    return eng.lower_step()


def lower_decode_tp(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                    impl: str = "ref"):
    """Pipeline-free serve_step for sporadic traffic (§Perf/H3): weights
    sharded over (data x model) jointly, the single micro-batch's decode
    runs every layer under GSPMD — no pipeline bubbles, at the price of
    all-gather-style weight traffic per step. Compare with the engine via
    analytic terms + HLO inventory."""
    B = shape.global_batch
    long_mode = shape.name == "long_500k"
    joint = {k: (tuple(v) + ("data",) if v == ("model",) else v)
             for k, v in rules.RULES.items()}
    joint = {k: (("model", "data") if v == ("model", "data") else v)
             for k, v in joint.items()}
    specs = M.build_param_specs(cfg)
    sh = rules.shardings(specs, mesh, joint)
    shapes = pspec.shapes(specs)
    is_sds = lambda x: isinstance(x, jax.ShapeDtypeStruct)
    p_specs = jax.tree.map(
        lambda s, n: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=n),
        shapes, sh, is_leaf=is_sds)
    cs = M.cache_specs(cfg, B, shape.seq_len, long_mode)
    cache_specs = {}
    for k, v in cs.items():
        parts = [None] * len(v.shape)
        if v.shape and v.shape[0] == cfg.n_layers and len(v.shape) > 2:
            if v.shape[2] % mesh.shape.get("model", 1) == 0:
                parts[2] = "model"      # seq dim of (L, B, S, ...)
        cache_specs[k] = jax.ShapeDtypeStruct(
            v.shape, v.dtype, sharding=NamedSharding(mesh, P(*parts)))
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)

    def serve_step(params, cache, token):
        return M.decode_step(cfg, params, cache, token, mesh=None,
                             impl=impl, long_mode=long_mode)

    return jax.jit(serve_step).lower(p_specs, cache_specs, tok)


def lower_pair(arch: str, shape_name: str, mesh: Mesh, impl: str = "ref",
               fetch_mode: str = "step", strategy: str = "default"):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.mode == "train":
        return lower_train(cfg, shape, mesh, impl,
                           strategy="dp" if strategy == "dp" else "tp")
    if shape.mode == "prefill":
        return lower_prefill(cfg, shape, mesh, impl)
    if strategy == "tp_serve":
        return lower_decode_tp(cfg, shape, mesh, impl)
    return lower_decode(cfg, shape, mesh, impl, fetch_mode)


# ============================================================================
# analysis: analytic roofline (primary) + HLO evidence (cross-check)
# ============================================================================
def analytic_terms(arch: str, shape_name: str, mesh: Mesh,
                   fetch_mode: str = "step"):
    from repro.launch import roofline as RL
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ms = dict(mesh.shape)
    if shape.mode == "train":
        return RL.train_terms(cfg, shape, ms)
    if shape.mode == "prefill":
        return RL.prefill_terms(cfg, shape, ms)
    plan = decode_plan(cfg, ms.get("data", 1))
    B = shape.global_batch
    n_stage = ms.get("data", 1)
    n_mb, mb = (n_stage, B // n_stage) if B >= n_stage else (1, B)
    return RL.decode_terms(cfg, shape, ms, n_seg=plan.n_seg,
                           k_res=plan.k_res, k_off=plan.k_off,
                           n_mb=n_mb, mb=mb, fetch_mode=fetch_mode,
                           long_mode=shape.name == "long_500k")


def analyze(lowered, compiled, n_devices: int) -> Dict[str, Any]:
    from repro.launch import roofline as RL
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    inv = RL.collective_inventory(hlo)
    return {
        "hlo_flops_scan_once": float(cost.get("flops", 0.0)),
        "hlo_bytes_scan_once": float(cost.get("bytes accessed", 0.0)),
        "hlo_collectives": {"bytes": inv["bytes"], "counts": inv["counts"],
                            "total_bytes": inv["total_bytes"]},
        "memory_per_device": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
    }


def model_flops_per_step(cfg: ModelConfig, shape: InputShape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode steps use D = batch."""
    n = cfg.active_params()
    if shape.mode == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # one token per sequence


# ============================================================================
# CLI
# ============================================================================
def run_one(arch: str, shape_name: str, mesh: Mesh, *, impl: str = "ref",
            fetch_mode: str = "step", verbose: bool = True) -> Dict[str, Any]:
    n_dev = int(np.prod(list(mesh.shape.values())))
    lowered = lower_pair(arch, shape_name, mesh, impl, fetch_mode)
    compiled = lowered.compile()
    info = analyze(lowered, compiled, n_dev)
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    terms = analytic_terms(arch, shape_name, mesh, fetch_mode)
    info["terms"] = terms.as_dict()
    mf = model_flops_per_step(cfg, shape)
    info["model_flops"] = mf
    info["useful_ratio"] = mf / terms.flops if terms.flops else 0.0
    info["arch"], info["shape"] = arch, shape_name
    info["mesh"] = dict(mesh.shape)
    if verbose:
        t = info["terms"]
        print(f"[{arch} x {shape_name} x "
              f"{'x'.join(map(str, mesh.shape.values()))}] "
              f"compute={t['compute_s']*1e3:.2f}ms "
              f"memory={t['memory_s']*1e3:.2f}ms "
              f"collective={t['collective_s']*1e3:.2f}ms "
              f"dominant={t['dominant']} useful={info['useful_ratio']:.2f}")
        print(f"  mem/device: "
              f"peak={info['memory_per_device']['peak_bytes']/1e9:.2f}GB "
              f"args={info['memory_per_device']['argument_bytes']/1e9:.2f}GB "
              f"| hlo collectives: "
              f"{ {k: round(v/1e6) for k, v in info['hlo_collectives']['bytes'].items() if v} } MB")
    return info


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--impl", default="ref")
    ap.add_argument("--fetch-mode", default="step",
                    choices=("step", "slot"),
                    help="'slot' = paper-literal per-segment streaming "
                         "(perf baseline); 'step' = per-step restore")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    results = []
    if args.all:
        for arch, shape_name, runnable, skip in dryrun_pairs():
            if not runnable:
                print(f"[{arch} x {shape_name}] SKIP: {skip}")
                results.append({"arch": arch, "shape": shape_name,
                                "skip": skip})
                continue
            try:
                results.append(run_one(arch, shape_name, mesh,
                                       impl=args.impl,
                                       fetch_mode=args.fetch_mode))
            except Exception as e:
                print(f"[{arch} x {shape_name}] FAIL: {type(e).__name__}: {e}")
                results.append({"arch": arch, "shape": shape_name,
                                "error": f"{type(e).__name__}: {e}"})
    else:
        results.append(run_one(args.arch, args.shape, mesh, impl=args.impl,
                               fetch_mode=args.fetch_mode))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=str)
    bad = [r for r in results if "error" in r]
    print(f"\n{len(results) - len(bad)}/{len(results)} pairs OK")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
