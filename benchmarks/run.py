"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig2a,e1e2e3,...]

Prints per-scenario results and writes benchmarks/results.csv. Roofline
terms for the (arch x shape x mesh) grid come from the dry-run
(`python -m repro.launch.dryrun --all`), not from here — this harness runs
the paper-reproduction simulator (EXPERIMENTS.md §Repro).
"""
import argparse
import glob
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

SUITES = {
    "fig2a": ("benchmarks.bench_motivation", "Fig 2a motivation"),
    "e1e2e3": ("benchmarks.bench_paper_e1e2e3", "Figs 12-14 E1/E2/E3"),
    "lowmem": ("benchmarks.bench_lowmem", "Figs 15-17 low-memory"),
    "varbw": ("benchmarks.bench_bandwidth", "Fig 18 varying bandwidth"),
    "ablation": ("benchmarks.bench_ablation", "Tab V ablation"),
    "kernels": ("benchmarks.bench_kernels", "kernel microbench"),
    "specdec": ("benchmarks.bench_specdec", "speculative vs AR decode"),
    "selfspec": ("benchmarks.bench_selfspec", "resident self-draft vs n-gram "
                                              "across retier rungs"),
    "prefix": ("benchmarks.bench_prefix", "radix prefix cache + chunked "
                                          "prefill"),
    "adaptation": ("benchmarks.bench_adaptation", "online memory adaptation "
                                                  "vs static plan"),
    "fleet": ("benchmarks.bench_fleet", "multi-replica router vs single "
                                        "pipeline"),
}


def check_baselines(baseline_dir=None):
    """Schema sanity over benchmarks/baselines/*.json: a baseline written
    by an older repo version carries an older (or no) schema_version —
    warn and keep going instead of KeyError-ing deep inside a comparison
    (serving/metrics.py SCHEMA_VERSION is the authority; report_from_dict
    fills fields the old schema lacked)."""
    from repro.obs.log import get_logger
    from repro.serving.metrics import SCHEMA_VERSION
    log = get_logger("benchmarks.run")
    if baseline_dir is None:
        baseline_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    "baselines")
    stale = []
    for path in sorted(glob.glob(os.path.join(baseline_dir, "*.json"))):
        try:
            with open(path) as f:
                d = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            log.warning(f"baseline {os.path.basename(path)}: unreadable "
                        f"({e}) — skipping")
            stale.append(path)
            continue
        # list-shaped baselines stamp each report; dict-shaped ones carry
        # one top-level version
        heads = d if isinstance(d, list) else [d]
        vers = {h.get("schema_version") for h in heads if isinstance(h, dict)}
        if vers != {SCHEMA_VERSION}:
            log.warning(
                f"baseline {os.path.basename(path)}: schema_version="
                f"{sorted(vers, key=str)} != current {SCHEMA_VERSION} — "
                f"comparisons may miss newer fields; regenerate with the "
                f"suite's --out flag")
            stale.append(path)
    return stale


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--csv", default="benchmarks/results.csv")
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(SUITES)
    check_baselines()

    all_rows = []
    for name in names:
        mod_name, title = SUITES[name]
        print(f"\n=== {title} ({name}) " + "=" * max(40 - len(title), 3))
        t0 = time.time()
        mod = __import__(mod_name, fromlist=["run"])
        rows = mod.run() or []
        print(f"--- {name} done in {time.time() - t0:.1f}s")
        for r in rows:
            if hasattr(r, "csv"):
                all_rows.append(r.csv())
            else:
                all_rows.append(f"{name},{r[0]},{r[1]:.1f},ok")
    if args.csv and all_rows:
        with open(args.csv, "w") as f:
            f.write("scenario,method,ms_per_token,status\n")
            f.write("\n".join(all_rows) + "\n")
        print(f"\nwrote {len(all_rows)} rows to {args.csv}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
