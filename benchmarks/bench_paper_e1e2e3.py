"""Paper Figs 12-14: E1/E2/E3 latency, two bandwidths x two request
patterns, LIME vs all six baselines."""
from benchmarks.common import ENVS, run_scenario, speedup_table
from repro.configs.registry import get_config


def run():
    rows = []
    for env_name, (arch, envf, D) in ENVS.items():
        cfg = get_config(arch)
        for bw in (100, 200):
            for pattern, nm in (("sporadic", 1), ("bursty", D)):
                sc = f"{env_name}/{arch}/{bw}Mbps/{pattern}"
                rows.extend(run_scenario(sc, envf(), cfg, bw_mbps=bw,
                                         pattern=pattern, n_micro=nm))
    for sc, t in speedup_table(rows).items():
        lime = next(r for r in rows
                    if r.scenario == sc and r.method == "LIME")
        print(f"{sc}: LIME {lime.ms_per_token:.0f} ms/tok | "
              + " ".join(f"{m}={v}" for m, v in t.items() if m != "LIME"))
    return rows


if __name__ == "__main__":
    run()
