"""Flight-recorder tracer: ring-buffered events with a stable vocabulary
(DESIGN.md §15).

LIME's whole argument is a timing argument — interleaved weight streaming
hides behind compute, retier trades HBM between weights and KV, a spec
round amortizes one streaming round over k+1 tokens — so the serving path
carries a low-overhead event recorder that can *show* those overlaps
instead of summarizing them away. Design constraints, in order:

  zero-cost off   tracing is opt-in. `get_tracer()` returns None unless a
                  Tracer was installed; every instrumentation site is a
                  module-global read + None check and nothing else.
  bounded on      events land in a ring (`collections.deque(maxlen=...)`):
                  a long run never grows memory without bound, the *last*
                  N events survive (flight-recorder semantics). Spans that
                  matter long-term (request lifecycles) are emitted at
                  completion, so they survive ring wrap of their live
                  instants.
  one timebase    every event carries an explicit timestamp in seconds on
                  the *backend clock* — wall time for the engine, virtual
                  time for the discrete-event simulator — so sim and
                  engine runs render identically in Perfetto. The
                  scheduler binds `tracer.clock` to `backend.now` at
                  construction; sites without a better clock call
                  `tracer.now()`.

Events are plain tuples (EVT_* index constants below), not objects: the
hot path allocates one tuple and one deque append per event.

Event vocabulary — request lifecycle (track "req:<rid>"):

  req.arrive  req.queue  req.admit  req.prefix_hit  req.prefill
  req.prefill_chunk  req.decode  req.spec_round  req.preempt  req.spill
  req.resume  req.finish  req.reject  req.span

and step / substrate internals (tracks "pipeline", "dev:<i>",
"dev:<i>:loader", "kv", "prefix", "sched", "engine"):

  step  stage.compute  weight.fetch  weight.stall  act.hop
  kv.migrate  kv.spill  kv.fetch  kv.grow  kv.shrink
  prefix.hit  prefix.insert  prefix.evict
  retier  retier.reclaim  planner.fired
  engine.prefill  engine.decode  engine.verify  engine.draft
  engine.seed  engine.retier

Phases follow the Chrome trace-event format (`ph`): "i" instant,
"X" complete (ts + dur), "B"/"E" begin/end, "C" counter.
"""
from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager
from typing import Callable, List, Optional, Tuple

# tuple layout of one event (kept flat for allocation cost)
EVT_NAME, EVT_PH, EVT_TS, EVT_DUR, EVT_TRACK, EVT_ARGS = range(6)

Event = Tuple[str, str, float, float, str, Optional[dict]]

# -- event vocabulary (DESIGN.md §15) ----------------------------------------
# request lifecycle
REQ_ARRIVE = "req.arrive"
REQ_QUEUE = "req.queue"
REQ_ADMIT = "req.admit"
REQ_PREFIX_HIT = "req.prefix_hit"
REQ_PREFILL = "req.prefill"
REQ_PREFILL_CHUNK = "req.prefill_chunk"
REQ_DECODE = "req.decode"
REQ_SPEC_ROUND = "req.spec_round"
REQ_PREEMPT = "req.preempt"
REQ_SPILL = "req.spill"
REQ_RESUME = "req.resume"
REQ_FINISH = "req.finish"
REQ_REJECT = "req.reject"
REQ_SPAN = "req.span"
# step internals
STEP = "step"
STAGE_COMPUTE = "stage.compute"
WEIGHT_FETCH = "weight.fetch"
WEIGHT_STALL = "weight.stall"
ACT_HOP = "act.hop"
KV_MIGRATE = "kv.migrate"
KV_SPILL = "kv.spill"
KV_FETCH = "kv.fetch"
KV_GROW = "kv.grow"
KV_SHRINK = "kv.shrink"
PREFIX_HIT = "prefix.hit"
PREFIX_INSERT = "prefix.insert"
PREFIX_EVICT = "prefix.evict"
RETIER = "retier"
RETIER_RECLAIM = "retier.reclaim"
PLANNER_FIRED = "planner.fired"
ENGINE_PREFILL = "engine.prefill"
ENGINE_DECODE = "engine.decode"
ENGINE_VERIFY = "engine.verify"
ENGINE_DRAFT = "engine.draft"
ENGINE_SEED = "engine.seed"
ENGINE_RETIER = "engine.retier"
# fleet router (DESIGN.md §16; track "router")
FLEET_ROUTE = "fleet.route"
FLEET_SPILLOVER = "fleet.spillover"
FLEET_DRAIN = "fleet.drain"
FLEET_DRAINED = "fleet.drained"
FLEET_JOIN = "fleet.join"
# SLO engine (DESIGN.md §17; track "slo")
SLO_BREACH = "slo.breach"
SLO_RECOVER = "slo.recover"
# measured-profile autotuner (DESIGN.md §18; track "tune")
TUNE_REFIT = "tune.refit"

# tracks
TRACK_SCHED = "sched"
TRACK_PIPELINE = "pipeline"
TRACK_KV = "kv"
TRACK_PREFIX = "prefix"
TRACK_ENGINE = "engine"
TRACK_ROUTER = "router"
TRACK_SLO = "slo"
TRACK_TUNE = "tune"


def req_track(rid: int) -> str:
    return f"req:{rid}"


def dev_track(i: int) -> str:
    return f"dev:{i}"


def loader_track(i: int) -> str:
    return f"dev:{i}:loader"


class Tracer:
    """Ring-buffered flight recorder. All timestamps are seconds on
    `clock` (monotonic by default; serving binds it to the backend's
    clock so sim traces carry virtual time)."""

    def __init__(self, capacity: int = 1 << 16,
                 clock: Callable[[], float] = time.monotonic,
                 namespace: Optional[str] = None):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.clock = clock
        # track-name namespace: when N backends trace into ONE ring (the
        # fleet layer) their "sched"/"kv"/"req:0" tracks collide — a
        # namespace "r1" rewrites them to "r1:sched" etc. at push time,
        # and the Chrome exporter maps each rN: group to its own Perfetto
        # process. The fleet executor flips this per replica step.
        self.namespace = namespace
        self.buf: deque = deque(maxlen=capacity)
        self.dropped = 0          # events the ring evicted (wraparound)
        self.emitted = 0          # events ever recorded

    # -- recording ---------------------------------------------------------------
    def now(self) -> float:
        return self.clock()

    def _push(self, evt: Event) -> None:
        ns = self.namespace
        if ns is not None:
            evt = (evt[0], evt[1], evt[2], evt[3],
                   ns + ":" + evt[EVT_TRACK], evt[5])
        if len(self.buf) == self.capacity:
            self.dropped += 1
        self.emitted += 1
        self.buf.append(evt)

    def instant(self, name: str, *, ts: Optional[float] = None,
                track: str = TRACK_SCHED, args: Optional[dict] = None) -> None:
        self._push((name, "i", self.clock() if ts is None else ts,
                    0.0, track, args))

    def complete(self, name: str, *, ts: float, dur: float,
                 track: str = TRACK_SCHED,
                 args: Optional[dict] = None) -> None:
        """One finished span (ph "X"): ts..ts+dur."""
        self._push((name, "X", ts, max(dur, 0.0), track, args))

    def begin(self, name: str, *, ts: Optional[float] = None,
              track: str = TRACK_SCHED, args: Optional[dict] = None) -> None:
        self._push((name, "B", self.clock() if ts is None else ts,
                    0.0, track, args))

    def end(self, name: str, *, ts: Optional[float] = None,
            track: str = TRACK_SCHED) -> None:
        self._push((name, "E", self.clock() if ts is None else ts,
                    0.0, track, None))

    def counter(self, name: str, *, ts: Optional[float] = None,
                track: str = TRACK_SCHED, **values: float) -> None:
        self._push((name, "C", self.clock() if ts is None else ts,
                    0.0, track, values))

    @contextmanager
    def span(self, name: str, *, track: str = TRACK_SCHED,
             args: Optional[dict] = None):
        """Wall-span context manager on the tracer clock (engine paths);
        discrete-event code passes explicit ts/dur via complete()."""
        t0 = self.clock()
        try:
            yield self
        finally:
            self.complete(name, ts=t0, dur=self.clock() - t0,
                          track=track, args=args)

    # -- reading -----------------------------------------------------------------
    def events(self) -> List[Event]:
        return list(self.buf)

    def clear(self) -> None:
        self.buf.clear()
        self.dropped = 0
        self.emitted = 0

    def __len__(self) -> int:
        return len(self.buf)

    # -- export (delegates; repro.obs.exporters owns the formats) ----------------
    def export(self, path: str) -> None:
        """Write the buffer to `path`: Chrome trace-event JSON
        (Perfetto-loadable) unless the suffix is .jsonl (append-only
        JSONL for post-hoc analysis)."""
        from repro.obs.exporters import export_chrome, export_jsonl
        if str(path).endswith(".jsonl"):
            export_jsonl(self, path)
        else:
            export_chrome(self, path)


# ----------------------------------------------------------------------------
# global installation: instrumented code pays one global read + None check
# ----------------------------------------------------------------------------
_tracer: Optional[Tracer] = None


def get_tracer() -> Optional[Tracer]:
    """The installed tracer, or None (tracing off — the common case)."""
    return _tracer


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install (or uninstall with None) the process tracer; returns the
    previous one so callers can restore it."""
    global _tracer
    prev, _tracer = _tracer, tracer
    return prev


@contextmanager
def tracing(capacity: int = 1 << 16,
            clock: Callable[[], float] = time.monotonic):
    """Install a fresh Tracer for the duration of the block."""
    tr = Tracer(capacity=capacity, clock=clock)
    prev = set_tracer(tr)
    try:
        yield tr
    finally:
        set_tracer(prev)
