"""Shared benchmark scaffolding: paper environments, regimes, CSV output.

The paper's experiments run until KV pressure binds ("once the KV cache …
exhausts the available GPU memory, the system is considered memory-
saturated", §V-A). `pressure_prompt` reproduces that regime: the prompt is
sized so that prompt + generation crosses the fleet's KV budget partway
through the run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.baselines import BASELINES
from repro.core.cost_model import CostEnv, Workload
from repro.core.pipeline_sim import SimResult, simulate_lime
from repro.core.profiles import DeviceProfile, env_E1, env_E2, env_E3, mbps

N_TOKENS = 300          # generated tokens per measured run

ENVS = {
    "E1": ("llama2-13b", env_E1, 2),
    "E2": ("qwen3-32b", env_E2, 3),
    "E3": ("llama3.3-70b", env_E3, 4),
}

OOT_SPORADIC_S = 40.0
OOT_BURSTY_S = 15.0


def pressure_prompt(devices: List[DeviceProfile], cfg: ModelConfig,
                    w: Workload, n_tokens: int, frac: float = 1.0,
                    cap: int = 16384) -> int:
    """Prompt length such that KV crosses ~frac of the fleet's budget at
    the midpoint of generation — the paper's 'memory-saturated' regime
    (§V-A). Envs with huge slack hit `cap` instead and simply never
    saturate (reported as-is)."""
    agg = sum(d.mem_bytes for d in devices)
    model = cfg.total_params() * 2
    kv_rate = cfg.n_layers * w.kv_bytes_per_token_layer()
    if kv_rate <= 0:
        return 2048
    budget = max(agg - model, agg * 0.03) * frac / kv_rate
    return min(max(int(budget - n_tokens // 2), 1024), cap)


@dataclasses.dataclass
class Row:
    scenario: str
    method: str
    ms_per_token: float
    status: str = "ok"      # ok | oom | oot

    def csv(self) -> str:
        v = "" if self.status != "ok" else f"{self.ms_per_token:.1f}"
        return f"{self.scenario},{self.method},{v},{self.status}"


def run_scenario(name: str, devices, cfg: ModelConfig, *, bw_mbps: float,
                 pattern: str, n_micro: int, prompt: Optional[int] = None,
                 n_tokens: int = N_TOKENS,
                 bandwidth_schedule=None) -> List[Row]:
    """LIME + all six baselines on one (env, bandwidth, pattern) point."""
    oot = OOT_SPORADIC_S if pattern == "sporadic" else OOT_BURSTY_S
    w0 = Workload(cfg, mb=1, ctx=1, n_micro=n_micro)
    P = prompt if prompt is not None else \
        pressure_prompt(devices, cfg, w0, n_tokens)
    w = Workload(cfg, mb=1, ctx=P, n_micro=n_micro)
    env = CostEnv(devices, mbps(bw_mbps), w)
    rows = []
    lime = simulate_lime(env, cfg.n_layers, n_tokens, n_micro=n_micro,
                         n_emp=P, prompt=P, oot_s_per_token=oot,
                         bandwidth_schedule=bandwidth_schedule)
    rows.append(_row(name, "LIME", lime))
    for bname, fn in BASELINES.items():
        r = fn(env, cfg.n_layers, n_tokens, n_micro=n_micro, prompt=P,
               oot_s_per_token=oot)
        rows.append(_row(name, bname, r))
    return rows


def _row(scenario: str, method: str, r: SimResult) -> Row:
    if r.oom:
        return Row(scenario, method, float("inf"), "oom")
    if r.oot:
        return Row(scenario, method, float("inf"), "oot")
    return Row(scenario, method, r.ms_per_token)


def speedup_table(rows: List[Row]) -> Dict[str, Dict[str, str]]:
    by_scenario: Dict[str, Dict[str, Row]] = {}
    for r in rows:
        by_scenario.setdefault(r.scenario, {})[r.method] = r
    out = {}
    for sc, methods in by_scenario.items():
        lime = methods.get("LIME")
        out[sc] = {}
        for m, r in methods.items():
            if r.status != "ok":
                out[sc][m] = r.status.upper()
            elif lime and lime.status == "ok":
                out[sc][m] = f"{r.ms_per_token / lime.ms_per_token:.2f}x"
    return out
