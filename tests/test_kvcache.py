"""Paged KV-cache subsystem (DESIGN.md §10): allocator/pool/manager
invariants, the paged decode-attention bit-wise contract, paged decode
losslessness, and page-granular scheduler admission with preemption."""
import collections
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kvcache import (BlockTable, OutOfPages, PageAllocator,
                           PagedKVConfig, PagedKVManager, PagePool)
from repro.kvcache.pool import DEVICE, HOST


# ----------------------------------------------------------------------------
# allocator + block tables
# ----------------------------------------------------------------------------
def test_allocator_lifo_reuse_and_refcounts():
    a = PageAllocator(4, page_size=8)
    p0, p1 = a.alloc(), a.alloc()
    assert (p0, p1) == (0, 1) and a.used_pages == 2
    a.incref(p0)
    a.decref(p0)
    assert a.refcount(p0) == 1          # still held
    a.decref(p0)
    assert a.free_pages == 3
    assert a.alloc() == p0              # LIFO: freshest page comes back

    with pytest.raises(ValueError):
        a.decref(3)                     # never allocated


def test_allocator_all_or_nothing():
    a = PageAllocator(3, page_size=8)
    a.alloc()
    with pytest.raises(OutOfPages):
        a.alloc_many(3)
    assert a.free_pages == 2            # nothing was partially grabbed
    assert a.pages_for(17) == 3 and a.pages_for(16) == 2 and \
        a.pages_for(0) == 0


def test_block_table_growth_and_partial_last_page():
    a = PageAllocator(8, page_size=4)
    t = BlockTable(4)
    assert t.extend_to(6, a) == [0, 1]  # 6 tokens -> 2 pages
    assert t.tokens == 6 and t.capacity_tokens == 8
    assert t.append_token(a) is None    # slot 7 fits the last page
    assert t.append_token(a) is None
    assert t.append_token(a) == 2       # token 9 crosses the boundary
    assert t.slot_of(5) == (1, 1)
    with pytest.raises(ValueError):
        t.extend_to(3, a)               # tables never shrink
    t.release(a)
    assert a.free_pages == 8


def test_block_table_fork_shares_pages():
    a = PageAllocator(4, page_size=4)
    t = BlockTable(4)
    t.extend_to(8, a)
    f = t.fork(a)
    assert f.pages == t.pages and a.refcount(t.pages[0]) == 2
    t.release(a)
    assert a.used_pages == 2            # fork still holds them
    f.release(a)
    assert a.free_pages == 4


# ----------------------------------------------------------------------------
# two-tier pool
# ----------------------------------------------------------------------------
def test_pool_tier_capacity_and_migration_bytes():
    pool = PagePool(PagedKVConfig(page_size=4, device_pages=3, host_pages=2,
                                  page_bytes=100.0))
    t = BlockTable(4)
    pool.extend_table(t, 12)            # 3 pages: device tier full
    with pytest.raises(OutOfPages):
        pool.alloc_pages(1, DEVICE)
    moved = pool.migrate(t.pages[:2], HOST)
    assert moved == 200.0 and pool.pages_in_use(HOST) == 2
    assert pool.pages_in_use(DEVICE) == 1 and pool.free_pages(DEVICE) == 2
    assert pool.migrate(t.pages[:2], HOST) == 0.0      # already there
    with pytest.raises(OutOfPages):                    # host tier full
        pool.migrate([t.pages[2]], HOST)
    assert pool.fetch_table(t) == 200.0                # all back on device
    assert pool.spilled_pages == 2 and pool.fetched_pages == 2
    pool.release_table(t)
    assert pool.pages_in_use(DEVICE) == 0


def test_pool_migrate_any_clamps():
    pool = PagePool(PagedKVConfig(page_size=4, device_pages=4, host_pages=1,
                                  page_bytes=10.0))
    t = BlockTable(4)
    pool.extend_table(t, 16)
    assert pool.migrate_any(3, HOST) == 10.0    # host capacity clamps to 1
    assert pool.migrate_any(5, DEVICE) == 10.0  # source supply clamps to 1


# ----------------------------------------------------------------------------
# manager: admission, preemption, resumption, Eq. 8 delegation
# ----------------------------------------------------------------------------
def _mgr(dev=6, host=6, ps=4, page_bytes=8.0):
    return PagedKVManager(PagePool(PagedKVConfig(
        page_size=ps, device_pages=dev, host_pages=host,
        page_bytes=page_bytes)))


def test_manager_admit_extend_release():
    m = _mgr()
    assert m.admit(1, 5)                # 2 pages
    assert m.admit(2, 9)                # 3 pages
    assert not m.admit(3, 9)            # would need 3, only 1 free
    assert m.device_pages_in_use() == 5
    assert m.extend(1, 8)               # still 2 pages
    assert not m.extend(1, 13)          # needs 2 more, only 1 free
    assert m.pages_of(1) == 2           # failed extend left no residue
    m.release(2)
    assert m.extend(1, 13)
    m.release(1)
    assert m.device_pages_in_use() == 0


def test_manager_headroom_watermark():
    m = _mgr(dev=4)
    assert m.can_admit(4, headroom_pages=3)
    assert not m.can_admit(4, headroom_pages=4)


def test_manager_spill_preempt_and_resume():
    m = _mgr(dev=4, host=4)
    m.admit(1, 8)                       # 2 pages
    m.admit(2, 8)                       # 2 pages, device full
    moved = m.preempt(2, "spill")
    assert moved == 16.0 and m.is_suspended(2)
    assert m.pool.pages_in_use(HOST) == 2
    assert m.extend(1, 16)              # freed device room
    assert not m.can_resume(2)          # device full again
    m.release(1)
    assert m.resume(2) == 16.0          # fetched back, priced
    assert not m.is_suspended(2) and m.tokens_of(2) == 8


def test_manager_recompute_preempt_and_resume():
    m = _mgr(dev=4, host=0)
    m.admit(1, 8)
    m.admit(2, 8)
    assert m.preempt(2, "recompute") == 0.0
    assert m.pages_of(2) == 0 and m.tokens_of(2) == 8   # span remembered
    m.release(1)
    assert m.resume(2) == 0.0
    assert m.pages_of(2) == 2 and m.tokens_of(2) == 8


def test_manager_spill_falls_back_to_recompute_when_host_full():
    m = _mgr(dev=4, host=1)
    m.admit(1, 8)                       # 2 pages > 1 host page
    assert m.preempt(1, "spill") == 0.0
    assert m.pages_of(1) == 0           # dropped, not leaked
    assert m.pool.pages_in_use(HOST) == 0
    assert m.resume(1) == 0.0 and m.pages_of(1) == 2


def test_manager_delegate_tail_partial_page_rounds_down():
    m = _mgr(dev=6, host=6, ps=4)
    m.admit(1, 10)                      # 3 pages, last holds 2 tokens
    assert m.delegate_tail(1, 3) == 0.0         # < 1 whole page
    assert m.delegate_tail(1, 9) == 16.0        # 2 whole pages move
    assert m.pool.pages_in_use(HOST) == 2
    assert m.resident_tokens(1) == 4            # 1 device page remains


# ----------------------------------------------------------------------------
# COW fork / truncate / preempt / resume: refcount leak-freedom (property)
# ----------------------------------------------------------------------------
def _refcount_consistent(pool, tree, tables):
    """Every page's allocator refcount equals how many owners actually
    name it: live block tables + the radix tree."""
    counts = collections.Counter()
    for t in tables:
        counts.update(t.pages)
    if tree is not None:
        for node in tree._iter_nodes():
            counts[node.page] += 1
    for pid in range(pool.alloc.n_pages):
        assert pool.alloc.refcount(pid) == counts.get(pid, 0), pid


@st.composite
def _kv_ops(draw):
    """A mixed workload: admissions (cold or over a radix match of a
    shared template prompt), extension, speculative truncate_to rollback,
    preemption (spill and recompute), resumption, eviction, and finishes
    that donate pages back to the tree."""
    n = draw(st.integers(5, 30))
    return [(draw(st.sampled_from(["admit", "extend", "truncate",
                                   "preempt", "resume", "finish",
                                   "evict"])),
             draw(st.integers(0, 2 ** 16))) for _ in range(n)]


@settings(max_examples=40, deadline=None)
@given(_kv_ops())
def test_fork_cow_no_refcount_leaks_property(ops):
    """BlockTable.fork COW semantics under preempt/spill/resume and
    truncate_to rollback: whatever interleaving runs, (a) refcounts always
    equal the set of actual owners, (b) shared prefix pages are never
    dropped while the tree or another table holds them, and (c) after all
    requests finish, the allocator holds exactly the live radix pages —
    zero leaks."""
    from repro.prefixcache import RadixPrefixCache

    ps = 4
    pool = PagePool(PagedKVConfig(page_size=ps, device_pages=14,
                                  host_pages=10, page_bytes=4.0))
    tree = RadixPrefixCache(pool)
    mgr = PagedKVManager(pool)
    prompts = {}                        # rid -> token list
    next_rid = [0]

    def template(tid, n):
        return [1000 + tid * 64 + i for i in range(n)]

    for op, arg in ops:
        live = list(prompts)
        if op == "admit":
            tid = arg % 3
            plen = 4 + arg % 13
            toks = template(tid, plen)
            pages, ctok = tree.match(toks, max_pages=(plen - 1) // ps)
            total = plen + 1
            if mgr.can_admit_prefix(total, pages):
                rid = next_rid[0]
                next_rid[0] += 1
                mgr.admit_with_prefix(rid, pages, ctok, total)
                assert mgr.table(rid).pages[:len(pages)] == pages
                prompts[rid] = toks
        elif op == "extend" and live:
            rid = live[arg % len(live)]
            if not mgr.is_suspended(rid):
                mgr.extend(rid, mgr.tokens_of(rid) + 1 + arg % 3)
        elif op == "truncate" and live:
            rid = live[arg % len(live)]
            if not mgr.is_suspended(rid) and mgr.table(rid).pages:
                mgr.truncate(rid, arg % (mgr.tokens_of(rid) + 1))
        elif op == "preempt" and live:
            rid = live[arg % len(live)]
            if not mgr.is_suspended(rid):
                mgr.preempt(rid, "spill" if arg % 2 else "recompute")
        elif op == "resume" and live:
            rid = live[arg % len(live)]
            if mgr.is_suspended(rid):
                mgr.resume(rid)
        elif op == "finish" and live:
            rid = live[arg % len(live)]
            t = mgr.table(rid)
            gen = [2 ** 20 + rid * 64 + i
                   for i in range(max(t.tokens - len(prompts[rid]), 0))]
            tree.insert(prompts[rid] + gen, t.pages, n_tokens=t.tokens)
            mgr.release(rid)
            del prompts[rid]
        elif op == "evict":
            tree.evict(arg % 4)
        _refcount_consistent(pool, tree,
                             [mgr.table(r) for r in prompts])

    for rid in list(prompts):
        mgr.release(rid)
    assert pool.alloc.used_pages == tree.n_pages
    tree.release_all()
    assert pool.alloc.used_pages == 0
    assert pool.pages_in_use(DEVICE) == 0 and pool.pages_in_use(HOST) == 0


# ----------------------------------------------------------------------------
# paged decode attention: bit-wise contracts
# ----------------------------------------------------------------------------
def _random_paged_case(rng, B, KV, G, dh, ps, maxp, dtype):
    import jax.numpy as jnp
    P = B * maxp + 2
    q = jnp.asarray(rng.standard_normal((B, 1, KV * G, dh)), dtype)
    kp = jnp.asarray(rng.standard_normal((P, ps, KV, dh)), dtype)
    vp = jnp.asarray(rng.standard_normal((P, ps, KV, dh)), dtype)
    ctx = np.array([int(rng.integers(1, maxp * ps + 1)) for _ in range(B)])
    bt = -np.ones((B, maxp), np.int32)
    used = set()
    for b in range(B):                  # non-contiguous, interleaved pages
        for j in range(-(-int(ctx[b]) // ps)):
            p = int(rng.choice([x for x in range(P) if x not in used]))
            used.add(p)
            bt[b, j] = p
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(ctx, np.int32)


@pytest.mark.parametrize("window", [None, 11])
def test_paged_kernel_bitwise_vs_jnp_ref_bf16(window):
    """The kernel must equal the blocked jnp reference bit-for-bit at the
    model's cache dtype, for random non-contiguous block tables with
    partially-filled last pages."""
    import jax.numpy as jnp

    from repro.kernels.decode_attention.paged import (
        paged_decode_attention, paged_decode_attention_ref)

    rng = np.random.default_rng(0)
    for _ in range(4):
        B = int(rng.integers(1, 4))
        KV = int(rng.choice([1, 2]))
        G = int(rng.choice([1, 2, 4]))
        dh = int(rng.choice([16, 32, 64]))
        ps = int(rng.choice([8, 16]))
        maxp = int(rng.integers(1, 5))
        q, kp, vp, bt, ctx = _random_paged_case(rng, B, KV, G, dh, ps,
                                                maxp, jnp.bfloat16)
        out_k = paged_decode_attention(q, kp, vp, bt, ctx, window=window)
        out_r = paged_decode_attention_ref(q, kp, vp, bt, ctx,
                                           window=window)
        assert bool(jnp.all(out_k == out_r)), \
            (B, KV, G, dh, ps, maxp, window)


def test_paged_kernel_bitwise_vs_contiguous_kernel():
    """Gather losslessness at any dtype: the paged kernel on the pool ==
    the existing contiguous kernel on the gathered cache, bit-for-bit
    (same block walk, so the only difference is the table indirection)."""
    import jax.numpy as jnp

    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.paged import (
        gather_page_row, paged_decode_attention)

    rng = np.random.default_rng(1)
    for dtype in (jnp.float32, jnp.bfloat16):
        B, KV, G, dh, ps, maxp = 3, 2, 2, 32, 8, 3
        q, kp, vp, bt, ctx = _random_paged_case(rng, B, KV, G, dh, ps,
                                                maxp, dtype)
        out_p = paged_decode_attention(q, kp, vp, bt, ctx)
        for b in range(B):
            kc = gather_page_row(kp, bt[b])[None]
            vc = gather_page_row(vp, bt[b])[None]
            ids = np.arange(maxp * ps)
            pos_ids = jnp.asarray(np.where(ids < int(ctx[b]), ids, -1),
                                  np.int32)
            o = decode_attention(q[b:b + 1], kc, vc, pos_ids,
                                 jnp.int32(int(ctx[b]) - 1), block_k=ps)
            assert bool(jnp.all(o == out_p[b:b + 1])), (dtype, b)


def test_paged_ref_matches_full_softmax_oracle():
    """Semantics: the blocked walk == the model's full-softmax decode
    reference on the gathered cache (float tolerance — different
    algorithm, same math)."""
    import jax.numpy as jnp

    from repro.kernels.decode_attention.paged import (
        gather_page_row, paged_decode_attention_ref)
    from repro.models.attention import decode_attention_ref

    rng = np.random.default_rng(2)
    B, KV, G, dh, ps, maxp = 2, 2, 2, 32, 8, 3
    q, kp, vp, bt, ctx = _random_paged_case(rng, B, KV, G, dh, ps, maxp,
                                            jnp.float32)
    out = paged_decode_attention_ref(q, kp, vp, bt, ctx)
    for b in range(B):
        kc = gather_page_row(kp, bt[b])[None]
        vc = gather_page_row(vp, bt[b])[None]
        ids = np.arange(maxp * ps)
        pos_ids = jnp.asarray(np.where(ids < int(ctx[b]), ids, -1),
                              np.int32)
        o = decode_attention_ref(q[b:b + 1], kc, vc, pos_ids,
                                 jnp.int32(int(ctx[b]) - 1), window=None)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(out[b:b + 1], np.float32),
                                   atol=1e-5, rtol=1e-5)


# ----------------------------------------------------------------------------
# paged single-device decode: lossless vs decode_step
# ----------------------------------------------------------------------------
PAGED_DECODE_WORKER = r"""
import functools, sys
import jax, jax.numpy as jnp
jnp.bfloat16 = jnp.float32      # fp32 => losslessness must be (near-)exact
from repro.configs.registry import get_smoke_config
from repro.models import model as M
from repro.kvcache.paged_decode import PagedDecodeCache

fails = []
for arch in ("gemma3-1b", "internlm2-1.8b"):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    cast = lambda t: jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a, t)
    params = cast(M.init_params(cfg, key))
    B, S, max_len, ps = 2, 12, 32, 8
    toks = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    cache = cast(M.init_cache(cfg, B, max_len))
    logits, cache = jax.jit(functools.partial(M.prefill, cfg))(
        params, toks, cache)
    dec = jax.jit(functools.partial(M.decode_step, cfg))
    pc = PagedDecodeCache(cfg, B, max_len, page_size=ps)
    pc.seed(cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    worst = 0.0
    for step in range(14):              # crosses page boundaries
        rl, cache = dec(params, cache, tok)
        pl_ = pc.step(params, tok)
        worst = max(worst, float(jnp.abs(
            rl.astype(jnp.float32) - pl_.astype(jnp.float32)).max()))
        tok = jnp.argmax(rl[:, 0].astype(jnp.float32), -1)[:, None] \
            .astype(jnp.int32)
    used = pc.pool.pages_in_use()
    pc.release()
    ok = worst < 5e-4 and used == B * -(-(S + 14) // ps) \
        and pc.pool.pages_in_use() == 0
    print(f"{arch}: worst={worst:.2e} pages={used} {'OK' if ok else 'FAIL'}")
    if not ok:
        fails.append(arch)
sys.exit(1 if fails else 0)
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_paged_decode_lossless_vs_decode_step():
    """Engine-tier losslessness: paged decode (pool + block tables +
    paged attention) == the dense decode_step, with page accounting."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", PAGED_DECODE_WORKER], env=env,
                       capture_output=True, text=True, timeout=900)
    sys.stdout.write(r.stdout)
    sys.stderr.write(r.stderr[-2000:])
    assert r.returncode == 0


# ----------------------------------------------------------------------------
# scheduler: page-granular admission + preemption over the simulator
# ----------------------------------------------------------------------------
def _sim_backend(slots: int, prompt: int = 64):
    from repro.configs.registry import get_config
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.profiles import env_E3, mbps
    from repro.serving import SimBackend

    cfg = get_config("llama2-13b")
    w = Workload(cfg, mb=1, ctx=prompt, n_micro=slots)
    return SimBackend(CostEnv(env_E3(), mbps(200), w), n_slots=slots,
                      prompt_tokens=prompt)


def _serve(policy, preempt="spill", budget=None, slots=8, n_req=8,
           prompt=64, max_new=64):
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               requests_from_arrivals, summarize)
    from repro.serving.traffic import bursty

    arr = bursty(n_req, burst_size=n_req, gap_s=0.0, prompt_len=prompt,
                 max_new_tokens=max_new, seed=0)
    if budget is None:
        budget = 3 * (prompt + max_new)       # reservation fits 3
    sched = ContinuousBatchingScheduler(_sim_backend(slots), SchedulerConfig(
        kv_budget_tokens=budget, kv_policy=policy, page_size=16,
        preempt=preempt))
    done = sched.serve(requests_from_arrivals(arr))
    rep = summarize(done, pattern="bursty", backend="sim", stats=sched.stats)
    return done, rep


@pytest.mark.parametrize("preempt", ["spill", "recompute"])
def test_paged_admission_beats_reservation_and_completes(preempt):
    """The bench_kvcache acceptance invariant: same budget, same bursty
    stream — paged admission holds strictly more co-resident requests,
    every request still completes with its exact token count, and the
    preemption/page counters surface in the report."""
    done_r, rep_r = _serve("reserve")
    done_p, rep_p = _serve("paged", preempt)
    for done in (done_r, done_p):
        assert all(not r.rejected and r.done and
                   r.generated == r.max_new_tokens for r in done)
    assert rep_p.peak_active > rep_r.peak_active
    assert rep_p.n_preempted > 0
    assert rep_r.n_preempted == 0 and rep_r.kv_pages_spilled == 0
    if preempt == "spill":
        assert rep_p.kv_pages_spilled > 0
        assert rep_p.kv_migrated_bytes > 0
    assert rep_p.peak_kv_pages <= (3 * 128) // 16   # device tier respected


def test_paged_preempted_requests_keep_latency_accounting():
    """A preempted request's TTFT is its *first* emission; finish time
    reflects the preemption detour, it completes with its full count,
    and the recompute span is consumed (cleared) by the resume."""
    done, rep = _serve("paged", "recompute")
    pre = [r for r in done if r.preempted]
    assert pre, "tight budget must preempt someone"
    for r in pre:
        assert r.first_token_s is not None and r.finish_s is not None
        assert r.finish_s >= r.first_token_s
        assert r.generated == r.max_new_tokens
        assert r.restart_tokens == 0        # cleared on resume


def test_paged_oversized_gate_is_page_rounded():
    """A request whose worst case fits the token budget but not the
    page-floored pool is shed at intake, not admitted into per-token
    self-preemption churn."""
    from repro.serving import (ContinuousBatchingScheduler, Request,
                               SchedulerConfig)

    be = _sim_backend(1)
    # budget 100 tokens, page 16 -> 6 pages = 96 usable tokens
    sched = ContinuousBatchingScheduler(be, SchedulerConfig(
        kv_budget_tokens=100, kv_policy="paged", page_size=16))
    done = sched.serve([Request(0, None, max_new_tokens=36, prompt_len=64),
                        Request(1, None, max_new_tokens=32, prompt_len=64)])
    by = {r.rid: r for r in done}
    assert by[0].rejected                   # 100 tokens > 96-token pool
    assert by[1].done and by[1].generated == 32   # 96 tokens fits exactly


def test_planner_sees_page_occupancy():
    """SimBackend note_kv_pages feeds the OnlinePlanner page-rounded
    occupancy (on_pages pathway): planner tokens == pages * page_size."""
    be = _sim_backend(2)
    be._ctx = {0: 100, 1: 50}
    base = be._planner_tokens()
    be.note_kv_pages(pages_in_use=20, page_size=16)
    n_micro_env = max(be.env.work.n_micro, 1)
    assert be._planner_tokens() == -(-(20 * 16) // n_micro_env)
    assert be._planner_tokens() != base


def test_online_planner_on_pages_hook():
    from repro.core.online_planner import OnlinePlanner

    be = _sim_backend(1)
    planner = OnlinePlanner(be.env, be.plan, horizon_tokens=2 ** 20)
    probe = OnlinePlanner(be.env, be.plan, horizon_tokens=2 ** 20)
    ts = min((lad[0].threshold_tokens for lad in probe.ladders if lad),
             default=None)
    if ts is None:
        pytest.skip("no thresholds for this fleet/arch")
    fired = planner.on_pages(ts // 16 + 1, 16)
    assert fired and all(isinstance(i, int) for i, _ in fired)


def test_kv_transfer_sync_pool_moves_and_clamps():
    """Eq. 8 volumes -> host-tier pages on the attached pool, clamped to
    the KV that actually exists; a volume drop migrates pages back."""
    be = _sim_backend(1, prompt=512)
    kv = be.sim.kv
    if kv is None or all(st.target is None for st in kv.states):
        pytest.skip("no delegating devices on this fleet")
    pool = PagePool(PagedKVConfig(page_size=16, device_pages=64,
                                  host_pages=64, page_bytes=4.0))
    t = BlockTable(16)
    pool.extend_table(t, 40 * 16)       # 40 device pages in use
    kv.init_transfers(ctx_tokens=4096)
    target = min(kv.delegated_pages(16), 40)
    moved = kv.sync_pool(pool)
    assert pool.pages_in_use(HOST) == target
    assert moved == pytest.approx(target * 4.0)
    for st in kv.states:                # volumes collapse -> pages return
        st.n_trans = 0
    kv.sync_pool(pool)
    assert pool.pages_in_use(HOST) == 0
