"""Continuous-batching scheduler for LIME-Serve (DESIGN.md §9, §10).

One scheduler in front of both execution substrates (engine and simulator,
behind the InferenceBackend protocol in `serving/backend.py`):

  admission   two policies (SchedulerConfig.kv_policy):
              "reserve" — a request is admitted only when the fleet's KV
              budget can hold its worst case (prompt + max_new tokens)
              alongside every co-resident request (paper Eq. 5 accounting).
              "paged"   — page-granular (DESIGN.md §10): admission
              allocates ceil((prompt+1)/page_size) pages from a two-tier
              PagePool and one page per page_size generated tokens after
              that, so co-residency is bounded by actual occupancy, not
              the worst case. When the pool runs dry mid-generation the
              latest-admitted request is preempted: its pages spill to
              the host tier (swap, fetched back on resume) or are dropped
              for recompute (resume re-prefills prompt + generated).
  queueing    FIFO past the admission gate; arrivals beyond `max_queue`
              are rejected (shed) rather than queued forever. Preempted
              requests resume ahead of fresh admissions.
  batching    up to `backend.n_slots` requests ride the pipeline's
              micro-batch slots. Backends that support it
              (`can_join_running`) refill freed slots mid-flight —
              continuous batching; epoch backends (the real engine, whose
              batch membership is fixed at cache-seed time) drain a batch,
              then form the next.

The loop is clock-agnostic: `backend.now()` is wall time for the engine
and virtual time for the simulator, so the same scheduler produces both
real measurements and discrete-event predictions.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.kvcache import PagedKVConfig, PagedKVManager, PagePool
from repro.obs import MetricsRegistry
from repro.obs import trace as tr_ev
from repro.obs.trace import get_tracer, req_track


@dataclasses.dataclass
class Request:
    """One serving request, from arrival to completion."""
    rid: int
    prompt: Optional[np.ndarray]    # (S,) int32 token ids; None -> length-only
    max_new_tokens: int
    arrival_s: float = 0.0
    prompt_len: int = 0
    output: List[int] = dataclasses.field(default_factory=list)
    generated: int = 0              # tokens emitted (simulated backends
                                    # emit steps without real token ids)
    done: bool = False
    rejected: bool = False
    preempted: int = 0              # times evicted mid-generation
    restart_tokens: int = 0         # recompute-resume: context to re-prefill
    cached_tokens: int = 0          # prompt tokens served from the radix
                                    # prefix cache (or kept through a spill
                                    # resume) — the backend prefills only
                                    # prefill_tokens - cached_tokens
    first_token_s: Optional[float] = None
    admitted_s: Optional[float] = None  # left the queue (TTFT split:
                                        # queue wait vs prefill compute)
    finish_s: Optional[float] = None
    session_id: Optional[int] = None    # multiturn conversation id — the
                                        # fleet router's stickiness key
                                        # (traffic.py stamps it)

    def __post_init__(self):
        if self.prompt is not None:
            self.prompt = np.asarray(self.prompt, np.int32)
            self.prompt_len = len(self.prompt)
        self.max_new_tokens = max(int(self.max_new_tokens), 1)

    @property
    def kv_tokens(self) -> int:
        """Worst-case KV footprint in tokens (reservation currency)."""
        return self.prompt_len + self.max_new_tokens

    @property
    def kv_tokens_now(self) -> int:
        """Actual KV occupancy in tokens (page-admission currency)."""
        return self.prompt_len + self.generated

    @property
    def prefill_tokens(self) -> int:
        """Context span the backend sees at (re-)admission: the prompt for
        a fresh request, prompt + generated for a resumed one (spill kept
        the KV — the re-entry step runs at the full context; recompute
        re-prefills the same span, its restart_tokens equals it)."""
        return self.prompt_len + self.generated

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.first_token_s is None \
            else self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.finish_s is None \
            else self.finish_s - self.arrival_s


def requests_from_arrivals(arrivals, *, start_rid: int = 0,
                           vocab_size: int = 32768,
                           seed: int = 0) -> List[Request]:
    """ArrivalEvents (traffic.py) -> Requests. Template-bearing events
    (shared_prefix / multiturn) materialize real token ids — the leading
    template_len tokens from the shared template stream, the rest unique
    per request — because the radix prefix cache keys on token content;
    plain events stay length-only."""
    from repro.serving.traffic import template_tokens
    out = []
    for i, ev in enumerate(arrivals):
        rid = start_rid + i
        prompt = None
        if ev.template_id is not None:
            shared = template_tokens(ev.template_id, ev.template_len,
                                     vocab_size=vocab_size, seed=seed)
            uniq = template_tokens(rid, ev.prompt_len - ev.template_len,
                                   vocab_size=vocab_size, seed=seed, salt=1)
            prompt = np.concatenate([shared, uniq])
        out.append(Request(rid, prompt, ev.max_new_tokens,
                           arrival_s=ev.time_s, prompt_len=ev.prompt_len,
                           session_id=getattr(ev, "session_id", None)))
    return out


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_queue: int = 4096                    # beyond this: shed (rejected)
    kv_budget_tokens: Optional[int] = None   # None -> ask the backend
    kv_policy: str = "reserve"               # "reserve" | "paged"
    page_size: int = 64                      # paged: tokens per page
    preempt: str = "spill"                   # paged: "spill" | "recompute"
    host_kv_budget_tokens: Optional[int] = None  # paged: spill-tier size
                                                 # (None -> device budget)
    prefix_cache: bool = False               # radix KV reuse (DESIGN.md
                                             # §12; needs kv_policy="paged"
                                             # and token-bearing requests)
    prefill_chunk_tokens: Optional[int] = None   # split prompt processing
                                                 # into chunks that ride
                                                 # mixed rounds with decode
                                                 # (None = monolithic)
    hist_capacity: Optional[int] = None      # bounded-memory histograms
                                             # (obs.sketch reservoir of
                                             # this many samples; None =
                                             # exact raw-sample mode)


class ContinuousBatchingScheduler:
    """Drives an InferenceBackend through an arrival stream."""

    def __init__(self, backend, config: SchedulerConfig = SchedulerConfig()):
        self.backend = backend
        self.config = config
        self._kv_in_use = 0
        budget = config.kv_budget_tokens
        if budget is None:
            budget = backend.kv_budget_tokens()
        self.kv_budget = budget               # None -> unbounded
        # per-request ceiling (e.g. the engine's statically-shaped per-slot
        # cache): pooled headroom must not admit an over-long request
        cap_fn = getattr(backend, "max_request_tokens", None)
        self.max_request = cap_fn() if cap_fn else None
        # optional batch-composition constraint (engine: left-padding
        # makes co-scheduled requests share position space)
        self._fits_batch = getattr(backend, "fits_batch", None)
        # page-granular admission state (DESIGN.md §10)
        assert config.kv_policy in ("reserve", "paged"), config.kv_policy
        self.paged = config.kv_policy == "paged" and budget is not None
        self.mgr: Optional[PagedKVManager] = None
        if self.paged:
            host = config.host_kv_budget_tokens
            host = budget if host is None else host
            self.mgr = PagedKVManager(PagePool(PagedKVConfig(
                page_size=config.page_size,
                device_pages=budget // config.page_size,
                host_pages=host // config.page_size,
                page_bytes=self._page_bytes())))
            # let the simulator move Eq. 8 volumes on this pool (see
            # core/kv_transfer.sync_pool; no-op for wall-clock backends)
            attach = getattr(backend, "attach_page_pool", None)
            if attach:
                attach(self.mgr.pool)
        # tokens a live request can commit in ONE decode round: 1, or up
        # to k+1 when the backend decodes speculatively (DESIGN.md §11) —
        # paged growth must reserve the whole round, or admission reads
        # stale occupancy and admits into guaranteed preemption churn
        self._round_tokens = 1 + getattr(getattr(backend, "spec", None),
                                         "k", 0)
        # radix prefix cache (DESIGN.md §12): shares the paged pool —
        # matched prompt prefixes fork COW into fresh block tables and
        # only the uncached suffix is prefilled
        if config.prefix_cache and not self.paged:
            raise ValueError("prefix_cache needs kv_policy='paged' "
                             "(the radix tree shares the page pool)")
        self.prefix = None
        if config.prefix_cache and self.paged:
            from repro.prefixcache import RadixPrefixCache
            self.prefix = RadixPrefixCache(self.mgr.pool)
        # chunked prefill (DESIGN.md §12): prompts are processed
        # prefill_chunk_tokens at a time in mixed rounds alongside live
        # decode streams — only on substrates that expose decode_mixed
        # (the simulator); epoch backends chunk inside their own prefill
        self.chunk = config.prefill_chunk_tokens
        self._mixed = getattr(backend, "decode_mixed", None) \
            if self.chunk else None
        self._fill: Dict[int, int] = {}   # rid -> prefill tokens remaining
        # preemption events are counted on the Request records themselves
        # (summarize sums Request.preempted — single source of truth).
        # Typed instruments (DESIGN.md §15); `stats` below keeps the
        # legacy flat-dict view for tests/benches that read it directly.
        self.metrics = MetricsRegistry(hist_capacity=config.hist_capacity)
        for k in ("kv_pages_spilled", "kv_pages_fetched",
                  "kv_migrated_bytes", "prefix_lookups", "prefix_hits",
                  "cached_tokens", "prefill_tokens_saved",
                  "prefix_pages", "prefix_evicted_pages"):
            self.metrics.counter(k)
        self.metrics.gauge("peak_active")
        self.metrics.gauge("peak_kv_pages")
        # flight recorder (DESIGN.md §15): when a tracer is installed,
        # slave its clock to the backend's — virtual time for the
        # simulator, wall time for the engine — so every event this run
        # emits shares one timebase and both substrates render identically
        self._tr = get_tracer()
        if self._tr is not None:
            self._tr.clock = backend.now
        # online SLO engine (DESIGN.md §17): attach_slo installs one;
        # finishes and rejections feed its burn-rate windows, and its
        # pressure signal reaches the backend's OnlinePlanner
        self.slo = None
        self._slo_pressure_fn = getattr(backend, "note_slo_pressure", None)
        # empty run state so load signals (queue_depth / in_flight /
        # outstanding) read sanely before begin() installs a stream
        self.begin([])

    @property
    def stats(self) -> Dict[str, float]:
        """Legacy flat stats view (the registry is the source of truth)."""
        return self.metrics.to_stats_dict()

    def attach_slo(self, engine) -> None:
        """Install an obs.slo.SLOEngine: every finish/reject from now on
        feeds its burn-rate windows (DESIGN.md §17)."""
        self.slo = engine

    def _note_slo(self, req: Request, now: float,
                  rejected: bool = False) -> None:
        if self.slo is None:
            return
        if rejected:
            self.slo.observe_reject(req, now)
        else:
            self.slo.observe_request(req, now)
        if self._slo_pressure_fn is not None:
            self._slo_pressure_fn(self.slo.pressure())

    def _page_bytes(self) -> float:
        fn = getattr(self.backend, "kv_bytes_per_token", None)
        return (fn() if fn else 0.0) * self.config.page_size

    # -- admission -------------------------------------------------------------
    def _lookup(self, req: Request):
        """Radix match for `req`'s prompt, capped below the last prompt
        token (page-aligned) so at least one token is always prefilled —
        the logits that seed its first sampled token. Returns (shared
        page ids, matched token count)."""
        if self.prefix is None or req.prompt is None or req.preempted:
            # a resumed request re-enters with its own pages (spill) or a
            # pending recompute span — prefix forking would double-count
            return [], 0
        cap = (req.prompt_len - 1) // self.config.page_size
        return self.prefix.match(req.prompt, max_pages=cap)

    def _admits(self, req: Request, active_count: int = 0) -> bool:
        if self.kv_budget is None:
            return True
        if self.paged:
            # watermark: keep one free page per already-resident request
            # (they each want another page within page_size steps) —
            # admitting into the last pages guarantees preemption churn
            need = req.prefill_tokens + 1
            if self.prefix is not None:
                # a prefix hit only needs pages for the uncached suffix —
                # admitting it as if cold under-fills the batch
                pages, _ = self._lookup(req)
                if self.mgr.can_admit_prefix(need, pages,
                                             headroom_pages=active_count):
                    return True
                # pool pressure: cached pages are the first to go —
                # reclaim unpinned radix leaves before refusing admission
                # (cold-requirement bound: >= the hit's actual shortfall)
                short = self.mgr.pool.pages_for(need) \
                    + active_count - self.mgr.pool.free_pages()
                if short > 0 and self._evict_cached(short):
                    pages, _ = self._lookup(req)   # eviction may have
                    if self.mgr.can_admit_prefix(  # pruned the match
                            need, pages, headroom_pages=active_count):
                        return True
                # same ordering as the cold path: retier headroom is the
                # step between radix eviction and refusing admission.
                # Shortfall from the PREFIX requirement — only the
                # uncached suffix needs fresh pages (the cold bound would
                # over-demote by the cached-prefix page count)
                pages, _ = self._lookup(req)
                short = self.mgr.pool.pages_for(need) - len(pages) \
                    + active_count - self.mgr.pool.free_pages()
                if short > 0 and self._reclaim(short):
                    pages, _ = self._lookup(req)
                    return self.mgr.can_admit_prefix(
                        need, pages, headroom_pages=active_count)
                return False
            if self.mgr.can_admit(need, headroom_pages=active_count):
                return True
            # retier headroom (DESIGN.md §13): before refusing, ask the
            # backend to demote resident layers — their HBM grows the
            # device tier, so a burst is absorbed without queueing
            short = self.mgr.pool.pages_for(need) + active_count \
                - self.mgr.pool.free_pages()
            if short > 0 and self._reclaim(short):
                return self.mgr.can_admit(need, headroom_pages=active_count)
            return False
        return self._kv_in_use + req.kv_tokens <= self.kv_budget

    def _reclaim(self, n_pages: int) -> int:
        """Ask the backend for retier headroom (demote resident layers ->
        device KV pages; no-op on backends without online adaptation).
        Ordered after radix eviction and before preemption: cached pages
        serve future hits, retiering costs steady-state load, preemption
        costs a live request its progress."""
        fn = getattr(self.backend, "reclaim_kv_pages", None)
        if fn is None:
            return 0
        got = fn(n_pages)
        if got:
            self.metrics.inc("retier_reclaimed_pages", got)
            if self._tr is not None:
                self._tr.instant(tr_ev.RETIER_RECLAIM, track=tr_ev.TRACK_KV,
                                 args={"pages": got, "asked": n_pages})
        return got

    def _evict_cached(self, n_pages: int) -> int:
        """Reclaim device-tier radix pages (the callers are starved for
        *device* capacity — host-tier cached leaves would free the wrong
        tier and loop the evict-retry paths to no effect)."""
        if self.prefix is None:
            return 0
        from repro.kvcache.pool import DEVICE
        freed = self.prefix.evict(n_pages, tier=DEVICE)
        self.metrics.set("prefix_evicted_pages", self.prefix.evicted_pages)
        return freed

    def _on_admit(self, req: Request) -> None:
        if self.paged:
            if self.prefix is not None:
                pages, ctok = self._lookup(req)
                moved = self.mgr.admit_with_prefix(
                    req.rid, pages, ctok, req.prefill_tokens + 1)
                self._charge(moved)
                req.cached_tokens = ctok
                # hit accounting per *admission* (the tree's own lookup
                # counters also see head-of-line re-checks)
                self.metrics.inc("prefix_lookups")
                self.metrics.inc("prefix_hits", int(ctok > 0))
                self.metrics.inc("prefill_tokens_saved", ctok)
            else:
                self.mgr.admit(req.rid, req.prefill_tokens + 1)
        else:
            self._kv_in_use += req.kv_tokens

    def _on_finish(self, req: Request) -> None:
        if self.paged:
            self._maybe_insert(req)
            self.mgr.release(req.rid)
        else:
            self._kv_in_use -= req.kv_tokens

    def _maybe_insert(self, req: Request) -> None:
        """Donate `req`'s committed pages to the radix tree (insert on
        finish and on spec-decode commit boundaries): keys are the tokens
        whose ids we actually know — the prompt plus any real emitted ids
        (the simulator emits None placeholders, which cannot key a page)."""
        if self.prefix is None or req.prompt is None:
            return
        toks = list(req.prompt)
        for t in req.output:
            if t is None:
                break
            toks.append(t)
        table = self.mgr.table(req.rid)
        self.prefix.insert(toks, table.pages,
                           n_tokens=min(len(toks), table.tokens))

    def _oversized(self, req: Request) -> bool:
        """Can never be served, even on an idle fleet (both policies cap
        a lone request at the device KV budget — paged mode never spills
        a request's own working set). Paged capacity is page-rounded:
        floor(budget/page_size) whole pages, less than the token budget —
        a request that fits the tokens but not the pages would otherwise
        self-preempt on every token past the last page boundary."""
        if self.max_request is not None and req.kv_tokens > self.max_request:
            return True
        if self.kv_budget is None:
            return False
        if self.paged:
            return self.mgr.pool.pages_for(req.kv_tokens) \
                > self.mgr.pool.cfg.device_pages
        return req.kv_tokens > self.kv_budget

    def _note_occupancy(self, active_count: int) -> None:
        self.metrics.set_gauge("peak_active", active_count)
        if self._tr is not None:
            self._tr.counter("active_requests", track=tr_ev.TRACK_SCHED,
                             active=active_count)
        if self.paged:
            pages = self.mgr.device_pages_in_use()
            self.metrics.set_gauge("peak_kv_pages", pages)
            if self._tr is not None:
                self._tr.counter("kv_pages", track=tr_ev.TRACK_KV,
                                 device=pages)
            note = getattr(self.backend, "note_kv_pages", None)
            if note:
                note(pages, self.config.page_size)

    def _charge(self, nbytes: float) -> None:
        if nbytes:
            fn = getattr(self.backend, "charge_transfer", None)
            if fn:
                fn(nbytes)

    # -- paged growth + preemption ----------------------------------------------
    def _grow_active(self, active: Dict[int, Request],
                     order: List[int], suspended: Deque[Request]) -> None:
        """Before a decode step every live request needs room for one more
        round of tokens (1, or a whole speculative commit). On a dry
        pool, preempt latest-admitted victims (vLLM-style) until the
        extension fits; a request that cannot even self-extend after
        evicting everyone else suspends itself (can't happen while
        _oversized() gates admission, kept as a defensive terminal)."""
        for slot in list(sorted(active, key=lambda s: order.index(s))):
            r = active.get(slot)
            if r is None:
                continue
            grow_to = r.kv_tokens_now + min(self._round_tokens,
                                            max(r.max_new_tokens
                                                - r.generated, 1))
            while not self.mgr.extend(r.rid, grow_to):
                # reclamation order under pressure (DESIGN.md §12): unpinned
                # radix-cached pages first — they serve future hits, not a
                # live decode — and only then preempt a victim
                need = self.mgr.pool.pages_for(grow_to) \
                    - self.mgr.pages_of(r.rid)
                if self._evict_cached(need):
                    continue
                # reclaim only the SHORTFALL past the free pages — the
                # gross requirement would over-demote resident layers
                # (permanent extra per-segment load for pages the pool
                # already had)
                short = need - self.mgr.pool.free_pages()
                if short > 0 and self._reclaim(short):
                    continue
                victims = [s for s in sorted(active,
                                             key=lambda s: order.index(s),
                                             reverse=True) if s != slot]
                victim = victims[0] if victims else slot
                self._preempt(victim, active, suspended)
                if victim == slot:
                    break

    def _preempt(self, slot: int, active: Dict[int, Request],
                 suspended: Deque[Request]) -> None:
        r = active.pop(slot)
        r.preempted += 1
        moved = self.mgr.preempt(r.rid, self.config.preempt)
        self._charge(moved)
        if not self.mgr.table(r.rid).pages:   # recompute (or spill fallback)
            r.restart_tokens = r.kv_tokens_now
        if self._tr is not None:
            mode = "spill" if self.mgr.table(r.rid).pages else "recompute"
            self._tr.instant(tr_ev.REQ_PREEMPT, track=req_track(r.rid),
                             args={"slot": slot, "mode": mode,
                                   "moved_bytes": moved})
        suspended.append(r)
        self.backend.release(slot)

    def _try_resume(self, req: Request) -> bool:
        kept = bool(self.mgr.table(req.rid).pages)   # spilled, not dropped
        moved = self.mgr.resume(req.rid)
        if moved is None:
            return False
        self._charge(moved)
        req.restart_tokens = 0        # resumed: no pending recompute span
        # a spill kept the KV: the re-entry step prefills nothing (the
        # backend prices one query); recompute re-prefills the whole span
        req.cached_tokens = req.kv_tokens_now if kept else 0
        if self._tr is not None:
            self._tr.instant(tr_ev.REQ_RESUME, track=req_track(req.rid),
                             args={"kept_kv": kept,
                                   "moved_bytes": moved})
        return True

    def _trace_lifecycle(self, r: Request) -> None:
        """Emit `r`'s lifecycle spans at completion, rebuilt from the
        timestamps the scheduler recorded anyway (arrival_s, admitted_s,
        first_token_s, finish_s). Emitting at finish — not live — means a
        long run's request spans survive ring wraparound: the flight
        recorder keeps the *most recent* N events, and one span per phase
        per request is cheap enough to always keep."""
        tr = self._tr
        track = req_track(r.rid)
        if r.admitted_s is not None:
            tr.complete(tr_ev.REQ_QUEUE, ts=r.arrival_s,
                        dur=r.admitted_s - r.arrival_s, track=track)
            if r.first_token_s is not None:
                tr.complete(tr_ev.REQ_PREFILL, ts=r.admitted_s,
                            dur=r.first_token_s - r.admitted_s,
                            track=track,
                            args={"prompt_len": r.prompt_len,
                                  "cached_tokens": r.cached_tokens})
        if r.first_token_s is not None and r.finish_s is not None:
            tr.complete(tr_ev.REQ_DECODE, ts=r.first_token_s,
                        dur=r.finish_s - r.first_token_s, track=track,
                        args={"generated": r.generated})
        if r.finish_s is not None:
            tr.complete(tr_ev.REQ_SPAN, ts=r.arrival_s,
                        dur=r.finish_s - r.arrival_s, track=track,
                        args={"prompt_len": r.prompt_len,
                              "generated": r.generated,
                              "preempted": r.preempted})
            tr.instant(tr_ev.REQ_FINISH, ts=r.finish_s, track=track)

    # -- main loop ---------------------------------------------------------------
    # serve() used to be one monolithic run-to-completion loop. It is now
    # a resumable state machine — begin() installs the run state, step()
    # executes ONE loop iteration (one admission wave or one decode
    # round), submit() delivers a new arrival mid-run, finish_run() does
    # the drain-time accounting — so a fleet executor (repro.fleet) can
    # co-step N replica schedulers in virtual-time order and read live
    # load signals (queue_depth / in_flight / free_kv_pages) between
    # steps. serve() composes them and behaves exactly as before.

    def begin(self, requests: List[Request]) -> None:
        """Install a run: requests sorted by arrival, nothing admitted."""
        self._pending: Deque[Request] = deque(
            sorted(requests, key=lambda r: r.arrival_s))
        self._q: Deque[Request] = deque()
        self._susp: Deque[Request] = deque()  # preempted, resume first
        self._active: Dict[int, Request] = {}  # slot -> request
        self._order: List[int] = []            # admission order of slots
        self._done: List[Request] = []
        self._shed: List[Request] = []

    def submit(self, req: Request) -> None:
        """Deliver one arrival into a running serve (fleet routing):
        keeps `_pending` sorted by arrival time."""
        p = self._pending
        if not p or req.arrival_s >= p[-1].arrival_s:
            p.append(req)
            return
        # rare out-of-order delivery: rebuild sorted (streams are small)
        items = sorted(list(p) + [req], key=lambda r: r.arrival_s)
        self._pending = deque(items)

    # -- live load signals (router scoring inputs) -------------------------------
    @property
    def queue_depth(self) -> int:
        """Requests accepted but not yet (re-)running."""
        return len(self._q) + len(self._susp)

    @property
    def in_flight(self) -> int:
        """Requests occupying pipeline slots right now."""
        return len(self._active)

    @property
    def outstanding(self) -> int:
        """Everything submitted and not yet finished or shed."""
        return len(self._pending) + len(self._q) + len(self._susp) \
            + len(self._active)

    def free_kv_pages(self) -> Optional[int]:
        """Device-tier KV headroom in pages (None: not page-managed)."""
        return self.mgr.pool.free_pages() if self.paged else None

    @property
    def has_live_work(self) -> bool:
        """Anything past intake: a step() now does real work regardless
        of the clock."""
        return bool(self._q or self._susp or self._active)

    @property
    def next_pending_s(self) -> Optional[float]:
        """Arrival time of the earliest not-yet-ingested request."""
        return self._pending[0].arrival_s if self._pending else None

    def now(self) -> float:
        return self.backend.now()

    # -- one-iteration helpers (instance-state versions of the old closures) -----
    def _reject(self, r: Request) -> None:
        r.rejected = True
        self._shed.append(r)
        if self._tr is not None:
            self._tr.instant(tr_ev.REQ_REJECT, track=req_track(r.rid),
                             args={"prompt_len": r.prompt_len})
        self._note_slo(r, self.backend.now(), rejected=True)

    def _intake(self, now: float) -> None:
        while self._pending and self._pending[0].arrival_s <= now:
            r = self._pending.popleft()
            if self._tr is not None:
                self._tr.instant(tr_ev.REQ_ARRIVE, ts=r.arrival_s,
                                 track=req_track(r.rid),
                                 args={"prompt_len": r.prompt_len,
                                       "max_new": r.max_new_tokens})
            if self._oversized(r) or len(self._q) >= self.config.max_queue:
                self._reject(r)
            else:
                self._q.append(r)

    def _next_candidate(self, batch):
        """Head-of-line pick: suspended (resume) before fresh."""
        n_resident = len(self._active) + len(batch)
        if self._susp:
            r = self._susp[0]
            if not self.mgr.can_resume(r.rid, headroom_pages=n_resident):
                return None
            if self._fits_batch is not None and batch \
                    and not self._fits_batch(batch, r):
                return None
            return "suspended"
        if self._q:
            r = self._q[0]
            if not self._admits(r, n_resident):
                return None
            if self._fits_batch is not None and batch \
                    and not self._fits_batch(batch, r):
                return None
            return "queue"
        return None

    def _pop_candidate(self, kind) -> Request:
        tr = self._tr
        if kind == "suspended":
            r = self._susp.popleft()
            self._try_resume(r)
            # the re-entry step emits a token; make room for its KV
            # (best effort — _grow_active preempts if this lost a race)
            self.mgr.extend(r.rid, r.kv_tokens_now + 1)
        else:
            r = self._q.popleft()
            self._on_admit(r)
            if tr is not None and r.cached_tokens > 0:
                tr.instant(tr_ev.REQ_PREFIX_HIT,
                           track=req_track(r.rid),
                           args={"cached_tokens": r.cached_tokens})
        if r.admitted_s is None:
            r.admitted_s = self.backend.now()
        if tr is not None:
            tr.instant(tr_ev.REQ_ADMIT, track=req_track(r.rid),
                       args={"resumed": kind == "suspended",
                             "cached_tokens": r.cached_tokens})
        if self._mixed is not None:
            # chunked prefill: the uncached span drains chunk-by-chunk
            # through mixed rounds instead of one monolithic pass
            fill_left = self._fill.get(r.rid, 0)
            if kind == "suspended" and fill_left > 0 \
                    and r.cached_tokens > 0:
                # spill-resumed mid-prefill: the KV computed so far
                # came back with the pages; only the un-prefilled
                # remainder still rides mixed rounds
                r.cached_tokens = max(r.prefill_tokens - fill_left, 0)
            else:
                self._fill[r.rid] = max(r.prefill_tokens
                                        - r.cached_tokens, 0)
        return r

    def _finish_req(self, r: Request, slot: int, t: float) -> None:
        r.done = True
        r.finish_s = t
        self._on_finish(r)
        self._done.append(r)
        del self._active[slot]
        self.backend.release(slot)
        if self._tr is not None:
            self._trace_lifecycle(r)
        self._note_slo(r, t)

    def step(self) -> bool:
        """One scheduler iteration: intake due arrivals, then either form
        an admission batch or run one decode round. Returns False when
        the run is drained (nothing pending, queued, or live)."""
        pending, queue = self._pending, self._q
        suspended, active = self._susp, self._active
        tr = self._tr
        if not (pending or queue or suspended or active):
            return False
        self._intake(self.backend.now())

        if not active:
            if not queue and not suspended:
                if not pending:   # intake shed the last arrivals
                    return False
                # idle: jump to the next arrival
                self.backend.advance_to(pending[0].arrival_s)
                self._intake(self.backend.now())
                return True
            batch, slots = [], list(range(self.backend.n_slots))
            while len(batch) < len(slots):
                kind = self._next_candidate(batch)
                if kind is None:
                    break
                batch.append(self._pop_candidate(kind))
            if not batch:
                # head-of-line blocked with nothing in flight: only
                # reachable when budget < kv_tokens, which
                # _oversized() already shed — defensive guard
                if suspended:
                    r = suspended.popleft()
                    self.mgr.release(r.rid)   # don't leak its pages
                else:
                    r = queue.popleft()
                self._reject(r)
                return True
            self._order = list(range(len(batch)))
            if self._mixed is not None:
                # chunked: register slots only — prompts drain through
                # mixed rounds below, first tokens emitted when each
                # request's last chunk lands
                for slot, r in enumerate(batch):
                    active[slot] = r
                    self.backend.attach_slot(slot, r, r.cached_tokens)
                self._note_occupancy(len(batch))
                return True
            first = self.backend.start_batch(batch)
            t = self.backend.now()
            for slot, (r, tok) in enumerate(zip(batch, first)):
                active[slot] = r
                if r.first_token_s is None:
                    r.first_token_s = t
                r.generated += 1
                if tok is not None:
                    r.output.append(tok)
                if r.generated >= r.max_new_tokens:  # max_new == 1
                    self._finish_req(r, slot, t)
            self._note_occupancy(len(batch))
            return True

        # one decode step for every live slot
        if self.paged:
            self._grow_active(active, self._order, suspended)
            self._note_occupancy(len(active))
            if not active:
                return True       # everyone preempted (defensive)
        if self._mixed is not None:
            # mixed round: prefilling slots consume one chunk each,
            # decoding slots commit a round of tokens — all riding the
            # same weight-stream (DESIGN.md §12)
            work = {}
            for slot in sorted(active):
                r = active[slot]
                rem = self._fill.get(r.rid, 0)
                if rem > 0:
                    n = min(self.chunk, rem)
                    work[slot] = ("prefill", n, n == rem)
                    self._fill[r.rid] = rem - n
                else:
                    work[slot] = ("decode",)
            emitted = self._mixed(work)
        else:
            emitted = self.backend.decode_active(sorted(active))
        t = self.backend.now()
        for slot, toks in emitted.items():
            r = active.get(slot)
            if r is None:         # preempted out of this step
                continue
            # speculative backends emit several committed tokens per
            # round (DESIGN.md §11); tokens past max_new are dropped
            # (the backend over-decodes padding, never user output)
            if not isinstance(toks, (list, tuple)):
                toks = [toks]
            for tok in toks:
                r.generated += 1
                if r.first_token_s is None:   # chunked: the prompt's
                    r.first_token_s = t       # last chunk emits here
                if tok is not None:
                    r.output.append(tok)
                if r.generated >= r.max_new_tokens:
                    self._finish_req(r, slot, t)
                    break
        # spec-decode commit boundary (DESIGN.md §12): multi-token
        # commits with real ids cross page boundaries mid-flight —
        # donate completed pages now so concurrent same-prefix
        # requests hit without waiting for this one to finish
        if self.prefix is not None \
                and getattr(self.backend, "spec", None) is not None:
            for r in active.values():
                if r.output:
                    self._maybe_insert(r)

        # continuous batching: refill freed slots mid-flight
        if self.backend.can_join_running and active:
            self._intake(self.backend.now())
            free = [s for s in range(self.backend.n_slots)
                    if s not in active]
            for slot in free:
                kind = self._next_candidate(list(active.values()))
                if kind is None:
                    break
                r = self._pop_candidate(kind)
                active[slot] = r
                if slot in self._order:
                    self._order.remove(slot)
                self._order.append(slot)
                if self._mixed is not None:
                    # chunked: the joiner's prompt drains through the
                    # coming mixed rounds — no monolithic join pass
                    self.backend.attach_slot(slot, r, r.cached_tokens)
                    continue
                tok = self.backend.join(slot, r)
                if r.first_token_s is None:
                    r.first_token_s = self.backend.now()
                r.generated += 1
                if tok is not None:
                    r.output.append(tok)
                if r.generated >= r.max_new_tokens:  # max_new == 1
                    self._finish_req(r, slot, self.backend.now())
            self._note_occupancy(len(active))
        return True

    def serve(self, requests: List[Request]) -> List[Request]:
        """Run every request to completion (or rejection); returns them
        all, completion order first, then rejected."""
        self.begin(requests)
        while self.step():
            pass
        return self.finish_run()

    def finish_run(self) -> List[Request]:
        """Drain-time accounting: fold subsystem counters into the
        registry and return every request record."""
        if self.paged:
            pool = self.mgr.pool
            self.metrics.set("kv_pages_spilled", pool.spilled_pages)
            self.metrics.set("kv_pages_fetched", pool.fetched_pages)
            self.metrics.set("kv_migrated_bytes", pool.migrated_bytes)
        if self.prefix is not None:
            self.metrics.set("cached_tokens", self.prefix.cached_tokens())
            self.metrics.set("prefix_pages", self.prefix.n_pages)
            self.metrics.set("prefix_evicted_pages",
                             self.prefix.evicted_pages)
        else:                         # engine-tier radix (real KV pages)
            bps = getattr(self.backend, "prefix_stats", None)
            if bps:
                self.metrics.update(bps)
        spec = getattr(self.backend, "spec_stats", None)
        if spec:                      # drafted/accepted counters -> report
            self.metrics.update(spec)
        adapt = getattr(self.backend, "adapt_stats", None)
        if adapt:                     # retier telemetry (DESIGN.md §13)
            self.metrics.update(adapt)
        return self._done + self._shed
