"""Resident-tier self-speculative decoding vs n-gram drafting
(EXPERIMENTS.md §Self-Spec).

Two exit-enforced claims (DESIGN.md §14):

 1. Throughput: on the E3 fleet, serving with the resident self-draft
    (acceptance scales with the live resident fraction, depth adapts per
    retier rung) beats the n-gram draft baseline in decode tokens/s at at
    least one rung of the retier ladder. Rungs are built by demoting j
    layers of the allocated plan into the streamed tier — the state the
    online planner leaves the pipeline in after KV pressure (the n-gram
    draft's flat acceptance does not care where the tier boundary sits;
    the self-draft's does — the bench maps where each one wins).
 2. Losslessness: a raw-engine resident-draft spec loop (draft k on the
    resident tier -> rollback -> one multi-query verify -> greedy commit),
    with a mid-stream retier demotion AND promotion, emits tokens
    identical to plain autoregressive greedy decode at bf16, on both the
    ref and Pallas attention paths (subprocess: forced host device count).

  python benchmarks/bench_selfspec.py
  python benchmarks/bench_selfspec.py --rungs 0,8,16,24,32 \
      --out benchmarks/baselines/selfspec_sim.json
  python benchmarks/bench_selfspec.py --no-engine-check   # sim sweep only
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

# --------------------------------------------------------------------------
# part 2: engine token-identity (subprocess, forced host device count)
# --------------------------------------------------------------------------
ENGINE_WORKER = r"""
import jax, jax.numpy as jnp, numpy as np, sys
import repro.core.engine as E
from repro.configs.base import ModelConfig, Family
from repro.models import model as M
from repro.specdec import greedy_verify

cfg = ModelConfig(name="d", family=Family.DENSE, n_layers=8, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16)
params = M.init_params(cfg, jax.random.PRNGKey(0))
PLAN = E.UniformPlan(4, 2, 1, 1)
STEPS = 12


def make(mesh, impl):
    eng = E.InterleavedEngine(cfg, mesh, PLAN, n_mb=1, mb=2, max_len=48,
                              impl=impl, retier_headroom=1)
    return eng, eng.init_state(params)


def greedy(lg):
    return jnp.argmax(lg[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)


fails = []
for impl, shape, axes in (("ref", (4, 2), ("data", "model")),
                          ("pallas", (4,), ("data",))):
    mesh = jax.make_mesh(shape, axes)
    tok0 = jax.random.randint(jax.random.PRNGKey(1), (2, 1), 0,
                              cfg.vocab_size)
    # plain autoregressive greedy reference
    eng, st = make(mesh, impl)
    t, ref = tok0, []
    for _ in range(STEPS):
        lg, st = eng.decode_step(st, t)
        t = greedy(lg)
        ref.append(np.asarray(t)[:, 0].copy())
    ref = np.stack(ref)

    # resident self-spec loop with retier events between rounds
    eng, st = make(mesh, impl)
    t = np.array(tok0, np.int32)
    out = [[], []]
    pos, k, rounds = 0, 3, 0
    while min(len(o) for o in out) < STEPS:
        cur = jnp.asarray(t)
        drafts = np.zeros((2, k), np.int32)
        for i in range(k):
            lg, st = eng.draft_step(st, cur)
            cur = greedy(lg)
            drafts[:, i] = np.asarray(cur)[:, 0]
        st = eng.rollback(st, pos)
        lg, st = eng.verify_step(
            st, jnp.asarray(np.concatenate([t, drafts], 1)))
        lgn = np.asarray(lg, np.float32)
        committed = [greedy_verify(lgn[b], drafts[b], cfg.vocab_size)
                     for b in range(2)]
        c = min(len(x) for x in committed)
        pos += c
        st = eng.rollback(st, pos)
        for b in range(2):
            out[b].extend(committed[b][:c])
            t[b, 0] = committed[b][c - 1]
        rounds += 1
        if rounds == 2:       # demote one resident slot mid-stream ...
            st, freed = eng.retier(st, 0, +1)
            assert freed > 0
        if rounds == 4:       # ... and promote it back two rounds later
            st, freed = eng.retier(st, 0, -1)
            assert freed < 0
    got = np.stack([np.asarray(o[:STEPS]) for o in out], 1).T
    ok = (got == ref.reshape(STEPS, 2).T).all()
    print(f"{impl}: resident-spec tokens "
          f"{'identical' if ok else 'MISMATCH'} ({rounds} rounds)")
    if not ok:
        fails.append(impl)
print("SELFSPEC_ENGINE_OK" if not fails else f"FAILS {fails}")
sys.exit(1 if fails else 0)
"""


def engine_identity_check() -> bool:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src")
    r = subprocess.run([sys.executable, "-c", ENGINE_WORKER], env=env,
                       capture_output=True, text=True, timeout=1800)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stderr.write(r.stderr[-2000:])
    return r.returncode == 0 and "SELFSPEC_ENGINE_OK" in r.stdout


# --------------------------------------------------------------------------
# part 1: sim throughput sweep over retier-ladder rungs
# --------------------------------------------------------------------------
def rung_plan(base, demoted: int):
    """Demote `demoted` layers of the allocated plan into the streamed
    tier: resident_total falls / off_full_seg rises one layer at a time,
    always on the currently most-resident stage — the shape the online
    planner's right-to-left ladder leaves behind. Only exact per-segment
    moves are expressible, so demotions step in units of n_seg."""
    import dataclasses

    from repro.core.cost_model import ExecutionPlan
    stages = [dataclasses.replace(st) for st in base.stages]
    left = demoted
    while left >= base.n_seg:
        d = max(range(len(stages)), key=lambda i: stages[i].resident_total)
        if stages[d].resident_total < base.n_seg:
            break
        stages[d] = dataclasses.replace(
            stages[d], resident_total=stages[d].resident_total - base.n_seg,
            off_full_seg=stages[d].off_full_seg + 1)
        left -= base.n_seg
    return ExecutionPlan(n_seg=base.n_seg, stages=stages)


def build_backend(args, plan, slots: int, spec):
    from repro.configs.registry import get_config
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.profiles import env_E1, env_E2, env_E3, mbps
    from repro.serving import SimBackend

    fleets = {"E1": env_E1, "E2": env_E2, "E3": env_E3}
    cfg = get_config(args.arch)
    w = Workload(cfg, mb=1, ctx=args.prompt_len, n_micro=slots)
    env = CostEnv(fleets[args.fleet](), mbps(args.bw_mbps), w)
    return SimBackend(env, plan, n_slots=slots,
                      prompt_tokens=args.prompt_len, spec=spec)


def base_plan(args):
    from repro.configs.registry import get_config
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.offline_scheduler import allocate
    from repro.core.profiles import env_E1, env_E2, env_E3, mbps

    fleets = {"E1": env_E1, "E2": env_E2, "E3": env_E3}
    cfg = get_config(args.arch)
    w = Workload(cfg, mb=1, ctx=args.prompt_len, n_micro=1)
    env = CostEnv(fleets[args.fleet](), mbps(args.bw_mbps), w)
    r = allocate(env, cfg.n_layers, n_emp=max(args.prompt_len, 1))
    if not r.feasible:
        raise SystemExit(f"infeasible {args.fleet} allocation: {r.reason}")
    return r.plan


def run_one(args, plan, spec) -> dict:
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               make_arrivals, requests_from_arrivals,
                               summarize)

    arrivals = make_arrivals("sporadic", args.n_requests, seed=args.seed,
                             prompt_len=args.prompt_len, gap_s=args.gap_s,
                             max_new_tokens=args.max_new)
    backend = build_backend(args, plan, 1, spec)
    sched = ContinuousBatchingScheduler(backend, SchedulerConfig())
    served = sched.serve(requests_from_arrivals(arrivals))
    rep = summarize(served, pattern="sporadic",
                    backend=f"sim/{spec.draft}", stats=sched.stats)
    out = rep.to_dict()
    out["draft"] = spec.draft
    return out


def compare_rung(args, base, demoted: int) -> dict:
    from repro.specdec import SpecConfig

    plan = rung_plan(base, demoted)
    total = max(plan.layers_total(), 1)
    frac = sum(st.resident_total for st in plan.stages) / total
    res = run_one(args, plan, SpecConfig(
        k=args.k, draft="resident", acceptance=args.resident_acceptance,
        seed=args.seed))
    ngram = run_one(args, plan, SpecConfig(
        k=args.k, draft="ngram", acceptance=args.ngram_acceptance,
        seed=args.seed))
    return {
        "rung_demoted_layers": demoted,
        "resident_fraction": frac,
        "resident_tok_s": res["throughput_tok_s"],
        "ngram_tok_s": ngram["throughput_tok_s"],
        "resident_wins": res["throughput_tok_s"] > ngram["throughput_tok_s"],
        "resident_acceptance_rate": res["spec_acceptance_rate"],
        "ngram_acceptance_rate": ngram["spec_acceptance_rate"],
        "resident": res, "ngram": ngram,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="llama2-13b")
    ap.add_argument("--fleet", default="E3", choices=("E1", "E2", "E3"))
    ap.add_argument("--bw-mbps", type=float, default=200.0)
    ap.add_argument("--n-requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--gap-s", type=float, default=4.0)
    ap.add_argument("--k", type=int, default=4,
                    help="draft depth cap (DepthController adapts below)")
    ap.add_argument("--resident-acceptance", type=float, default=0.9,
                    help="full-residency acceptance of the self-draft "
                         "(scaled by the live resident fraction)")
    ap.add_argument("--ngram-acceptance", type=float, default=0.35,
                    help="flat acceptance of the n-gram baseline")
    ap.add_argument("--rungs", default="0,8,16,24,32",
                    help="comma-separated demoted-layer counts")
    ap.add_argument("--no-engine-check", action="store_true",
                    help="skip the subprocess token-identity check")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    base = base_plan(args)
    rungs = [int(x) for x in args.rungs.split(",") if x != ""]
    results = [compare_rung(args, base, j) for j in rungs]
    payload = {"config": {k: v for k, v in vars(args).items()},
               "results": results}
    text = json.dumps(payload, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")

    rc = 0
    wins = [r for r in results if r["resident_wins"]]
    for r in results:
        print(f"# rung {r['rung_demoted_layers']:>2} "
              f"(res frac {r['resident_fraction']:.2f}): resident "
              f"{r['resident_tok_s']:.2f} vs ngram {r['ngram_tok_s']:.2f} "
              f"tok/s {'WIN' if r['resident_wins'] else 'loss'}",
              file=sys.stderr)
    if not wins:
        print("# WARNING: resident draft never beat the n-gram baseline "
              "at any retier rung — acceptance scaling or depth control "
              "broke", file=sys.stderr)
        rc = 1
    if not args.no_engine_check:
        if not engine_identity_check():
            print("# WARNING: resident-spec decode is NOT token-identical "
                  "to autoregressive greedy on the engine", file=sys.stderr)
            rc = 1
    return rc


def run():
    """benchmarks.run harness hook: sim rung sweep + engine identity."""
    class _Row:
        def __init__(self, name, ms):
            self.name, self.ms = name, ms

        def csv(self):
            return f"selfspec,{self.name},{self.ms:.1f},ok"

    rc = main(["--n-requests", "2", "--max-new", "16", "--rungs", "0,16,32"])
    if rc:
        raise SystemExit("bench_selfspec smoke failed")
    return [_Row("resident_vs_ngram_rungs", 0.0)]


if __name__ == "__main__":
    raise SystemExit(main())
