"""Mixture-of-Experts layer: top-k routing + sort-based grouped expert compute.

TPU-native formulation (MegaBlocks/MaxText-style, no (T, E, C) dispatch einsum):
tokens are *sorted by expert id*, packed into a capacity-bounded (E, C, D)
buffer, experts run as one batched einsum, and outputs scatter back weighted by
router probabilities. Under a mesh, the layer runs inside ``shard_map``:
routing is replicated per data-shard, each model-shard computes only its
E/|model| experts, and the combine is a single ``psum`` over the model axis —
the same collective cost as a Megatron MLP, with no global sort.

This matters for LIME: for MoE architectures the expert tensors dominate layer
memory (p_M ~ 0.97-0.99), so the paper's fine-grained MHA/MLP offload split
becomes an attention/expert split (DESIGN.md §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.spec import ParamSpec
from repro.models.modules import mlp, mlp_specs


def moe_specs(d_model: int, n_experts: int, moe_d_ff: int,
              n_shared: int) -> dict:
    out = {
        "router": ParamSpec((d_model, n_experts), ("embed", None),
                            dtype=jnp.float32, init="small"),
        "wi_gate": ParamSpec((n_experts, d_model, moe_d_ff),
                             ("expert", "embed", None)),
        "wi_up": ParamSpec((n_experts, d_model, moe_d_ff),
                           ("expert", "embed", None)),
        "wo": ParamSpec((n_experts, moe_d_ff, d_model),
                        ("expert", None, "embed")),
    }
    if n_shared:
        out["shared"] = mlp_specs(d_model, n_shared * moe_d_ff)
    return out


def _route(router, x_flat, top_k: int):
    """Returns (weights (T,K) f32, ids (T,K) i32, probs (T,E) f32)."""
    from repro import compat
    logits = (x_flat.astype(jnp.float32) @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = compat.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, ids, probs


def _group_tokens(ids, capacity: int, n_experts: int):
    """Sort token-slots by expert; compute packed buffer indices.

    ids: (T, K) -> returns (order (T*K,), buf_idx (T*K,), keep (T*K,)).
    buf_idx indexes an (E*C + 1)-row buffer; dropped slots go to the dump row.
    """
    TK = ids.size
    e_flat = ids.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    # rank within expert = position - first occurrence of this expert id
    first = jnp.searchsorted(e_sorted, e_sorted, side="left")
    rank = jnp.arange(TK) - first
    keep = rank < capacity
    buf_idx = jnp.where(keep, e_sorted * capacity + rank, n_experts * capacity)
    return order, buf_idx, keep


def _expert_ffn(wg, wu, wo, buf):
    """buf: (E_l, C, D) -> (E_l, C, D)."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", g * u, wo)


def _moe_local(params, x_flat, *, top_k: int, n_experts: int,
               capacity_factor: float, expert_slice=None, n_local: int = 0,
               constraint_mesh=None):
    """MoE on a local token shard. expert_slice: traced start index of this
    shard's experts (None = all experts local). constraint_mesh: GSPMD-auto
    context — pin expert-dim sharding instead of manual collectives."""
    T, D = x_flat.shape
    weights, ids, probs = _route(params["router"], x_flat, top_k)
    cap = max(1, int(T * top_k / n_experts * capacity_factor + 0.999))
    order, buf_idx, keep = _group_tokens(ids, cap, n_experts)
    tok = jnp.repeat(jnp.arange(T), top_k)[order]
    w_sorted = weights.reshape(-1)[order]

    dump = jnp.zeros((n_experts * cap + 1, D), x_flat.dtype)
    buf = dump.at[buf_idx].set(x_flat[tok] * keep[:, None].astype(x_flat.dtype))
    buf = buf[:-1].reshape(n_experts, cap, D)

    if expert_slice is None:
        if constraint_mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as _P
            pin = lambda t: jax.lax.with_sharding_constraint(
                t, NamedSharding(constraint_mesh, _P("model")))
            buf = pin(buf)
        y = _expert_ffn(params["wi_gate"], params["wi_up"], params["wo"], buf)
        if constraint_mesh is not None:
            y = pin(y)
        y = jnp.concatenate([y.reshape(-1, D),
                             jnp.zeros((1, D), x_flat.dtype)], 0)
    else:
        buf_l = jax.lax.dynamic_slice_in_dim(buf, expert_slice * n_local,
                                             n_local, axis=0)
        y_l = _expert_ffn(params["wi_gate"], params["wi_up"], params["wo"], buf_l)
        # place local experts' outputs back at their global offset
        y = jnp.zeros((n_experts, cap, D), x_flat.dtype)
        y = jax.lax.dynamic_update_slice_in_dim(y, y_l, expert_slice * n_local, 0)
        y = jnp.concatenate([y.reshape(-1, D),
                             jnp.zeros((1, D), x_flat.dtype)], 0)

    gathered = y[buf_idx] * (w_sorted * keep).astype(x_flat.dtype)[:, None]
    out = jnp.zeros_like(x_flat).at[tok].add(gathered)

    # Switch-style load-balance aux loss (per shard; psum'd by caller if needed)
    me = probs.mean(0)                                       # (E,)
    ce = jnp.zeros((n_experts,)).at[ids.reshape(-1)].add(1.0) / (T * top_k)
    aux = n_experts * jnp.sum(me * ce)
    return out, aux


def moe_forward(params, x, *, cfg, mesh=None, capacity_factor: float = 1.25,
                mode: str = "shard_map"):
    """x: (B, S, D). Returns (out, aux_loss).

    mode="shard_map": explicit manual experts over 'model' (train/prefill).
    mode="auto": GSPMD constraints only — for callers already inside a
    partial-auto shard_map (the LIME engine), where nesting manual
    collectives over 'model' is not an option. The constraint pins the
    expert einsum to expert-sharded compute; without it the partitioner
    all-gathers the expert weights (TBs for kimi-k2 — see EXPERIMENTS §Perf).
    """
    B, S, D = x.shape
    x_flat = x.reshape(-1, D)
    E, K = cfg.n_experts, cfg.top_k

    if mode == "auto" and mesh is not None and "model" in mesh.shape \
            and E % mesh.shape["model"] == 0:
        from repro.compat import PARTIAL_AUTO_SHARDING_CONSTRAINT_OK
        out_flat, aux = _moe_local(
            {k: params[k] for k in ("router", "wi_gate", "wi_up", "wo")},
            x_flat, top_k=K, n_experts=E, capacity_factor=capacity_factor,
            constraint_mesh=(mesh if PARTIAL_AUTO_SHARDING_CONSTRAINT_OK
                             else None))
        if "shared" in params:
            out_flat = out_flat + mlp(params["shared"], x_flat)
        return out_flat.reshape(B, S, D), aux

    if mode != "auto" and mesh is not None and "model" in mesh.shape \
            and mesh.shape["model"] > 1 \
            and E % mesh.shape["model"] == 0:
        n_local = E // mesh.shape["model"]
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        ba = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(ba, None),
                      {"router": P(None, None),
                       "wi_gate": P("model", None, None),
                       "wi_up": P("model", None, None),
                       "wo": P("model", None, None)}),
            out_specs=(P(ba, None), P()),
            check_vma=False)
        def _sharded(x_l, p_l):
            idx = jax.lax.axis_index("model")
            out, aux = _moe_local(p_l, x_l, top_k=K, n_experts=E,
                                  capacity_factor=capacity_factor,
                                  expert_slice=idx, n_local=n_local)
            out = jax.lax.psum(out, "model")
            aux = jax.lax.pmean(aux, "model")
            if ba is not None:
                aux = jax.lax.pmean(aux, ba)
            return out, aux

        core = {k: params[k] for k in ("router", "wi_gate", "wi_up", "wo")}
        out_flat, aux = _sharded(x_flat, core)
    else:
        out_flat, aux = _moe_local(
            {k: params[k] for k in ("router", "wi_gate", "wi_up", "wo")},
            x_flat, top_k=K, n_experts=E, capacity_factor=capacity_factor)

    if "shared" in params:
        out_flat = out_flat + mlp(params["shared"], x_flat)
    return out_flat.reshape(B, S, D), aux


def moe_forward_naive(params, x, *, cfg):
    """O(T*E) per-token oracle for tests: every expert on every token."""
    B, S, D = x.shape
    x_flat = x.reshape(-1, D)
    weights, ids, _ = _route(params["router"], x_flat, cfg.top_k)
    ys = _expert_ffn(params["wi_gate"], params["wi_up"], params["wo"],
                     jnp.broadcast_to(x_flat, (cfg.n_experts,) + x_flat.shape))
    onehot = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32)  # (T,K,E)
    w_e = (weights[..., None] * onehot).sum(1)                      # (T,E)
    out = jnp.einsum("te,etd->td", w_e.astype(x.dtype), ys)
    if "shared" in params:
        out = out + mlp(params["shared"], x_flat)
    return out.reshape(B, S, D)
