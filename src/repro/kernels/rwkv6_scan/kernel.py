"""RWKV6 (Finch) WKV recurrence for TPU (Pallas).

    a_t = k_t^T v_t                    (dh, dh) rank-1 update
    o_t = r_t · (S + u ⊙_rows a_t)
    S  <- diag(w_t) S + a_t            (data-dependent decay on the k index)

TPU adaptation (vs. the CUDA kernels in the RWKV repo): the per-(batch, head)
state matrix S (dh × dh, fp32) lives in VMEM scratch for the *entire*
sequence — the grid is (B, H, n_time_blocks) with the time dimension
sequential, so S never round-trips HBM between steps. Within a block the
time loop is a `fori_loop` over rows of the (block_t, dh) r/k/v/w tiles;
each step is a rank-1 outer product + row-scaled matvec, i.e. VPU work on
(dh, dh) tiles with dh a multiple of the 128-lane register width (dh = 64
heads are lane-padded by ops.py; decay padding uses w = 1 and k = 0 so
padded lanes stay zero).

VMEM working set: 4·block_t·dh·4B (tiles) + 2·dh²·4B (state + out) ≈ 0.3 MB
at block_t = 256, dh = 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,   # in
                o_ref, sT_ref,                               # out
                state_ref,                                   # scratch
                *, block_t: int):
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _load_state():
        state_ref[...] = s0_ref[0, 0]

    u = u_ref[0].astype(jnp.float32)                 # (dh,)
    r = r_ref[0, 0].astype(jnp.float32)              # (block_t, dh)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)

    def step(t, S):
        r_t = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)       # (1, dh)
        k_t = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        v_t = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        w_t = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        a = k_t.T * v_t                                      # (dh, dh)
        o = r_t @ (S + u[:, None] * a)                       # (1, dh)
        # int dims spelled as ds(0, 1): bare python ints in a store index
        # tuple break old Pallas (NDIndexer expects Slice/array indices)
        pl.store(o_ref, (pl.ds(0, 1), pl.ds(0, 1), pl.ds(t, 1), slice(None)),
                 o[None, None].astype(o_ref.dtype))
        return w_t.T * S + a

    S = jax.lax.fori_loop(0, block_t, step, state_ref[...])
    state_ref[...] = S

    @pl.when(it == nt - 1)
    def _emit_state():
        sT_ref[0, 0] = S


def wkv_kernel(r, k, v, w, u, s0, *, block_t: int = 256,
               interpret: bool = False):
    """r/k/v/w: (B, H, S, dh) [w fp32 decay in (0,1)]; u: (H, dh);
    s0: (B, H, dh, dh) fp32. S % block_t == 0 (ops.py pads).
    Returns (out (B, H, S, dh) fp32, final state (B, H, dh, dh) fp32)."""
    B, H, S, dh = r.shape
    block_t = min(block_t, S)
    grid = (B, H, S // block_t)

    t_spec = pl.BlockSpec((1, 1, block_t, dh), lambda b, h, it: (b, h, it, 0))
    s_spec = pl.BlockSpec((1, 1, dh, dh), lambda b, h, it: (b, h, 0, 0))

    return pl.pallas_call(
        functools.partial(_wkv_kernel, block_t=block_t),
        grid=grid,
        in_specs=[t_spec, t_spec, t_spec, t_spec,
                  pl.BlockSpec((1, dh), lambda b, h, it: (h, 0)),
                  s_spec],
        out_specs=[t_spec, s_spec],
        out_shape=[jax.ShapeDtypeStruct((B, H, S, dh), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, dh, dh), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
