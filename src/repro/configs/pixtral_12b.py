"""Pixtral-12B — VLM: mistral-nemo decoder backbone + (stubbed) Pixtral-ViT frontend.

Per assignment, only the language backbone is implemented; input_specs() feeds
precomputed patch embeddings (frontend_tokens) of shape (B, n_patch, d_model).
[hf:mistralai/Pixtral-12B-2409]
"""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="pixtral-12b", family=Family.VLM,
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    attn_kind=AttnKind.FULL, rope_theta=1_000_000_000.0,
    frontend_tokens=256,  # 16x16 patch grid worth of image embeddings
    source="Pixtral-12B-2409 model card [hf:mistralai/Pixtral-12B-2409]",
)
