"""Adafactor (Shazeer & Stern, 2018) — factored second moment, no momentum.

The production optimizer for models whose AdamW state can't fit the pod:
kimi-k2 1T x (fp32 master+mu+nu = 12 B/param) = 12.5 TB, vs a v5e pod's
4 TB HBM. Adafactor keeps one row vector + one column vector per matrix
(~1e-3 of AdamW's bytes) at the cost of update-rule fidelity; bf16 params
take the update directly (no fp32 master), the standard trade at this
scale. launch/dryrun.lower_train switches to it automatically when the
AdamW state would exceed the per-chip budget.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vs: Any          # per-leaf dict: {"vr": ..., "vc": ...} or {"v": ...}


def _is_state_leaf(x):
    return isinstance(x, dict) and ("v" in x or "vr" in x)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: Callable[[jnp.ndarray], jnp.ndarray]
    eps: float = 1e-30
    clip_rms: float = 1.0
    weight_decay: float = 0.0

    def _init_one(self, p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    def init(self, params) -> AdafactorState:
        return AdafactorState(jnp.int32(0),
                              jax.tree.map(self._init_one, params))

    def update(self, grads, state: AdafactorState, params
               ) -> Tuple[Any, AdafactorState]:
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** -0.8
        lr = self.lr(step)

        def upd(p, g, v):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + self.eps
            if "vr" in v:
                vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(-2)
                denom = vr[..., :, None] * vc[..., None, :] \
                    / jnp.maximum(vr.mean(-1)[..., None, None], self.eps)
                u = gf * jax.lax.rsqrt(denom + self.eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nvv = beta2 * v["v"] + (1 - beta2) * g2
                u = gf * jax.lax.rsqrt(nvv + self.eps)
                nv = {"v": nvv}
            rms = jnp.sqrt(jnp.mean(u * u) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_rms)
            new_p = p.astype(jnp.float32) - lr * u
            if self.weight_decay and p.ndim >= 2:
                new_p = new_p - lr * self.weight_decay * p.astype(jnp.float32)
            return new_p.astype(p.dtype), nv

        flat_p, td = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_v = jax.tree.leaves(state.vs, is_leaf=_is_state_leaf)
        outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = jax.tree.unflatten(td, [o[0] for o in outs])
        new_vs = jax.tree.unflatten(td, [o[1] for o in outs])
        return new_params, AdafactorState(step, new_vs)

    # -- dry-run helpers -------------------------------------------------------
    def state_specs(self, p_specs):
        """ShapeDtypeStruct state tree from sharded param specs (vr/vc keep
        the surviving dims' shardings)."""
        def one(s):
            from jax.sharding import NamedSharding, PartitionSpec as P
            spec = list(s.sharding.spec) if s.sharding else []
            spec += [None] * (len(s.shape) - len(spec))
            if len(s.shape) >= 2:
                return {
                    "vr": jax.ShapeDtypeStruct(
                        s.shape[:-1], jnp.float32,
                        sharding=NamedSharding(s.sharding.mesh,
                                               P(*spec[:-1]))),
                    "vc": jax.ShapeDtypeStruct(
                        s.shape[:-2] + s.shape[-1:], jnp.float32,
                        sharding=NamedSharding(s.sharding.mesh,
                                               P(*(spec[:-2] + spec[-1:])))),
                }
            return {"v": jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                              sharding=s.sharding)}
        is_sds = lambda x: isinstance(x, jax.ShapeDtypeStruct)
        vs = jax.tree.map(one, p_specs, is_leaf=is_sds)
        return AdafactorState(jax.ShapeDtypeStruct((), jnp.int32), vs)
