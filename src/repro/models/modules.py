"""Shared neural building blocks (pure-functional JAX)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.spec import ParamSpec


def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * (1.0 + scale.astype(dt))


def rms_norm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="zeros")


# ----------------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ----------------------------------------------------------------------------
def mlp_specs(d_model: int, d_ff: int) -> dict:
    return {
        "wi_gate": ParamSpec((d_model, d_ff), ("embed", "ffn")),
        "wi_up": ParamSpec((d_model, d_ff), ("embed", "ffn")),
        "wo": ParamSpec((d_ff, d_model), ("ffn", "embed")),
    }


def mlp(params, x):
    g = jax.nn.silu(x @ params["wi_gate"])
    return (g * (x @ params["wi_up"])) @ params["wo"]


# ----------------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------------
def round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def embed_specs(vocab: int, d_model: int, tie: bool) -> dict:
    pv = round_up(vocab, 256)   # pad for clean vocab sharding
    out = {"embedding": ParamSpec((pv, d_model), ("vocab", "embed"), scale=1.0)}
    if not tie:
        out["lm_head"] = ParamSpec((d_model, pv), ("embed", "vocab"))
    return out


def embed(params, tokens):
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params, x):
    if "lm_head" in params:
        return x @ params["lm_head"]
    # tied embeddings (gemma-style): normalize logit scale by 1/sqrt(d)
    return (x * (x.shape[-1] ** -0.5)) @ params["embedding"].T


def cross_entropy_loss(logits, labels, mask=None, real_vocab: Optional[int] = None):
    """Stable CE over (possibly padded) vocab; labels < real_vocab always."""
    logits = logits.astype(jnp.float32)
    if real_vocab is not None and real_vocab < logits.shape[-1]:
        pad = logits.shape[-1] - real_vocab
        neg = jnp.full((pad,), -1e30, logits.dtype)
        logits = logits + jnp.concatenate(
            [jnp.zeros((real_vocab,), logits.dtype), neg])
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
