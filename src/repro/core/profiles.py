"""Device profiles for the LIME cost model and simulator.

The paper's testbed (Tab. II) is heterogeneous NVIDIA Jetson devices with
NVMe SSDs; the TPU adaptation maps "SSD load bandwidth" to ICI all-to-all
bandwidth and "device memory" to per-chip HBM (DESIGN.md §2). Both kinds of
profile flow through the same scheduler/simulator — heterogeneity is a
property of the profile list, not of the algorithms.

Effective FLOP/s: vendor "AI performance" numbers are INT8 TOPS; sustained
fp16 transformer throughput on Jetson is roughly 25–35 % of that. The
calibration constants below are knobs, not measurements — the paper's claims
we validate are *relative* speedups, which are insensitive to a common
scale (EXPERIMENTS.md §Repro).
"""
from __future__ import annotations

import dataclasses
from typing import List

GB = 1024 ** 3
MB = 1024 ** 2


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    mem_bytes: float           # usable accelerator memory for weights + KV
    flops: float               # effective dense fp16/bf16 FLOP/s
    mem_bw: float              # HBM/LPDDR bandwidth (bytes/s) — decode bound
    load_bw: float             # weight-residency restore bandwidth (bytes/s)
                               #   Jetson: NVMe read; TPU: ICI all-to-all
    load_write_bw: float = 0.0 # SSD write bandwidth (0 = no write-back needed)
    host_bw: float = 0.0       # host-RAM->GPU staging bandwidth (TPI-LLM's
                               # sliding window streams from CPU memory);
                               # 0 = same as load_bw

    def scaled_mem(self, frac: float) -> "DeviceProfile":
        return dataclasses.replace(self, name=f"{self.name}[{frac:.0%}mem]",
                                   mem_bytes=self.mem_bytes * frac)


# --- paper Tab. II ----------------------------------------------------------
# Jetson memory is *unified* CPU+GPU: the OS + PyTorch + CUDA context eat
# ~2.5 GB before the model sees a byte, then ~8% headroom for activations /
# fragmentation. Unified memory also means TPI-LLM's "CPU RAM" sliding
# window streams from the *same* NVMe when the shard exceeds device memory
# — host_bw == load_bw on Jetson (the paper's OOT observations for TPI-LLM
# under memory pressure follow from this).
def _jetson(name, mem_gb, tops, mem_bw_gbs, nvme_read_gbs, nvme_write_gbs):
    return DeviceProfile(
        name=name,
        mem_bytes=(mem_gb - 4.0) * 0.90 * GB,
        flops=tops * 1e12 * 0.30 * 0.5,     # INT8->fp16 halves, 30% sustained
        mem_bw=mem_bw_gbs * 0.7 * GB,
        load_bw=nvme_read_gbs * GB,
        load_write_bw=nvme_write_gbs * GB,
        host_bw=nvme_read_gbs * GB,
    )


XAVIER_NX_16 = _jetson("xavier-nx-16g", 16, 21, 59.7, 1.0, 0.8)
AGX_ORIN_32 = _jetson("agx-orin-32g", 32, 200, 204.8, 2.0, 1.4)
AGX_ORIN_64 = _jetson("agx-orin-64g", 64, 275, 204.8, 2.5, 1.8)


# --- paper experimental environments (Tab. IV + §V-C settings) --------------
def env_E1() -> List[DeviceProfile]:
    return [XAVIER_NX_16, AGX_ORIN_32]

def env_E2() -> List[DeviceProfile]:
    return [XAVIER_NX_16, AGX_ORIN_32, AGX_ORIN_64]

def env_E3() -> List[DeviceProfile]:
    return [XAVIER_NX_16, AGX_ORIN_32, AGX_ORIN_64, AGX_ORIN_64]

def env_lowmem(setting: int) -> List[DeviceProfile]:
    """§V-C Settings 1-3, progressively tighter memory (Qwen3-32B / 70B)."""
    base = [AGX_ORIN_64, AGX_ORIN_32, AGX_ORIN_32, XAVIER_NX_16, XAVIER_NX_16]
    if setting >= 2:
        base[3] = XAVIER_NX_16.scaled_mem(0.5)
    if setting >= 3:
        frac = (32 * 0.85 - 8) / (32 * 0.85)   # 8 GB made unavailable
        base[1] = AGX_ORIN_32.scaled_mem(frac)
    return base


# --- TPU v5e (the porting target; DESIGN.md §2) ------------------------------
# load_bw: weight re-gather via ICI all-to-all across the stage axis — each
# chip pulls (n-1)/n of the layer bytes over ~4 links; effective ~45 GB/s.
TPU_V5E = DeviceProfile(
    name="tpu-v5e",
    mem_bytes=16 * 0.9 * GB,
    flops=197e12 * 0.55,         # bf16 peak x sustained matmul efficiency
    mem_bw=819e9 * 0.8,
    load_bw=45e9,
    load_write_bw=0.0,           # sharded copy never stale: no write-back
)


def tpu_pod_stage_devices(n_stages: int) -> List[DeviceProfile]:
    return [TPU_V5E] * n_stages


def mbps(x: float) -> float:
    """Network bandwidth helper: Mbps -> bytes/s (paper uses 100/200 Mbps)."""
    return x * 1e6 / 8
