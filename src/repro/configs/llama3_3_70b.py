"""Llama3.3-70B-Instruct — paper Tab. III row 3 (80L, hidden 8192, 64H, kv=8)."""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="llama3.3-70b", family=Family.DENSE,
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    attn_kind=AttnKind.FULL, rope_theta=500_000.0,
    source="LIME paper Tab. III / Llama3 herd [arXiv:2407.21783]",
)
