"""Discrete-event simulator for the interleaved pipeline (paper §IV, Figs 3-8).

The cost model (Eq. 1) predicts steady-state latency; this simulator *executes*
a Plan on a timeline with explicit resources — per-device compute, per-device
weight loader (SSD/ICI channel), and the activation ring — so pipeline fill,
load/compute overlap, online-planner triggers and KV-transfer effects emerge
rather than being assumed. It is the artifact behind EXPERIMENTS.md §Repro
(Figs 12-18, Tab. V) and the golden-trace tests.

Execution order per auto-regressive step (paper Fig. 6): for each segment
s = 1..#Seg, each device computes all in-flight micro-batches for its stage
of s, hands activations to the next device (h_size/bw per hop), and — after
the *last* micro-batch of s — its loader evicts the segment-s offloaded
blocks and begins fetching segment s+1's (the interleave). A stage may not
start until its activation arrives AND its weights are resident.

Request patterns (paper §V-A): sporadic = 1 micro-batch in flight;
bursty = |D| micro-batches.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

from repro.core.cost_model import CostEnv, ExecutionPlan
from repro.core.online_planner import OnlinePlanner
from repro.core.kv_transfer import KVTransferProtocol
from repro.obs import trace as tr_ev
from repro.obs.trace import dev_track, get_tracer, loader_track


@dataclasses.dataclass
class StepTrace:
    token: int
    latency: float
    load_stall: float          # time any stage waited on weights
    comm_time: float
    planner_fired: bool = False
    kv_moved_bytes: float = 0.0  # Eq. 8 page migrations this step (wire
                                 # volume; rides idle network, not latency)


@dataclasses.dataclass
class SimResult:
    per_token: List[StepTrace]
    oom: bool = False
    oot: bool = False
    reason: str = ""

    @property
    def ms_per_token(self) -> float:
        if not self.per_token:
            return float("inf")
        return 1e3 * sum(t.latency for t in self.per_token) / len(self.per_token)

    @property
    def total_s(self) -> float:
        return sum(t.latency for t in self.per_token)


# ----------------------------------------------------------------------------
# Core timeline
# ----------------------------------------------------------------------------
class InterleavedPipelineSim:
    """Simulates LIME decoding `n_tokens` with an allocation Plan."""

    def __init__(self, env: CostEnv, plan: ExecutionPlan, *,
                 use_planner: bool = True, use_kv_transfer: bool = True,
                 planner_full_layer_fallback: bool = False,
                 horizon_tokens: Optional[int] = None,
                 bandwidth_schedule: Optional[Callable[[int], float]] = None,
                 prompt_tokens: int = 64,
                 true_env: Optional[CostEnv] = None):
        self.env = env
        # planned-vs-true split (DESIGN.md §18): `env` is the *model* the
        # planner/scheduler reason with; `true_env` is what the hardware
        # actually does — the sim prices compute and loader time from it.
        # They are the same object unless a drift experiment separates
        # them (set_true_env mid-run injects a throttle/contention event).
        self.true_env = true_env if true_env is not None else env
        self.refit = None
        self.plan = plan
        self.w = env.work
        self.D = len(plan.stages)
        self.n_seg = max(plan.n_seg, 1)
        self.bw_schedule = bandwidth_schedule
        self.prompt = prompt_tokens
        if horizon_tokens is None:
            # cover the largest context any device could conceivably reach
            horizon_tokens = int(2 ** 20)
        self.planner = OnlinePlanner(env, plan, horizon_tokens=horizon_tokens) \
            if use_planner or planner_full_layer_fallback else None
        self.full_layer_fallback = planner_full_layer_fallback
        self.kv = KVTransferProtocol(env, plan, self.planner) \
            if (use_kv_transfer and self.planner) else None
        if self.kv:
            self.kv.init_transfers(ctx_tokens=prompt_tokens)
        # per-device rolling loader state: when next segment's weights land
        self._loader_free = [0.0] * self.D
        self._load_done = [[0.0] * (self.n_seg + 1) for _ in range(self.D)]
        # arrival-driven stepping state (LIME-Serve, DESIGN.md §9): the
        # virtual clock, the autoregressive step counter, and the current
        # network bandwidth. run() and step_once() share these.
        self.now = 0.0
        self._tok_count = 0
        self._bw = env.bw_net
        # paged KV accounting (DESIGN.md §10): when a PagePool is attached
        # the KV-transfer protocol's Eq. 8 volumes are reconciled against
        # it every step (delegated tokens -> host-tier pages) and
        # scheduler-driven spill/fetch traffic is priced via
        # charge_transfer().
        self.page_pool = None
        self.kv_moved_bytes = 0.0

    def attach_page_pool(self, pool) -> None:
        self.page_pool = pool

    def set_true_env(self, true_env: CostEnv) -> None:
        """Inject a ground-truth drift mid-run (thermal throttle, SSD
        contention): subsequent steps *execute* at true_env's rates while
        the planner keeps reasoning with `self.env` until a re-fit folds
        the observed drift back in."""
        self.true_env = true_env

    def attach_refit(self, refit) -> None:
        """Wire an OnlineRefit: the sim feeds it per-segment fetch and
        compute observations and gives it a shot at rebuilding after
        every step. The refit must share `self.env` (the planned model)."""
        if not isinstance(self.env.devices, list):
            self.env.devices = list(self.env.devices)
        refit.env = self.env
        refit.planner = self.planner
        self.refit = refit

    def charge_transfer(self, nbytes: float) -> float:
        """Price scheduler-driven page movement (preemption spill/fetch)
        at the current network bandwidth; advances the virtual clock —
        unlike Eq. 8 delegation, a forced swap is on the critical path."""
        dt = nbytes / max(self._bw, 1e-9)
        self.now += dt
        self.kv_moved_bytes += nbytes
        return dt

    # -- per-device per-segment quantities -------------------------------------
    def _layers_seg(self, i: int) -> float:
        d = self.plan.stages[i]
        return d.resident_total / self.n_seg + d.off_layers_seg()

    def _comp_seg_mb(self, i: int, ctx: int, q_len: int = 1,
                     env: Optional[CostEnv] = None) -> float:
        """One micro-batch's compute for device i's slice of one segment.
        q_len > 1 prices a speculative verify round (DESIGN.md §11): the
        round scores q_len query positions, so FLOPs and KV reads scale
        with q_len (mb -> mb*q_len in the roofline) while weight bytes —
        the term that dominates offloaded decode — are read once.
        Prices from true_env (what the hardware does); pass env=self.env
        to price the planned model instead (re-fit drift observation)."""
        env = self.true_env if env is None else env
        w = dataclasses.replace(self.w, ctx=max(ctx, 1),
                                mb=self.w.mb * max(q_len, 1))
        return self._layers_seg(i) * w.comp_layer(env.devices[i])

    def _load_bytes_seg(self, i: int) -> float:
        d = self.plan.stages[i]
        extra = self.planner.extra_load_bytes_seg(i) if self.planner else 0.0
        if self.full_layer_fallback and self.planner:
            st = self.planner.states[i]
            if st.alpha or st.beta:    # ablation: whole layers, not blocks
                extra = max(st.alpha, st.beta) * self.w.l_size
        total = d.load_bytes_seg(self.w) + extra
        if self.kv:
            # delegated KV frees memory that pins blocks resident (Eq. 8 win)
            total = max(total - self.kv.load_reduction_bytes_seg(i), 0.0)
        return total

    def _hop_time(self, bw: float, q_len: int = 1) -> float:
        """q_len positions hop together in a verify round — the ring
        hands q_len activations per micro-batch."""
        return max(q_len, 1) * self.w.h_size / bw + self.env.net_latency

    # -- one auto-regressive step ----------------------------------------------
    def _step(self, t0: float, ctx: int, bw: float, n_micro: int,
              q_len: int = 1,
              q_lens: Optional[List[int]] = None) -> Tuple[float, float, float]:
        """Returns (t_end, load_stall, comm_time). `q_lens` gives each
        micro-batch its own query count (a *mixed* round: decode streams at
        q=1 riding the same weight-stream as a chunked-prefill stream at
        q=chunk — DESIGN.md §12); `q_len` is the uniform shorthand."""
        D, S = self.D, self.n_seg
        qs = list(q_lens) if q_lens is not None else [q_len] * n_micro
        assert len(qs) == n_micro, (len(qs), n_micro)
        dev_free = [t0] * D
        stall = 0.0
        comm = 0.0
        # flight recorder (DESIGN.md §15): one stage.compute span per
        # (device, segment) on "dev:<i>", one weight.fetch span per
        # interleave fetch on "dev:<i>:loader" — the Perfetto view where
        # load/compute overlap (the paper's whole argument) is *visible*
        tr = get_tracer()
        # activation readiness per micro-batch (enters device 0, segment 0)
        ready = [t0] * n_micro
        for s in range(S):
            for i in range(D):
                w_ready = self._load_done[i][s % S]
                last_end = dev_free[i]
                seg_start = None
                seg_stall = 0.0
                hop = 0.0
                for m in range(n_micro):
                    hop = self._hop_time(bw, qs[m])
                    start = max(ready[m], dev_free[i], w_ready)
                    if seg_start is None:
                        seg_start = start
                    mb_stall = max(w_ready - max(ready[m], dev_free[i]), 0.0)
                    stall += mb_stall
                    seg_stall += mb_stall
                    end = start + self._comp_seg_mb(i, ctx, qs[m])
                    dev_free[i] = end
                    ready[m] = end + hop
                    comm += hop
                    last_end = end
                if tr is not None and seg_start is not None:
                    tr.complete(tr_ev.STAGE_COMPUTE, ts=seg_start,
                                dur=last_end - seg_start, track=dev_track(i),
                                args={"segment": s, "n_micro": n_micro,
                                      "stall_s": seg_stall})
                    if seg_stall > 0:
                        # the stall is always the FIRST micro-batch waiting
                        # on w_ready (later ones inherit dev_free >= w_ready)
                        # so it is one contiguous interval ending at
                        # seg_start — emit it as a span so critical-path
                        # attribution can classify the wall-clock it covers
                        tr.complete(tr_ev.WEIGHT_STALL,
                                    ts=seg_start - seg_stall, dur=seg_stall,
                                    track=dev_track(i),
                                    args={"stall_s": seg_stall,
                                          "segment": s})
                    # last micro-batch's hand-off to the next device
                    tr.complete(tr_ev.ACT_HOP, ts=last_end, dur=hop,
                                track=dev_track(i), args={"segment": s})
                if self.refit is not None and seg_start is not None:
                    actual = sum(self._comp_seg_mb(i, ctx, qm) for qm in qs)
                    planned = sum(self._comp_seg_mb(i, ctx, qm, env=self.env)
                                  for qm in qs)
                    self.refit.observe_compute(i, actual, planned,
                                               now=last_end)
                # interleave: evict seg-s blocks, fetch seg-(s+1) blocks
                lb = self._load_bytes_seg(i)
                if lb > 0:
                    ld_start = max(last_end, self._loader_free[i])
                    ld_end = ld_start + lb / self.true_env.devices[i].load_bw
                    # KV-transfer wire time rides the otherwise-idle network
                    # inside the uncovered window (Eq. 8 sizes it to fit), so
                    # it adds no loader-channel latency by construction.
                    self._loader_free[i] = ld_end
                    self._load_done[i][(s + 1) % S] = ld_end
                    if self.refit is not None:
                        self.refit.observe_fetch(i, lb, ld_end - ld_start,
                                                 now=ld_end)
                    if tr is not None:
                        tr.complete(tr_ev.WEIGHT_FETCH, ts=ld_start,
                                    dur=ld_end - ld_start,
                                    track=loader_track(i),
                                    args={"segment": (s + 1) % S,
                                          "bytes": lb})
        return max(max(dev_free), max(ready)), stall, comm

    # -- arrival-driven stepping (LIME-Serve) ------------------------------------
    def reset_clock(self) -> None:
        """Restore the t=0 state run() historically assumed. The clock,
        token counter, bandwidth, and loader timeline persist across
        run()/step_once() calls (arrival-driven serving needs that); call
        this before reusing one sim instance for an independent run."""
        self.now = 0.0
        self._tok_count = 0
        self._bw = self.env.bw_net
        self.kv_moved_bytes = 0.0
        self._loader_free = [0.0] * self.D
        self._load_done = [[0.0] * (self.n_seg + 1) for _ in range(self.D)]

    def advance_to(self, t: float) -> None:
        """Idle the fleet until virtual time `t` (waiting for an arrival)."""
        self.now = max(self.now, t)

    def step_once(self, *, ctx: Optional[int] = None, n_micro: int = 1,
                  kv_tokens: Optional[int] = None,
                  q_len: int = 1,
                  q_lens: Optional[List[int]] = None) -> StepTrace:
        """One autoregressive step at the current virtual clock.

        ctx: KV read span this step (default: prompt + steps taken, the
        fixed-loop behaviour). n_micro: micro-batches in flight *this step*
        — the serving layer passes the live slot count, so a half-full
        pipeline is priced as one. kv_tokens: effective per-stream token
        count for the OnlinePlanner's TS thresholds (default ctx); the
        serving layer passes Σ_active ctx_i / n_micro_env so admission-level
        KV accounting is what walks the ladder (paper Eq. 5). q_len: query
        positions scored this round (speculative verify, DESIGN.md §11) —
        compute and activation hops scale with q_len, weight streaming
        does not, which is exactly why the verify round amortizes the
        per-round load bytes over every accepted token. q_lens:
        per-micro-batch query counts for mixed rounds (chunked prefill
        riding alongside live decode streams, DESIGN.md §12); overrides
        q_len when given.
        """
        tok = self._tok_count
        if ctx is None:
            ctx = self.prompt + tok
        if self.bw_schedule:
            new_bw = self.bw_schedule(tok)
            if new_bw != self._bw:
                if self.kv:
                    self.kv.on_bandwidth(new_bw, ctx * n_micro)
                self._bw = new_bw
        fired = False
        moved = 0.0
        if self.planner:
            if self.kv:
                self.kv.refresh(ctx)
                if self.page_pool is not None:
                    # Eq. 8 volumes become page migrations on the attached
                    # pool; sized to ride idle network, so wire volume is
                    # recorded but adds no step latency
                    moved = self.kv.sync_pool(self.page_pool)
                    self.kv_moved_bytes += moved
                    if moved > 0:
                        tr = get_tracer()
                        if tr is not None:
                            tr.instant(tr_ev.KV_MIGRATE, ts=self.now,
                                       track=tr_ev.TRACK_KV,
                                       args={"bytes": moved})
            offsets = [self.kv.transferred_tokens(i)
                       for i in range(self.D)] if self.kv else None
            eff = ctx if kv_tokens is None else kv_tokens
            fired = bool(self.planner.on_token(eff, offsets))
        t_end, stall, comm = self._step(self.now, ctx, self._bw, n_micro,
                                        q_len, q_lens)
        trace = StepTrace(tok, t_end - self.now, stall, comm, fired,
                          kv_moved_bytes=moved)
        self.now = t_end
        self._tok_count += 1
        if self.refit is not None:
            self.refit.maybe_refit(self.now)
        return trace

    # -- main loop ---------------------------------------------------------------
    def run(self, n_tokens: int, *, n_micro: int = 1,
            oot_s_per_token: Optional[float] = None) -> SimResult:
        """Fixed token loop from t=0 (resets the clock — the historical
        contract; arrival-driven serving drives step_once() directly and
        never calls this)."""
        self.reset_clock()
        traces: List[StepTrace] = []
        for _ in range(n_tokens):
            traces.append(self.step_once(n_micro=n_micro))
            if oot_s_per_token and traces[-1].latency > oot_s_per_token:
                return SimResult(traces, oot=True,
                                 reason=f"{traces[-1].latency:.1f}s/token")
        return SimResult(traces)


# ----------------------------------------------------------------------------
# Convenience wrapper: schedule + simulate LIME
# ----------------------------------------------------------------------------
def simulate_lime(env: CostEnv, n_layers: int, n_tokens: int, *,
                  n_micro: int = 1, n_emp: int = 512, prompt: int = 64,
                  use_planner: bool = True, use_kv_transfer: bool = True,
                  planner_full_layer_fallback: bool = False,
                  bandwidth_schedule=None,
                  oot_s_per_token: Optional[float] = None) -> SimResult:
    from repro.core.offline_scheduler import allocate
    r = allocate(env, n_layers, n_emp=n_emp)
    if not r.feasible:
        return SimResult([], oom=True, reason=r.reason)
    sim = InterleavedPipelineSim(
        env, r.plan, use_planner=use_planner,
        use_kv_transfer=use_kv_transfer,
        planner_full_layer_fallback=planner_full_layer_fallback,
        bandwidth_schedule=bandwidth_schedule, prompt_tokens=prompt)
    return sim.run(n_tokens, n_micro=n_micro, oot_s_per_token=oot_s_per_token)
