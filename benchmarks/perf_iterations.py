"""§Perf hillclimb driver: lowers baseline vs optimized variants for the
three chosen (arch x shape) pairs and prints the roofline deltas + HLO
collective inventories side by side. Run inside the dry-run environment:

  PYTHONPATH=src python -m benchmarks.perf_iterations [--pair H1|H2|H3]

H1  kimi-k2-1t-a32b x decode_32k   (collective-bound; the paper's technique)
    slot-fetch (paper-literal)  ->  step-fetch  ->  resident (budget retune)
H2  internlm2-1.8b x train_4k      (worst fraction; TP-allreduce-bound)
    16-way TP  ->  pure DP (weights replicated, batch over all axes)
H3  gemma3-1b x long_500k          (bubble-bound sporadic decode)
    16-stage LIME pipeline  ->  pipeline-free TP serve_step
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
import argparse

import numpy as np


def measure(arch, shape, mesh, **kw):
    from repro.launch.dryrun import analyze, analytic_terms, lower_pair
    lowered = lower_pair(arch, shape, mesh, **kw)
    compiled = lowered.compile()
    n_dev = int(np.prod(list(mesh.shape.values())))
    info = analyze(lowered, compiled, n_dev)
    return info


def show(tag, info, terms):
    t = terms.as_dict()
    mem = info["memory_per_device"]
    coll = {k: round(v / 1e6, 1)
            for k, v in info["hlo_collectives"]["bytes"].items() if v}
    print(f"  {tag}:")
    print(f"    compute={t['compute_s']*1e3:.2f}ms "
          f"memory={t['memory_s']*1e3:.2f}ms "
          f"collective={t['collective_s']*1e3:.2f}ms "
          f"dominant={t['dominant']}")
    print(f"    wire/dev={t['wire_bytes_per_dev']/1e9:.2f}GB  "
          f"peak HBM={mem['peak_bytes']/1e9:.2f}GB  "
          f"HLO collectives(MB)={coll}")


def h1(mesh):
    from repro.launch import roofline as RL
    from repro.launch.dryrun import analytic_terms
    from repro.configs.registry import get_config, INPUT_SHAPES
    print("H1: kimi-k2-1t-a32b x decode_32k — streamed-weight traffic")
    arch, shape = "kimi-k2-1t-a32b", "decode_32k"
    # baseline: paper-literal per-slot streaming
    info = measure(arch, shape, mesh, fetch_mode="slot")
    show("baseline (slot fetch)", info, analytic_terms(arch, shape, mesh,
                                                       "slot"))
    # iteration 1: per-step restore
    info = measure(arch, shape, mesh, fetch_mode="step")
    show("iter1 (step fetch)", info, analytic_terms(arch, shape, mesh,
                                                    "step"))
    # iteration 2: all-resident — raise the weight budget so the plan keeps
    # every layer resident (61L x 34GB / 256 chips = 8.3 GB/chip fits)
    import repro.launch.dryrun as DR
    cfg = get_config(arch)
    orig = DR.decode_plan

    def resident_plan(cfg_, n_stage):
        import math
        from repro.core.engine import UniformPlan
        k = math.ceil(cfg_.n_layers / n_stage)
        return UniformPlan(n_stage, 1, k, 0)
    DR.decode_plan = resident_plan
    try:
        info = measure(arch, shape, mesh, fetch_mode="step")
        ms = dict(mesh.shape)
        t = RL.decode_terms(cfg, INPUT_SHAPES[shape], ms, n_seg=1,
                            k_res=4, k_off=0, n_mb=16, mb=8)
        show("iter2 (all resident)", info, t)
    finally:
        DR.decode_plan = orig


def h2(mesh):
    from repro.launch import roofline as RL
    from repro.configs.registry import get_config, INPUT_SHAPES
    print("H2: internlm2-1.8b x train_4k — TP allreduce vs pure DP")
    arch, shape = "internlm2-1.8b", "train_4k"
    cfg = get_config(arch)
    ms = dict(mesh.shape)
    info = measure(arch, shape, mesh, strategy="default")
    show("baseline (16-way TP)", info,
         RL.train_terms(cfg, INPUT_SHAPES[shape], ms, "tp"))
    info = measure(arch, shape, mesh, strategy="dp")
    show("iter1 (pure DP, replicated weights)", info,
         RL.train_terms(cfg, INPUT_SHAPES[shape], ms, "dp"))


def h3(mesh):
    from repro.launch import roofline as RL
    from repro.launch.dryrun import analytic_terms
    from repro.configs.registry import get_config, INPUT_SHAPES
    print("H3: gemma3-1b x long_500k — pipeline bubbles vs TP serving")
    arch, shape = "gemma3-1b", "long_500k"
    cfg = get_config(arch)
    ms = dict(mesh.shape)
    info = measure(arch, shape, mesh, fetch_mode="step")
    show("baseline (LIME pipeline, n_mb=1)", info,
         analytic_terms(arch, shape, mesh))
    info = measure(arch, shape, mesh, strategy="tp_serve")
    # analytic: no pipeline => no stage axis; all 256 chips tensor-parallel
    ms_tp = {"data": 1, "model": ms.get("data", 1) * ms.get("model", 1),
             **({"pod": ms["pod"]} if "pod" in ms else {})}
    t = RL.decode_terms(cfg, INPUT_SHAPES[shape], ms_tp, n_seg=1,
                        k_res=cfg.n_layers, k_off=0, n_mb=1, mb=1,
                        long_mode=True)
    show("iter1 (TP-only serve_step)", info, t)
    # flops-occupancy: pipeline computes garbage during fill/drain
    base = analytic_terms(arch, shape, mesh)
    mf = 2.0 * cfg.active_params() * 1
    print(f"    useful-flops ratio: pipeline={mf/base.flops:.2f} "
          f"tp={mf/t.flops:.2f}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default="all",
                    choices=("all", "H1", "H2", "H3"))
    args = ap.parse_args(argv)
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh()
    if args.pair in ("all", "H1"):
        h1(mesh)
    if args.pair in ("all", "H2"):
        h2(mesh)
    if args.pair in ("all", "H3"):
        h3(mesh)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
