"""Serving launcher: LIME-Serve over the interleaved pipeline (DESIGN.md §9).

  # CPU demo (4 virtual stages), bursty traffic:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --stages 4 --pattern bursty --requests 4 --max-new 16

  # Poisson arrivals at 2 req/s through the same engine:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --stages 4 --pattern poisson --rate-rps 2 --requests 8
"""
from __future__ import annotations

import argparse
import json


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--pattern",
                    choices=("sporadic", "bursty", "poisson", "trace",
                             "shared_prefix", "multiturn"),
                    default="sporadic")
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gap-s", type=float, default=2.0)
    ap.add_argument("--rate-rps", type=float, default=1.0)
    ap.add_argument("--arrival-trace", default=None,
                    help="JSON arrival trace for --pattern trace")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="flight-recorder output (DESIGN.md §15): Chrome "
                         "trace-event JSON loadable in Perfetto, or JSONL "
                         "when PATH ends in .jsonl")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="fleet mode (DESIGN.md §16): N replica serving "
                         "stacks behind the router, each its own backend "
                         "(real execution, single-device fallback each — "
                         "one engine cannot back N independent replicas)")
    ap.add_argument("--router", default="prefix",
                    choices=("prefix", "sticky", "random", "roundrobin"),
                    help="fleet placement policy (--replicas > 1)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding: draft k tokens, verify "
                         "them in one pipeline round (DESIGN.md §11)")
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--spec-draft", default="ngram",
                    choices=("ngram", "model", "resident"),
                    help="draft provider; 'resident' self-drafts through "
                         "the target's own resident tier with retier-"
                         "adaptive depth (DESIGN.md §14)")
    ap.add_argument("--plan", choices=("uniform", "hetero"),
                    default="uniform",
                    help="uniform: hand-built homogeneous split; hetero: "
                         "run the offline allocation scheduler over "
                         "per-stage device profiles and execute its "
                         "heterogeneous ExecutionPlan (DESIGN.md §13)")
    ap.add_argument("--adapt", action="store_true",
                    help="online memory adaptation: an OnlinePlanner walks "
                         "KV page occupancy and retiers the live engine — "
                         "resident layers demote to the streamed tier, "
                         "their HBM becomes KV pages (DESIGN.md §13)")
    ap.add_argument("--retier-headroom", type=int, default=1,
                    help="streamed-store slots per stage reserved for "
                         "runtime demotions (--adapt)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache over real KV pages "
                         "(single-device fallback only — DESIGN.md §12)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill span (0 = monolithic)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="KV page size (prefix sharing is page-granular: "
                         "pick <= prefix length for smoke prompts)")
    ap.add_argument("--n-templates", type=int, default=4)
    ap.add_argument("--prefix-len", type=int, default=32)
    ap.add_argument("--turns", type=int, default=3)
    ap.add_argument("--slo", action="store_true",
                    help="online SLO engine (DESIGN.md §17): burn-rate "
                         "alerts on TTFT/TPOT/goodput/reject targets, "
                         "health fed to router scoring and planner "
                         "pressure; final report gains an 'slo' section")
    ap.add_argument("--slo-ttft", type=float, default=8.0,
                    help="TTFT p99 threshold in seconds (--slo)")
    ap.add_argument("--slo-tpot", type=float, default=1.0,
                    help="TPOT p50 threshold in seconds/token (--slo)")
    ap.add_argument("--measure", action="store_true",
                    help="measured-profile autotune (DESIGN.md §18): run "
                         "the microbenchmark harness on this device and "
                         "plan from timed FLOP/s + stream bandwidth "
                         "instead of the analytic knobs; results persist "
                         "to --profile-cache")
    ap.add_argument("--profile-cache", default=None, metavar="PATH",
                    help="tune-cache JSON (measured profiles + swept "
                         "kernel block configs, keyed by device kind); "
                         "loaded at startup — tuned kernel configs are "
                         "installed before the first trace — and updated "
                         "by --measure. Default: ~/.cache/repro/"
                         "tune_cache.json when --measure is set")
    ap.add_argument("--refit", action="store_true",
                    help="online re-fit (DESIGN.md §18): EWMA-track "
                         "measured weight-stream bandwidth during "
                         "serving and rebuild the planner's TS ladders "
                         "when it drifts >20%% from the planned model")
    ap.add_argument("--dash-interval", type=float, default=0.0,
                    help="seconds between live dashboard snapshots on "
                         "stdout (0 = off; backend clock, so virtual "
                         "seconds in sim runs)")
    args = ap.parse_args(argv)

    import jax
    from repro.configs.registry import get_config, get_smoke_config
    from repro.core.engine import InterleavedEngine, UniformPlan
    from repro.models import model as M
    from repro.obs.log import get_logger
    from repro.obs.trace import Tracer, set_tracer
    from repro.serving import (ContinuousBatchingScheduler, LimeServer,
                               SamplerConfig, SchedulerConfig, cli_arrivals,
                               requests_from_arrivals, summarize)

    log = get_logger("repro.launch.serve")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())

    # measured-profile autotune (DESIGN.md §18): load the tune cache and
    # install tuned kernel block configs BEFORE any model code traces
    # (jit caches do not retrace on a later install); --measure runs the
    # harness and persists the profile for next launch
    measured = None
    if args.measure or args.profile_cache:
        from repro.tune import TuneCache, default_cache_path
        from repro.tune.measure import device_kind
        cache_path = args.profile_cache or default_cache_path()
        tune_cache = TuneCache.load(cache_path)
        dk = device_kind()
        n_installed = tune_cache.install(dk)
        if n_installed:
            log.info(f"installed {n_installed} tuned kernel configs "
                     f"for {dk} from {cache_path}")
        measured = tune_cache.get_profile(dk)
        if args.measure:
            from repro.core.profiles import TPU_V5E
            from repro.tune.measure import measure_profile
            log.info("running microbenchmark harness (--measure)...")
            measured = measure_profile(dk, TPU_V5E)
            tune_cache.put_profile(measured)
            tune_cache.save(cache_path)
            log.info(f"measured profile for {dk}: "
                     f"flops={measured.flops:.3g} "
                     f"load_bw={measured.load_bw:.3g} -> {cache_path}")
        elif measured is not None:
            log.info(f"planning from cached measured profile for {dk} "
                     f"(measured {measured.measured_at})")
    use_engine = n_dev >= args.stages * args.tp and args.stages > 1
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    engine = None
    planner = None
    if use_engine:
        mesh = jax.make_mesh((args.stages, args.tp), ("data", "model"))
        n_mb = args.stages if args.pattern != "sporadic" else 1
        env = None
        if args.plan == "hetero" or args.adapt:
            # per-stage profiles scaled to the model so the offline
            # scheduler actually offloads (real 16 GB chips would hold a
            # smoke model outright); --plan hetero varies the memory per
            # stage, so the emitted ExecutionPlan has unequal splits
            import dataclasses as _dc

            from repro.core.cost_model import CostEnv, Workload
            from repro.core.profiles import TPU_V5E, mbps
            base = cfg.total_params() * 2.0 / args.stages
            fracs = ([2.0, 1.2, 1.6, 1.0] if args.plan == "hetero"
                     else [1.5])

            # measured throughputs override the synthetic knobs (memory
            # stays the enforced budget — DESIGN.md §18)
            overrides = {}
            if measured is not None:
                from repro.tune.profiles import MEASURED_FIELDS
                overrides = {f: getattr(measured, f)
                             for f in MEASURED_FIELDS
                             if getattr(measured, f) > 0}

            def mk_env(scale):
                devs = [_dc.replace(TPU_V5E, name=f"stage{i}",
                                    mem_bytes=base * scale
                                    * fracs[i % len(fracs)],
                                    **overrides)
                        for i in range(args.stages)]
                return CostEnv(devs, mbps(200.0),
                               Workload(cfg, mb=1, ctx=args.prompt_len,
                                        n_micro=n_mb))
            env = mk_env(1.0)
        if args.plan == "hetero":
            from repro.core.offline_scheduler import allocate_with_retry
            r, env, scale = allocate_with_retry(mk_env, cfg.n_layers,
                                                n_emp=args.max_len)
            if not r.feasible:
                raise SystemExit(f"hetero allocation infeasible: {r.reason}")
            if scale > 1.0:
                log.info(f"hetero allocation relaxed memory x{scale:.2f} "
                         f"for feasibility")
            plan = r.plan
            log.info(f"hetero plan: seg={plan.n_seg} "
                     f"k_res={plan.k_res_list} k_off={plan.k_off_list}")
        else:
            # pad layers to a chunk grid; one streamed layer per chunk
            import math
            n_seg = 2
            k = math.ceil(cfg.n_layers / (n_seg * args.stages))
            plan = UniformPlan(args.stages, n_seg, max(k - 1, 0),
                               1 if k >= 1 else 0)
        engine = InterleavedEngine(
            cfg, mesh, plan, n_mb=n_mb, mb=1, max_len=args.max_len,
            retier_headroom=args.retier_headroom if args.adapt else 0)
        if args.adapt:
            from repro.core.online_planner import OnlinePlanner
            planner = OnlinePlanner(env, plan,
                                    horizon_tokens=4 * n_mb * args.max_len)
        log.info(f"engine: {args.stages} stages x tp{args.tp}, "
                 f"plan seg={plan.n_seg} chunks k_res={plan.k_res_list} "
                 f"k_off={plan.k_off_list} adapt={args.adapt}")
    else:
        log.info("single-device fallback (no engine)")

    if args.refit and planner is None:
        log.info("--refit needs an OnlinePlanner to rebuild (engine path "
                 "with --adapt); ignoring")

    spec = None
    if args.spec:
        from repro.specdec import SpecConfig
        spec = SpecConfig(k=args.spec_k, draft=args.spec_draft,
                          seed=args.seed)
    srv = LimeServer(cfg, params, engine=engine, max_len=args.max_len,
                     pattern="sporadic" if args.pattern == "sporadic"
                     else "bursty",
                     sampler=SamplerConfig(temperature=args.temperature),
                     spec=spec,
                     prefix_cache=args.prefix_cache,
                     prefill_chunk_tokens=args.prefill_chunk,
                     page_size=args.page_size,
                     planner=planner, refit=args.refit)

    arrivals = cli_arrivals(args.pattern, args.requests, seed=args.seed,
                            prompt_len=args.prompt_len,
                            max_new_tokens=args.max_new, gap_s=args.gap_s,
                            burst_size=srv.slots, rate_rps=args.rate_rps,
                            n_templates=args.n_templates,
                            prefix_len=args.prefix_len, turns=args.turns,
                            trace=args.arrival_trace)

    # adaptation rides page-granular admission: note_kv_pages feeds the
    # planner, and the scheduler can reclaim retier headroom pre-preempt
    scfg = SchedulerConfig(kv_policy="paged", page_size=args.page_size) \
        if args.adapt else SchedulerConfig()
    # flight recorder: installed before the scheduler is built (it caches
    # the tracer and binds its clock to the backend at construction)
    tracer = None
    if args.trace:
        tracer = Tracer()
        set_tracer(tracer)

    def mk_slo():
        if not args.slo:
            return None
        from repro.obs.slo import SLOEngine, default_targets
        return SLOEngine(default_targets(ttft_p99_s=args.slo_ttft,
                                         tpot_p50_s=args.slo_tpot))

    fleet_report = None
    slo = None
    try:
        reqs = requests_from_arrivals(arrivals, vocab_size=cfg.vocab_size)
        if args.replicas > 1:
            # fleet mode (DESIGN.md §16): N real-execution replicas (each
            # the single-device fallback backend — one InterleavedEngine
            # cannot back N independent replicas) behind the router
            from repro.fleet import Fleet, Replica, RouterConfig
            from repro.serving import EngineBackend
            if engine is not None:
                log.info("fleet mode: replicas run the single-device "
                         "fallback backend (engine ignored)")
            reps = [Replica(i, EngineBackend(
                        cfg, params, engine=None, n_slots=srv.slots,
                        max_len=args.max_len, sampler=srv.sampler,
                        spec=spec, prefix_cache=args.prefix_cache,
                        prefill_chunk_tokens=args.prefill_chunk,
                        page_size=args.page_size), scfg)
                    for i in range(args.replicas)]
            if args.slo:
                # one engine per replica: health is a per-replica signal
                # (the router sheds off the breaching one, not the fleet)
                for rep in reps:
                    rep.sched.attach_slo(mk_slo())
            fleet = Fleet(reps, config=RouterConfig(policy=args.router,
                                                    seed=args.seed))
            result = fleet.run(reqs)
            done = result.requests
            fleet_report = result.report(
                pattern=args.pattern, backend=f"fleet{args.replicas}")
        else:
            sched = ContinuousBatchingScheduler(srv.make_backend(), scfg)
            slo = mk_slo()
            if slo is not None:
                sched.attach_slo(slo)
            if args.dash_interval > 0:
                from repro.obs.dashboard import Dashboard
                dash = Dashboard(slo=slo, sched=sched, tracer=tracer,
                                 interval_s=args.dash_interval)
                sched.begin(reqs)
                while sched.step():
                    snap = dash.tick(sched.now())
                    if snap is not None:
                        print(snap)
                done = sched.finish_run()
                print(dash.render(sched.now()))
            else:
                done = sched.serve(reqs)
    finally:
        if tracer is not None:
            set_tracer(None)
    if tracer is not None:
        tracer.export(args.trace)
        log.info(f"trace: {args.trace} ({tracer.emitted} events, "
                 f"{tracer.dropped} dropped)")
    for r in sorted(done, key=lambda r: r.rid):
        status = "REJECTED" if r.rejected else \
            f"ttft {r.ttft_s:.2f}s total {r.latency_s:.2f}s " \
            f"out[:8]={r.output[:8]}"
        print(f"req {r.rid}: {status}")
    if fleet_report is not None:
        print(fleet_report.to_json())
    else:
        report = summarize(done, pattern=args.pattern,
                           backend="engine" if engine else "fallback",
                           stats=sched.stats)
        doc = report.to_dict()
        if slo is not None:
            doc["slo"] = slo.snapshot(sched.now())
        print(json.dumps(doc, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
