"""Jit'd public wrapper for flash-decode attention.

Model layout in: q (B, 1, H, dh), cache (B, S_c, KV, dh), pos_ids (S_c,).
Pads S_c to the kv block and dh to 128 lanes; padded slots get pos_id = -1
so the kernel's validity mask drops them — no separate padding mask needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.decode_attention.kernel import decode_attention_kernel

GLOBAL_WINDOW = 2 ** 30


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, pos_ids, pos, *, window=None,
                     block_k=None, interpret=None):
    """q: (B, 1, H, dh); k/v_cache: (B, S_c, KV, dh); pos_ids: (S_c,);
    pos: int32 scalar -> (B, 1, H, dh). block_k=None consults the tuned
    table (repro.kernels.tuning) at trace time; 512 with none installed."""
    if interpret is None:
        interpret = _auto_interpret()
    B, _, H, dh = q.shape
    S_c, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    if window is None:
        window = GLOBAL_WINDOW
    block_k = tuning.resolve("decode_attention", S_c, dh, "block_k", block_k)

    bk = min(block_k, max(S_c, 128))
    pad_s = (-S_c) % bk
    pad_d = (-dh) % 128

    qk = jnp.moveaxis(q.reshape(B, KV, G, dh), 0, 0)       # already (B,KV,G,dh)
    kt = jnp.moveaxis(k_cache, 2, 1)                       # (B, KV, S_c, dh)
    vt = jnp.moveaxis(v_cache, 2, 1)
    if pad_s or pad_d:
        qk = jnp.pad(qk, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, pad_d)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, pad_d)))
    ids = jnp.pad(pos_ids.astype(jnp.int32), (0, pad_s),
                  constant_values=-1).reshape(1, -1)

    out = decode_attention_kernel(qk, kt, vt, ids, pos, window,
                                  dh_real=dh, block_k=bk,
                                  interpret=interpret)
    return out[..., :dh].reshape(B, 1, H, dh)
