"""Paper Tab. V: ablation of the online memory-aware planner and the KV
cache transfer protocol (llama3.3-70b, both request patterns).

The regime is probed so the planner's thresholds actually fire during the
run (the paper's setup generates until memory saturates)."""
from benchmarks.common import Row
from repro.configs.registry import get_config
from repro.core.cost_model import CostEnv, Workload
from repro.core.offline_scheduler import allocate
from repro.core.online_planner import OnlinePlanner
from repro.core.pipeline_sim import simulate_lime
from repro.core.profiles import env_lowmem, mbps

N = 500


def _probe_prompt(devices, cfg, nm, n_tokens):
    w = Workload(cfg, mb=1, ctx=1024, n_micro=nm)
    env = CostEnv(devices, mbps(200), w)
    r = allocate(env, cfg.n_layers, n_emp=1024)
    if not r.feasible:
        return 1024
    pl = OnlinePlanner(env, r.plan, horizon_tokens=2 ** 20)
    ts = [l[0].threshold_tokens for l in pl.ladders if l]
    return max(min(ts) - n_tokens // 4, 512) if ts else 4096


def run():
    cfg = get_config("llama3.3-70b")
    devices = env_lowmem(1)
    rows = []
    for pattern, nm in (("sporadic", 1), ("bursty", 5)):
        P = _probe_prompt(devices, cfg, nm, N)
        w = Workload(cfg, mb=1, ctx=P, n_micro=nm)
        env = CostEnv(devices, mbps(200), w)
        kw = dict(n_micro=nm, n_emp=max(P // 2, 512), prompt=P)
        full = simulate_lime(env, cfg.n_layers, N, **kw)
        no_kv = simulate_lime(env, cfg.n_layers, N, use_kv_transfer=False,
                              **kw)
        no_pl = simulate_lime(env, cfg.n_layers, N,
                              planner_full_layer_fallback=True, **kw)
        sc = f"ablation/{pattern}"
        rows += [Row(sc, "LIME", full.ms_per_token),
                 Row(sc, "no-kv-transfer", no_kv.ms_per_token),
                 Row(sc, "no-planner", no_pl.ms_per_token)]
        print(f"{sc}: LIME {full.ms_per_token:.1f} | "
              f"no-KV-transfer {no_kv.ms_per_token:.1f} "
              f"({full.ms_per_token/no_kv.ms_per_token:.2f}x) | "
              f"no-planner {no_pl.ms_per_token:.1f} "
              f"({full.ms_per_token/no_pl.ms_per_token:.2f}x) "
              f"[paper: 0.86x / 0.67x]")
    return rows


if __name__ == "__main__":
    run()
