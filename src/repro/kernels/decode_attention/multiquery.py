"""Multi-query flash-decode attention: q_len > 1 query positions per step.

Speculative decoding (repro.specdec, DESIGN.md §11) verifies k drafted
tokens in one pass: the q_len = k+1 newest positions of each sequence
attend to the whole cache — including each other, through the cache,
because their K/V are written before attention runs. Causality between
the new positions is purely a masking question: query row qi (absolute
position P+qi) may see cache token t iff t's position <= P+qi (and the
sliding window). Both kernels here are the q_len=1 kernels of this
package with the G query-head rows widened to q_len*G and the validity
mask made per-row:

  mq_decode_attention        contiguous cache, pos_ids slot validity
                             (the engine's per-stage layout)
  mq_paged_decode_attention  block-table gather over a shared page pool
                             (the paged KV subsystem)

Bit-wise contract (test_specdec.py): each kernel equals its blocked jnp
reference bit-for-bit at bf16, and at q_len=1 reproduces the existing
single-query kernel's output exactly — speculative verification is
provably the same arithmetic as sequential decode, just batched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# one definition each — the bit-wise kernel-vs-ref contracts depend on
# every module in this package masking with the same constant
from repro.kernels import tuning
from repro.kernels.decode_attention.kernel import NEG_INF
from repro.kernels.decode_attention.ops import GLOBAL_WINDOW, _auto_interpret


# ============================================================================
# Contiguous-cache kernel (pos_ids validity, per-query positions)
# ============================================================================
def _mq_decode_kernel(scalars_ref,                   # SMEM: [pos, window]
                      q_ref, k_ref, v_ref, ids_ref,  # VMEM blocks
                      o_ref,                         # VMEM out
                      m_ref, l_ref, acc_ref,         # VMEM scratch
                      *, dh_real: int, block_k: int, q_len: int, g: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (q_len*G, dh)
    k = k_ref[0, 0].astype(jnp.float32)           # (block_k, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (dh_real ** -0.5)                     # (q_len*G, block_k)

    pos = scalars_ref[0]                          # first query's position
    window = scalars_ref[1]
    ids = ids_ref[0]                              # (block_k,) int32
    rows = s.shape[0]
    qpos = pos + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0) // g
    valid = (ids[None, :] >= 0) & (ids[None, :] <= qpos) \
        & ((qpos - ids[None, :]) < window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def mq_decode_attention_kernel(q, k, v, pos_ids, pos, window, *,
                               dh_real: int, q_len: int,
                               block_k: int = 512, interpret: bool = False):
    """q: (B, KV, q_len*G, dh) — row qi*G + g is query head g of position
    pos + qi; k, v: (B, KV, S_c, dh); pos_ids: (1, S_c) int32; pos (first
    query's absolute position), window: int32 scalars.
    Returns (B, KV, q_len*G, dh)."""
    B, KV, R, dh = q.shape
    assert R % q_len == 0, (R, q_len)
    g = R // q_len
    S_c = k.shape[2]
    block_k = min(block_k, S_c)
    grid = (B, KV, S_c // block_k)
    scalars = jnp.stack([jnp.asarray(pos, jnp.int32),
                         jnp.asarray(window, jnp.int32)])

    kernel = functools.partial(_mq_decode_kernel, dh_real=dh_real,
                               block_k=block_k, q_len=q_len, g=g)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, R, dh),
                             lambda b, h, ik, sc: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, dh),
                             lambda b, h, ik, sc: (b, h, ik, 0)),
                pl.BlockSpec((1, 1, block_k, dh),
                             lambda b, h, ik, sc: (b, h, ik, 0)),
                pl.BlockSpec((1, block_k),
                             lambda b, h, ik, sc: (0, ik)),
            ],
            out_specs=pl.BlockSpec((1, 1, R, dh),
                                   lambda b, h, ik, sc: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((R, 1), jnp.float32),
                pltpu.VMEM((R, 1), jnp.float32),
                pltpu.VMEM((R, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, R, dh), q.dtype),
        interpret=interpret,
    )(scalars, q, k, v, pos_ids)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def mq_decode_attention(q, k_cache, v_cache, pos_ids, pos, *, window=None,
                        block_k=None, interpret=None):
    """q: (B, q_len, H, dh); k/v_cache: (B, S_c, KV, dh); pos_ids: (S_c,);
    pos: int32 scalar, the absolute position of query 0 (query i sits at
    pos + i) -> (B, q_len, H, dh). block_k=None consults the tuned table
    (repro.kernels.tuning) at trace time; 512 with none installed."""
    if interpret is None:
        interpret = _auto_interpret()
    B, Q, H, dh = q.shape
    S_c, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    if window is None:
        window = GLOBAL_WINDOW
    block_k = tuning.resolve("mq_decode_attention", S_c, dh, "block_k",
                             block_k)

    bk = min(block_k, max(S_c, 128))
    pad_s = (-S_c) % bk
    pad_d = (-dh) % 128

    # (B, Q, KV, G, dh) -> (B, KV, Q*G, dh): row qi*G + g
    qk = q.reshape(B, Q, KV, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B, KV, Q * G, dh)
    kt = jnp.moveaxis(k_cache, 2, 1)                       # (B, KV, S_c, dh)
    vt = jnp.moveaxis(v_cache, 2, 1)
    if pad_s or pad_d:
        qk = jnp.pad(qk, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, pad_d)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, pad_d)))
    ids = jnp.pad(pos_ids.astype(jnp.int32), (0, pad_s),
                  constant_values=-1).reshape(1, -1)

    out = mq_decode_attention_kernel(qk, kt, vt, ids, pos, window,
                                     dh_real=dh, q_len=Q, block_k=bk,
                                     interpret=interpret)
    out = out[..., :dh].reshape(B, KV, Q, G, dh).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, Q, H, dh)


# ============================================================================
# Paged kernel (block-table gather, per-query positions)
# ============================================================================
def _mq_paged_kernel(bt_ref, lens_ref, win_ref,     # SMEM scalar prefetch
                     q_ref, k_ref, v_ref,           # VMEM blocks
                     o_ref,                         # VMEM out
                     m_ref, l_ref, acc_ref,         # VMEM scratch
                     *, dh_real: int, page_size: int, q_len: int, g: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (q_len*G, dh)
    k = k_ref[0, 0].astype(jnp.float32)           # (page_size, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (dh_real ** -0.5)                     # (q_len*G, page_size)

    ctx = lens_ref[b]                             # incl. the q_len new ones
    window = win_ref[0]
    allocated = bt_ref[b, ip] >= 0
    rows = s.shape[0]
    t = ip * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (rows, page_size), 1)
    qpos = ctx - q_len \
        + jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 0) // g
    valid = allocated & (t <= qpos) & ((qpos - t) < window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(ip == n_pages - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def mq_paged_decode_attention_kernel(q, k_pool, v_pool, block_tables,
                                     ctx_lens, window, *, dh_real: int,
                                     q_len: int, interpret: bool = False):
    """q: (B, KV, q_len*G, dh); k/v_pool: (P, KV, page_size, dh);
    block_tables: (B, max_pages) int32 (-1 = unallocated); ctx_lens: (B,)
    int32 counting tokens *including* the q_len new positions; window:
    int32 scalar. Returns (B, KV, q_len*G, dh)."""
    B, KV, R, dh = q.shape
    assert R % q_len == 0, (R, q_len)
    g = R // q_len
    page_size = k_pool.shape[2]
    max_pages = block_tables.shape[1]
    grid = (B, KV, max_pages)

    kernel = functools.partial(_mq_paged_kernel, dh_real=dh_real,
                               page_size=page_size, q_len=q_len, g=g)

    def kv_index(b, h, ip, bt, lens, win):
        return (jnp.maximum(bt[b, ip], 0), h, 0, 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, R, dh),
                             lambda b, h, ip, bt, lens, win: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, page_size, dh), kv_index),
                pl.BlockSpec((1, 1, page_size, dh), kv_index),
            ],
            out_specs=pl.BlockSpec((1, 1, R, dh),
                                   lambda b, h, ip, bt, lens, win:
                                   (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((R, 1), jnp.float32),
                pltpu.VMEM((R, 1), jnp.float32),
                pltpu.VMEM((R, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, R, dh), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), ctx_lens.astype(jnp.int32),
      jnp.asarray(window, jnp.int32)[None], q, k_pool, v_pool)


# ============================================================================
# Pure-jnp blocked oracle (bit-wise contract with the paged kernel)
# ============================================================================
def mq_paged_decode_attention_ref(q, k_pool, v_pool, block_tables, ctx_lens,
                                  *, window=None):
    """Same layouts as the public wrapper: q (B, q_len, H, dh); k/v_pool
    (P, page_size, KV, dh); block_tables (B, max_pages); ctx_lens (B,)
    incl. the q_len new positions. Walks pages with the kernel's exact
    online-softmax arithmetic, so interpret-mode kernel output must equal
    this bit-for-bit. Returns (B, q_len, H, dh)."""
    B, Q, H, dh = q.shape
    page_size, KV = k_pool.shape[1], k_pool.shape[2]
    G = H // KV
    R = Q * G
    max_pages = block_tables.shape[1]
    if window is None:
        window = GLOBAL_WINDOW

    qg = q.reshape(B, Q, KV, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B, KV, R, dh).astype(jnp.float32)
    kt = jnp.moveaxis(k_pool, 2, 1)               # (P, KV, page_size, dh)
    vt = jnp.moveaxis(v_pool, 2, 1)
    safe_bt = jnp.maximum(block_tables, 0)
    ctx = ctx_lens.astype(jnp.int32)

    # per-(b, kv-head) 2D dots, one per kernel grid step, rows padded to
    # the 8-row sublane tile (same rationale as paged_decode_attention_ref)
    Rp = max(R, 8)

    def _dot(a2, c2, contract):
        a2 = jnp.pad(a2, ((0, Rp - R), (0, 0)))
        out = jax.lax.dot_general(a2, c2, (((1,), (contract,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        return out[:R]

    def dot_qk(a, c):
        return jnp.stack([jnp.stack([_dot(a[b, h], c[b, h], 1)
                                     for h in range(KV)]) for b in range(B)])

    def dot_pv(a, c):
        return jnp.stack([jnp.stack([_dot(a[b, h], c[b, h], 0)
                                     for h in range(KV)]) for b in range(B)])

    rows = jnp.arange(R) // G                     # query index per row
    m = jnp.full((B, KV, R, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((B, KV, R, 1), jnp.float32)
    acc = jnp.zeros((B, KV, R, dh), jnp.float32)
    for ip in range(max_pages):
        k = kt[safe_bt[:, ip]].astype(jnp.float32)   # (B, KV, ps, dh)
        v = vt[safe_bt[:, ip]].astype(jnp.float32)
        s = dot_qk(qg, k) * (dh ** -0.5)             # (B, KV, R, ps)
        t = ip * page_size + jnp.arange(page_size)
        qpos = (ctx[:, None] - Q) + rows[None, :]    # (B, R)
        valid = (block_tables[:, ip] >= 0)[:, None, None] \
            & (t[None, None, :] <= qpos[:, :, None]) \
            & ((qpos[:, :, None] - t[None, None, :]) < window)
        s = jnp.where(valid[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1, keepdims=True)
        acc = acc * corr + dot_pv(p, v)
        m = m_new
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).astype(q.dtype)
    return out.reshape(B, KV, Q, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B, Q, H, dh)


# ============================================================================
# Public wrapper (model layout in)
# ============================================================================
@functools.partial(jax.jit, static_argnames=("interpret",))
def mq_paged_decode_attention(q, k_pool, v_pool, block_tables, ctx_lens, *,
                              window=None, interpret=None):
    """q: (B, q_len, H, dh); k/v_pool: (P, page_size, KV, dh);
    block_tables: (B, max_pages) int32 (-1 pads); ctx_lens: (B,) int32
    counting tokens incl. the q_len new positions -> (B, q_len, H, dh)."""
    if interpret is None:
        interpret = _auto_interpret()
    B, Q, H, dh = q.shape
    page_size, KV = k_pool.shape[1], k_pool.shape[2]
    G = H // KV
    if window is None:
        window = GLOBAL_WINDOW
    assert page_size % 8 == 0, f"page_size {page_size} not sublane-aligned"

    pad_d = (-dh) % 128
    qk = q.reshape(B, Q, KV, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B, KV, Q * G, dh)
    kt = jnp.moveaxis(k_pool, 2, 1)               # (P, KV, page_size, dh)
    vt = jnp.moveaxis(v_pool, 2, 1)
    if pad_d:
        qk = jnp.pad(qk, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, 0), (0, pad_d)))

    out = mq_paged_decode_attention_kernel(qk, kt, vt, block_tables,
                                           ctx_lens, window, dh_real=dh,
                                           q_len=Q, interpret=interpret)
    out = out[..., :dh].reshape(B, KV, Q, G, dh).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, Q, H, dh)
