"""InternLM2-1.8B — dense GQA decoder. [arXiv:2403.17297]"""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="internlm2-1.8b", family=Family.DENSE,
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92544, head_dim=128,
    attn_kind=AttnKind.FULL, rope_theta=1_000_000.0,
    source="InternLM2 technical report [arXiv:2403.17297]",
)
