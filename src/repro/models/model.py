"""Unified model builder for all assigned architecture families.

One :class:`~repro.configs.base.ModelConfig` fully determines

* ``build_param_specs(cfg)``   — ParamSpec pytree (layers scan-stacked)
* ``forward(cfg, params, tokens, ...)``          — train / prefill pass
* ``decode_step(cfg, params, cache, token, ...)``— one autoregressive token
* ``init_cache / cache_specs`` — per-family decode state

Layers are stacked on a leading ``L`` axis and executed with ``lax.scan`` so
HLO size (and hence 512-device dry-run compile time) is O(1) in depth. Families
with a leading dense layer before MoE layers (deepseek-moe, kimi-k2) run two
scans. gemma3's 5:1 local:global pattern is a per-layer traced ``window``
array fed to one homogeneous scan body.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import AttnKind, Family, ModelConfig
from repro.models import spec as pspec
from repro.models.attention import (attention_specs, attn_forward, attn_decode,
                                    attn_decode_multi, cross_attn_decode)
from repro.models.modules import (embed, embed_specs, mlp, mlp_specs, rms_norm,
                                  rms_norm_spec, unembed,
                                  round_up,  # noqa: F401  (M.* namespace API)
                                  cross_entropy_loss)  # noqa: F401
from repro.models.moe import moe_specs, moe_forward
from repro.models.ssm import (rwkv_timemix_specs, rwkv_channelmix_specs,
                              rwkv_timemix, rwkv_channelmix,
                              mamba_head_specs, mamba_forward, _causal_conv,
                              ssm_scan_ref)

GLOBAL_WINDOW = jnp.int32(2 ** 30)   # sentinel: effectively unwindowed


# ============================================================================
# Param specs
# ============================================================================
def _dense_layer_specs(cfg: ModelConfig) -> dict:
    s = {
        "ln1": rms_norm_spec(cfg.d_model),
        "attn": attention_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim),
    }
    if not cfg.parallel_block:
        s["ln2"] = rms_norm_spec(cfg.d_model)
    s["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff)
    return s


def _moe_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": rms_norm_spec(cfg.d_model),
        "attn": attention_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim),
        "ln2": rms_norm_spec(cfg.d_model),
        "moe": moe_specs(cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff,
                         cfg.n_shared_experts),
    }


def _rwkv_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": rms_norm_spec(cfg.d_model),
        "tm": rwkv_timemix_specs(cfg.d_model, cfg.n_heads, cfg.head_dim),
        "ln2": rms_norm_spec(cfg.d_model),
        "cm": rwkv_channelmix_specs(cfg.d_model, cfg.d_ff),
    }


def _hymba_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": rms_norm_spec(cfg.d_model),
        "attn": attention_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim),
        "mamba": mamba_head_specs(cfg.d_model, cfg.ssm_heads, cfg.head_dim,
                                  cfg.ssm_state_size),
        "ln_attn": rms_norm_spec(cfg.n_heads * cfg.head_dim),
        "ln_ssm": rms_norm_spec(cfg.ssm_heads * cfg.head_dim),
        "w_fuse": pspec.ParamSpec((cfg.n_heads * cfg.head_dim, cfg.d_model),
                                  ("ffn", "embed")),
        "ln2": rms_norm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff),
    }


def _encdec_decoder_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": rms_norm_spec(cfg.d_model),
        "attn": attention_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim),
        "ln_x": rms_norm_spec(cfg.d_model),
        "xattn": attention_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim),
        "ln2": rms_norm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff),
    }


def _layer_specs(cfg: ModelConfig) -> dict:
    if cfg.family in (Family.DENSE, Family.VLM):
        return _dense_layer_specs(cfg)
    if cfg.family == Family.MOE:
        return _moe_layer_specs(cfg)
    if cfg.family == Family.SSM:
        return _rwkv_layer_specs(cfg)
    if cfg.family == Family.HYBRID:
        return _hymba_layer_specs(cfg)
    if cfg.family == Family.ENCDEC:
        return _encdec_decoder_layer_specs(cfg)
    raise ValueError(cfg.family)


def build_param_specs(cfg: ModelConfig) -> dict:
    specs: Dict[str, Any] = dict(embed_specs(cfg.vocab_size, cfg.d_model,
                                             cfg.tie_embeddings))
    specs["final_norm"] = rms_norm_spec(cfg.d_model)
    n_dense_first = cfg.first_dense_layers if cfg.family == Family.MOE else 0
    if n_dense_first:
        specs["dense_layers"] = pspec.stack(_dense_layer_specs(cfg),
                                            n_dense_first, "layer")
    specs["layers"] = pspec.stack(_layer_specs(cfg),
                                  cfg.n_layers - n_dense_first, "layer")
    if cfg.family == Family.ENCDEC:
        specs["encoder"] = pspec.stack(_dense_layer_specs(cfg),
                                       cfg.n_encoder_layers, "layer")
        specs["enc_final_norm"] = rms_norm_spec(cfg.d_model)
    return specs


def init_params(cfg: ModelConfig, key):
    return pspec.init(key, build_param_specs(cfg))


def param_shapes(cfg: ModelConfig):
    return pspec.shapes(build_param_specs(cfg))


# ============================================================================
# Per-layer windows (gemma3 local:global; hymba sliding; others full)
# ============================================================================
def layer_windows(cfg: ModelConfig, n_layers: int, long_mode: bool = False,
                  offset: int = 0):
    """(n_layers,) int32 visibility window per layer."""
    if cfg.attn_kind == AttnKind.FULL:
        return jnp.full((n_layers,), GLOBAL_WINDOW)
    if cfg.attn_kind == AttnKind.SLIDING:
        return jnp.full((n_layers,), jnp.int32(cfg.window_size))
    if cfg.attn_kind == AttnKind.LOCAL_GLOBAL:
        idx = jnp.arange(n_layers) + offset     # offset may be traced
        is_global = (idx + 1) % (cfg.local_global_ratio + 1) == 0
        if long_mode:  # long-context serving: cap globals to the window too
            return jnp.full((n_layers,), jnp.int32(cfg.window_size))
        return jnp.where(is_global, GLOBAL_WINDOW, jnp.int32(cfg.window_size))
    return jnp.full((n_layers,), GLOBAL_WINDOW)


def kv_cache_len(cfg: ModelConfig, max_len: int, long_mode: bool = False) -> int:
    if cfg.attn_kind == AttnKind.NONE:
        return 0
    if cfg.attn_kind == AttnKind.SLIDING:
        return min(max_len, cfg.window_size)
    if cfg.attn_kind == AttnKind.LOCAL_GLOBAL and long_mode:
        return min(max_len, cfg.window_size)
    return max_len


# ============================================================================
# Layer bodies (sequence / train / prefill)
# ============================================================================
def _seq_body(cfg: ModelConfig, mesh, impl: str, moe: bool):
    """Returns scan body: (carry, (params_l, window_l)) -> (carry, kv_l)."""
    bc = _bconstraint(mesh)

    def body(carry, xs):
        x, aux = carry
        p, window = xs
        if cfg.family == Family.SSM:
            B, S, D = x.shape
            st = jnp.zeros((B, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                           jnp.float32)
            last = jnp.zeros((B, D), x.dtype)
            h, _, _ = rwkv_timemix(p["tm"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                   last, st, n_heads=cfg.n_heads,
                                   head_dim=cfg.head_dim,
                                   norm_eps=cfg.norm_eps, impl=impl)
            x = x + h
            h, _ = rwkv_channelmix(p["cm"], rms_norm(x, p["ln2"], cfg.norm_eps),
                                   jnp.zeros((B, D), x.dtype))
            x = bc(x + h)
            return (x, aux), jnp.zeros((0,), x.dtype)

        if cfg.family == Family.HYBRID:
            B, S, D = x.shape
            xn = rms_norm(x, p["ln1"], cfg.norm_eps)
            a_out, _ = attn_forward(p["attn"], xn, rope_theta=cfg.rope_theta,
                                    causal=True, window=window, impl=impl)
            conv0 = jnp.zeros((B, p["mamba"]["conv"].shape[0] - 1,
                               cfg.ssm_heads * cfg.head_dim), x.dtype)
            ssm0 = jnp.zeros((B, cfg.ssm_heads, cfg.ssm_state_size,
                              cfg.head_dim), jnp.float32)
            m_out, _, _ = mamba_forward(p["mamba"], xn, conv0, ssm0,
                                        n_heads=cfg.ssm_heads,
                                        head_dim=cfg.head_dim,
                                        ssm_size=cfg.ssm_state_size,
                                        norm_eps=cfg.norm_eps, impl=impl)
            fused = 0.5 * (rms_norm(a_out, p["ln_attn"], cfg.norm_eps)
                           + rms_norm(m_out, p["ln_ssm"], cfg.norm_eps))
            x = x + fused @ p["w_fuse"]
            x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
            return (bc(x), aux), jnp.zeros((0,), x.dtype)

        # attention families
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        a_out, (k, v) = attn_forward(p["attn"], xn, rope_theta=cfg.rope_theta,
                                     causal=True, window=window, impl=impl)
        if cfg.parallel_block:  # stablelm-2: attn and MLP share the pre-norm
            x = x + a_out + mlp(p["mlp"], xn)
        elif moe and "moe" in p:
            x = x + a_out
            m_out, l_aux = moe_forward(p["moe"],
                                       rms_norm(x, p["ln2"], cfg.norm_eps),
                                       cfg=cfg, mesh=mesh)
            x = x + m_out
            aux = aux + l_aux
        else:
            x = x + a_out
            x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return (bc(x), aux), (k, v)

    return body


_BATCH_AXES = ("pod", "data")     # activation batch-sharding axes


@contextlib.contextmanager
def batch_axes(axes):
    """Trace-time override of the activation batch axes. The DP-only
    training strategy (small models: replicate weights, shard batch over
    *all* mesh axes) wraps `.lower()` in `batch_axes(("pod","data","model"))`
    — see launch/dryrun.lower_train and EXPERIMENTS.md §Perf/H2."""
    global _BATCH_AXES
    prev = _BATCH_AXES
    _BATCH_AXES = tuple(axes)
    try:
        yield
    finally:
        _BATCH_AXES = prev


_SEQ_SHARD = False                # sequence parallelism for activations


@contextlib.contextmanager
def seq_shard(enabled: bool = True):
    """Trace-time toggle: shard the sequence dim of (B, S, D) activations
    over 'model' (Megatron sequence parallelism). Needed when remat layer
    carries exceed HBM (kimi-k2 train: 940 MB x 61 layers per chip without
    it — EXPERIMENTS.md §Dry-run)."""
    global _SEQ_SHARD
    prev = _SEQ_SHARD
    _SEQ_SHARD = enabled
    try:
        yield
    finally:
        _SEQ_SHARD = prev


def _bconstraint(mesh, batch_axes=None):
    if mesh is None:
        return lambda x: x
    seq_model = _SEQ_SHARD and "model" in mesh.shape
    axes = tuple(a for a in (batch_axes or _BATCH_AXES)
                 if a in mesh.shape)
    ba = axes if len(axes) > 1 else (axes[0] if axes else None)

    def f(x):
        sh = x.shape
        n = 1
        for a in (axes or ()):
            n *= mesh.shape[a]
        if ba is None or sh[0] % max(n, 1):
            return x
        rest = [None] * (len(sh) - 1)
        if seq_model and len(sh) == 3 \
                and sh[1] % mesh.shape["model"] == 0:
            rest[0] = "model"
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, P(ba, *rest)))
    return f


def _scan_layers(body, x, stacked_params, windows, remat: bool,
                 collect_kv: bool = False):
    if remat:
        body = jax.checkpoint(body)
    (x, aux), kv = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                (stacked_params, windows))
    return x, aux, (kv if collect_kv else None)


# ============================================================================
# Forward (train / prefill)
# ============================================================================
def forward(cfg: ModelConfig, params, tokens, *, frontend_embeds=None,
            mesh=None, impl: str = "ref", remat: bool = False,
            return_hidden: bool = False, enc_out=None):
    """tokens: (B, S_text) int32. Returns hidden (B, S, D) if return_hidden
    else logits (B, S, padded_vocab); plus aux loss scalar."""
    x = embed(params, tokens).astype(jnp.bfloat16)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    bc = _bconstraint(mesh)
    x = bc(x)

    aux_total = jnp.float32(0.0)
    off = 0
    if "dense_layers" in params:
        nd = jax.tree.leaves(params["dense_layers"])[0].shape[0]
        body = _seq_body(cfg, mesh, impl, moe=False)
        x, aux, _ = _scan_layers(body, x, params["dense_layers"],
                                 layer_windows(cfg, nd, offset=0), remat)
        aux_total += aux
        off = nd

    if cfg.family == Family.ENCDEC:
        assert enc_out is not None, "encdec forward needs encoder output"
        body = _encdec_seq_body(cfg, mesh, impl)
        if remat:
            body = jax.checkpoint(body)
        nl = cfg.n_layers
        (x, _), _ = jax.lax.scan(
            body, (x, enc_out.astype(x.dtype)),
            (params["layers"], layer_windows(cfg, nl)))
    else:
        nl = jax.tree.leaves(params["layers"])[0].shape[0]
        body = _seq_body(cfg, mesh, impl, moe=(cfg.family == Family.MOE))
        x, aux, _ = _scan_layers(body, x, params["layers"],
                                 layer_windows(cfg, nl, offset=off), remat)
        aux_total += aux

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, aux_total
    return unembed(params, x), aux_total


def _encdec_seq_body(cfg: ModelConfig, mesh, impl):
    bc = _bconstraint(mesh)

    def body(carry, xs):
        x, enc = carry
        p, window = xs
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        a, _ = attn_forward(p["attn"], xn, rope_theta=cfg.rope_theta,
                            causal=True, window=window, impl=impl)
        x = x + a
        xn = rms_norm(x, p["ln_x"], cfg.norm_eps)
        a, _ = attn_forward(p["xattn"], xn, rope_theta=cfg.rope_theta,
                            causal=False, window=None, kv=(enc, enc), impl=impl)
        x = x + a
        x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return (bc(x), enc), jnp.zeros((0,), x.dtype)

    return body


def encode(cfg: ModelConfig, params, frame_embeds, *, mesh=None,
           impl: str = "ref"):
    """Encoder pass for ENCDEC (bidirectional). frame_embeds: (B, S_enc, D)."""
    x = frame_embeds.astype(jnp.bfloat16)
    bc = _bconstraint(mesh)

    def enc_body(carry, xs):
        x, aux = carry
        p, window = xs
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        a, _ = attn_forward(p["attn"], xn, rope_theta=cfg.rope_theta,
                            causal=False, window=None, impl=impl)
        x = x + a
        x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return (bc(x), aux), jnp.zeros((0,), x.dtype)

    nl = cfg.n_encoder_layers
    (x, _), _ = jax.lax.scan(enc_body, (x, jnp.float32(0.0)),
                             (params["encoder"], layer_windows(cfg, nl)))
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


# ============================================================================
# KV / state cache
# ============================================================================
def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                long_mode: bool = False, enc_len: int = 0) -> dict:
    """ParamSpec tree for the decode cache (dry-run uses shapes, engine inits)."""
    L = cfg.n_layers
    out: Dict[str, Any] = {
        "pos": pspec.ParamSpec((), (), jnp.int32, init="zeros"),
    }
    S_c = kv_cache_len(cfg, max_len, long_mode)
    if S_c:
        kv = (L, batch, S_c, cfg.n_kv_heads, cfg.head_dim)
        ax = ("layer", "batch", "kv_seq", "kv_heads", None)
        out["k"] = pspec.ParamSpec(kv, ax, jnp.bfloat16, init="zeros")
        out["v"] = pspec.ParamSpec(kv, ax, jnp.bfloat16, init="zeros")
        out["pos_ids"] = pspec.ParamSpec((S_c,), (None,), jnp.int32,
                                         init="zeros")
    if cfg.family == Family.SSM:
        out["rwkv_state"] = pspec.ParamSpec(
            (L, batch, cfg.n_heads, cfg.head_dim, cfg.head_dim),
            ("layer", "batch", None, None, None), jnp.float32, init="zeros")
        out["last_tm"] = pspec.ParamSpec((L, batch, cfg.d_model),
                                         ("layer", "batch", "embed"),
                                         jnp.bfloat16, init="zeros")
        out["last_cm"] = pspec.ParamSpec((L, batch, cfg.d_model),
                                         ("layer", "batch", "embed"),
                                         jnp.bfloat16, init="zeros")
    if cfg.family == Family.HYBRID:
        d_inner = cfg.ssm_heads * cfg.head_dim
        out["conv_state"] = pspec.ParamSpec((L, batch, 3, d_inner),
                                            ("layer", "batch", None, "ffn"),
                                            jnp.bfloat16, init="zeros")
        out["ssm_state"] = pspec.ParamSpec(
            (L, batch, cfg.ssm_heads, cfg.ssm_state_size, cfg.head_dim),
            ("layer", "batch", None, None, None), jnp.float32, init="zeros")
    if cfg.family == Family.ENCDEC and enc_len:
        xkv = (L, batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
        ax = ("layer", "batch", None, "kv_heads", None)
        out["xk"] = pspec.ParamSpec(xkv, ax, jnp.bfloat16, init="zeros")
        out["xv"] = pspec.ParamSpec(xkv, ax, jnp.bfloat16, init="zeros")
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               long_mode: bool = False, enc_out=None) -> dict:
    enc_len = 0 if enc_out is None else enc_out.shape[1]
    cache = pspec.init(jax.random.PRNGKey(0),
                       cache_specs(cfg, batch, max_len, long_mode, enc_len))
    if "pos_ids" in cache:
        cache["pos_ids"] = cache["pos_ids"] - 1  # -1 = empty slot
    return cache


def seed_cross_kv(cfg: ModelConfig, params, cache, enc_out):
    wk = params["layers"]["xattn"]["wk"]        # (L, D, KV, dh)
    wv = params["layers"]["xattn"]["wv"]
    cache = dict(cache)
    cache.pop("_needs_xkv", None)
    cache["xk"] = jnp.einsum("bsd,ldhk->lbshk", enc_out.astype(wk.dtype), wk)
    cache["xv"] = jnp.einsum("bsd,ldhk->lbshk", enc_out.astype(wv.dtype), wv)
    return cache


# ============================================================================
# Decode step
# ============================================================================
def _decode_body(cfg: ModelConfig, mesh, impl: str, moe: bool, pos, slot,
                 pos_ids, enc_len: int = 0, moe_mode: str = "shard_map",
                 q_slots=None):
    """q_slots: optional (q_len,) cache slots — switches the attention
    read/write to the multi-query verification path (speculative decoding,
    DESIGN.md §11); every other block is position-free and handles the
    (B, q_len, D) activation unchanged."""
    bc = _bconstraint(mesh) if moe_mode != "auto" else (lambda x: x)

    def body(carry, xs):
        x, aux = carry
        p = xs["p"]
        window = xs["window"]
        ys = {}
        if cfg.family == Family.SSM:
            B = x.shape[0]
            xn = rms_norm(x, p["ln1"], cfg.norm_eps)
            h, new_last_tm, new_state = rwkv_timemix(
                p["tm"], xn, xs["last_tm"], xs["rwkv_state"],
                n_heads=cfg.n_heads, head_dim=cfg.head_dim,
                norm_eps=cfg.norm_eps, impl="ref")
            x = x + h
            xn = rms_norm(x, p["ln2"], cfg.norm_eps)
            h, new_last_cm = rwkv_channelmix(p["cm"], xn, xs["last_cm"])
            x = bc(x + h)
            ys = {"rwkv_state": new_state, "last_tm": new_last_tm,
                  "last_cm": new_last_cm}
            return (x, aux), ys

        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        if q_slots is not None:
            a_out, ck, cv = attn_decode_multi(
                p["attn"], xn, xs["k"], xs["v"], pos_ids, pos, q_slots,
                rope_theta=cfg.rope_theta, window=window, impl=impl)
        else:
            a_out, ck, cv = attn_decode(p["attn"], xn, xs["k"], xs["v"],
                                        pos_ids, pos, slot,
                                        rope_theta=cfg.rope_theta,
                                        window=window, impl=impl)
        ys["k"], ys["v"] = ck, cv

        if cfg.family == Family.HYBRID:
            m_out, new_conv, new_ssm = _mamba_decode(cfg, p["mamba"], xn,
                                                     xs["conv_state"],
                                                     xs["ssm_state"])
            ys["conv_state"], ys["ssm_state"] = new_conv, new_ssm
            fused = 0.5 * (rms_norm(a_out, p["ln_attn"], cfg.norm_eps)
                           + rms_norm(m_out, p["ln_ssm"], cfg.norm_eps))
            x = x + fused @ p["w_fuse"]
            x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
            return (bc(x), aux), ys

        if cfg.family == Family.ENCDEC:
            x = x + a_out
            xn = rms_norm(x, p["ln_x"], cfg.norm_eps)
            x = x + cross_attn_decode(p["xattn"], xn, xs["xk"], xs["xv"],
                                      enc_len, impl=impl)
            x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
            return (bc(x), aux), ys

        if cfg.parallel_block:
            x = x + a_out + mlp(p["mlp"], xn)
        elif moe and "moe" in p:
            x = x + a_out
            m_out, l_aux = moe_forward(p["moe"],
                                       rms_norm(x, p["ln2"], cfg.norm_eps),
                                       cfg=cfg, mesh=mesh, mode=moe_mode)
            x = x + m_out
            aux = aux + l_aux
        else:
            x = x + a_out
            x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return (bc(x), aux), ys

    return body


def _mamba_decode(cfg, p, x, conv_state, ssm_state):
    xi = x @ p["in_x"]
    z = x @ p["in_z"]
    xi, conv_state = _causal_conv(xi, p["conv"], conv_state.astype(x.dtype))
    xi = jax.nn.silu(xi)
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    Bm, Cm = x @ p["w_B"], x @ p["w_C"]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    B, S, _ = x.shape
    xh = xi.reshape(B, S, cfg.ssm_heads, cfg.head_dim)
    y, ssm_state = ssm_scan_ref(xh, dt, Bm, Cm, A, ssm_state)
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(B, S, -1).astype(x.dtype)
    y = rms_norm(y, p["ln"], cfg.norm_eps) * jax.nn.silu(z)
    return y, conv_state, ssm_state


def decode_step(cfg: ModelConfig, params, cache, token, *, mesh=None,
                impl: str = "ref", long_mode: bool = False, enc_len: int = 0):
    """token: (B, 1) int32 -> (logits (B, 1, PV), new_cache)."""
    pos = cache["pos"]
    x = embed(params, token).astype(jnp.bfloat16)
    x = _bconstraint(mesh)(x)

    new_cache = dict(cache)
    slot = jnp.int32(0)
    pos_ids = cache.get("pos_ids")
    if pos_ids is not None:
        S_c = pos_ids.shape[0]
        # while pos < S_c, pos % S_c == pos, so one rule covers contiguous
        # caches and ring buffers alike
        slot = pos % S_c
        pos_ids = jax.lax.dynamic_update_slice(
            pos_ids, pos[None].astype(pos_ids.dtype), (slot,))
        new_cache["pos_ids"] = pos_ids

    aux = jnp.float32(0.0)
    off = 0
    per_layer_keys = [k for k in ("k", "v", "rwkv_state", "last_tm", "last_cm",
                                  "conv_state", "ssm_state", "xk", "xv")
                      if k in cache]

    def run_stack(x, aux, stack_params, n_layers, layer_off, moe):
        body = _decode_body(cfg, mesh, impl, moe, pos, slot, pos_ids,
                            enc_len=enc_len)
        xs = {"p": stack_params,
              "window": layer_windows(cfg, n_layers, long_mode, layer_off)}
        for kkey in per_layer_keys:
            xs[kkey] = jax.lax.dynamic_slice_in_dim(cache[kkey], layer_off,
                                                    n_layers, axis=0)
        (x, aux), ys = jax.lax.scan(body, (x, aux), xs)
        return x, aux, ys

    if "dense_layers" in params:
        nd = jax.tree.leaves(params["dense_layers"])[0].shape[0]
        x, aux, ys = run_stack(x, aux, params["dense_layers"], nd, 0, False)
        for kkey in ys:
            new_cache[kkey] = jax.lax.dynamic_update_slice_in_dim(
                new_cache[kkey], ys[kkey], 0, axis=0)
        off = nd

    nl = cfg.n_layers - off
    x, aux, ys = run_stack(x, aux, params["layers"], nl, off,
                           cfg.family == Family.MOE)
    for kkey in ys:
        new_cache[kkey] = jax.lax.dynamic_update_slice_in_dim(
            new_cache[kkey], ys[kkey], off, axis=0)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def verify_step(cfg: ModelConfig, params, cache, tokens, *, mesh=None,
                impl: str = "ref", long_mode: bool = False):
    """Multi-token verification pass for speculative decoding (DESIGN.md
    §11): score q_len query positions in one traversal of the stack.

    tokens: (B, q_len) int32 — position pos+i holds tokens[:, i] (column 0
    is the last committed token, the rest are drafted). Returns
    (logits (B, q_len, PV), new_cache) with new_cache["pos"] = pos + q_len
    and all q_len K/V written. Rolling back rejected positions is just
    resetting "pos": stale cache entries carry pos_ids > pos and are
    masked out of every future attention read, then overwritten when
    decoding actually reaches their position.

    Families with recurrent per-step state (SSM/HYBRID) cannot roll back
    by masking; ENCDEC's cross-attention is untested here — all three are
    rejected."""
    if cfg.family not in (Family.DENSE, Family.MOE):
        raise NotImplementedError(
            f"speculative verification needs pure-KV per-layer state "
            f"(DENSE/MOE), not {cfg.family}")
    B, Q = tokens.shape
    pos = cache["pos"]
    x = embed(params, tokens).astype(jnp.bfloat16)
    x = _bconstraint(mesh)(x)

    new_cache = dict(cache)
    pos_ids = cache.get("pos_ids")
    S_c = pos_ids.shape[0]
    assert Q < S_c, f"q_len {Q} must be < cache length {S_c}"
    qpos = pos + jnp.arange(Q)
    slots = qpos % S_c
    # contiguous update: the verify window never wraps the ring (callers
    # cap pos + Q at the cache length; see attn_decode_multi)
    pos_ids = jax.lax.dynamic_update_slice(pos_ids,
                                           qpos.astype(pos_ids.dtype),
                                           (slots[0],))
    new_cache["pos_ids"] = pos_ids

    aux = jnp.float32(0.0)
    off = 0
    per_layer_keys = [k for k in ("k", "v") if k in cache]

    def run_stack(x, aux, stack_params, n_layers, layer_off, moe):
        body = _decode_body(cfg, mesh, impl, moe, pos, jnp.int32(0),
                            pos_ids, q_slots=slots)
        xs = {"p": stack_params,
              "window": layer_windows(cfg, n_layers, long_mode, layer_off)}
        for kkey in per_layer_keys:
            xs[kkey] = jax.lax.dynamic_slice_in_dim(cache[kkey], layer_off,
                                                    n_layers, axis=0)
        (x, aux), ys = jax.lax.scan(body, (x, aux), xs)
        return x, aux, ys

    if "dense_layers" in params:
        nd = jax.tree.leaves(params["dense_layers"])[0].shape[0]
        x, aux, ys = run_stack(x, aux, params["dense_layers"], nd, 0, False)
        for kkey in ys:
            new_cache[kkey] = jax.lax.dynamic_update_slice_in_dim(
                new_cache[kkey], ys[kkey], 0, axis=0)
        off = nd

    nl = cfg.n_layers - off
    x, aux, ys = run_stack(x, aux, params["layers"], nl, off,
                           cfg.family == Family.MOE)
    for kkey in ys:
        new_cache[kkey] = jax.lax.dynamic_update_slice_in_dim(
            new_cache[kkey], ys[kkey], off, axis=0)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x)
    new_cache["pos"] = pos + Q
    return logits, new_cache


# ============================================================================
# Prefill (seeds the cache by running the sequence path, then filling KV)
# ============================================================================
def prefill(cfg: ModelConfig, params, tokens, cache, *, frontend_embeds=None,
            mesh=None, impl: str = "ref", enc_out=None):
    """Run the full prompt, fill the cache, return last-token logits + cache.

    For simplicity and losslessness this re-runs the sequence path and captures
    per-layer K/V (full-attention archs) or final states (SSM archs) — one pass,
    same FLOPs as a fused implementation.
    """
    B, S = tokens.shape[0], tokens.shape[1]
    if frontend_embeds is not None:
        S = S + frontend_embeds.shape[1]

    if cfg.family == Family.ENCDEC and enc_out is not None:
        cache = seed_cross_kv(cfg, params, cache, enc_out)

    # run through decode_step token by token would be O(S^2); instead run the
    # sequence body capturing kv — implemented for attention archs:
    if "k" in cache and cfg.family not in (Family.SSM,):
        x = embed(params, tokens).astype(jnp.bfloat16)
        if frontend_embeds is not None:
            x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
        x = _bconstraint(mesh)(x)
        aux = jnp.float32(0.0)
        off = 0
        S_c = cache["k"].shape[2]

        def capture_stack(x, aux, stack_params, n_layers, layer_off, moe):
            if cfg.family == Family.ENCDEC:
                body = _encdec_prefill_body(cfg, mesh, impl, cache, layer_off)
                (x, aux), kv = jax.lax.scan(
                    body, (x, aux),
                    {"p": stack_params,
                     "window": layer_windows(cfg, n_layers, False, layer_off),
                     "xk": cache["xk"], "xv": cache["xv"]})
            else:
                body = _seq_body(cfg, mesh, impl, moe)
                (x, aux), kv = jax.lax.scan(
                    body, (x, aux),
                    (stack_params,
                     layer_windows(cfg, n_layers, False, layer_off)))
            return x, aux, kv

        new_cache = dict(cache)
        stacks = []
        if "dense_layers" in params:
            nd = jax.tree.leaves(params["dense_layers"])[0].shape[0]
            stacks.append((params["dense_layers"], nd, 0, False))
            stacks.append((params["layers"], cfg.n_layers - nd, nd,
                           cfg.family == Family.MOE))
        else:
            stacks.append((params["layers"], cfg.n_layers, 0,
                           cfg.family == Family.MOE))
        for sp, n, o, moe in stacks:
            x, aux, kv = capture_stack(x, aux, sp, n, o, moe)
            if kv is not None and isinstance(kv, tuple) and kv[0].ndim == 5:
                k_all, v_all = kv  # (n, B, S, KV, dh)
                if S <= S_c:  # contiguous fill at slots [0, S)
                    new_cache["k"] = jax.lax.dynamic_update_slice(
                        new_cache["k"], k_all.astype(new_cache["k"].dtype),
                        (o, 0, 0, 0, 0))
                    new_cache["v"] = jax.lax.dynamic_update_slice(
                        new_cache["v"], v_all.astype(new_cache["v"].dtype),
                        (o, 0, 0, 0, 0))
                else:  # ring: keep the last S_c tokens; slot(p) = p mod S_c
                    last_k = k_all[:, :, S - S_c:S]
                    last_v = v_all[:, :, S - S_c:S]
                    sh = S % S_c
                    new_cache["k"] = jax.lax.dynamic_update_slice(
                        new_cache["k"],
                        jnp.roll(last_k, sh, axis=2).astype(new_cache["k"].dtype),
                        (o, 0, 0, 0, 0))
                    new_cache["v"] = jax.lax.dynamic_update_slice(
                        new_cache["v"],
                        jnp.roll(last_v, sh, axis=2).astype(new_cache["v"].dtype),
                        (o, 0, 0, 0, 0))
        if "pos_ids" in new_cache:
            if S <= S_c:
                ids = jnp.where(jnp.arange(S_c) < S, jnp.arange(S_c), -1)
            else:
                ids = jnp.roll(jnp.arange(S - S_c, S), S % S_c)
            new_cache["pos_ids"] = ids.astype(jnp.int32)
        new_cache["pos"] = jnp.int32(S)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params, x[:, -1:])
        return logits, new_cache

    if cfg.family == Family.SSM:
        return _ssm_prefill(cfg, params, tokens, cache, mesh=mesh, impl=impl)

    # fallback: stream through decode_step token by token
    def step(cache, tok):
        logits, cache = decode_step(cfg, params, cache, tok[:, None],
                                    mesh=mesh, impl=impl)
        return cache, logits[:, 0]

    cache, logits_all = jax.lax.scan(step, cache, jnp.moveaxis(tokens, 1, 0))
    return logits_all[-1][:, None], cache


def _ssm_prefill(cfg: ModelConfig, params, tokens, cache, *, mesh=None,
                 impl: str = "ref"):
    """RWKV prefill: sequence pass per layer capturing final (state, shifts)."""
    bc = _bconstraint(mesh)
    x = embed(params, tokens).astype(jnp.bfloat16)
    x = bc(x)
    B, S, D = x.shape

    def body(carry, xs):
        x, aux = carry
        p = xs["p"]
        h, last_tm, state = rwkv_timemix(
            p["tm"], rms_norm(x, p["ln1"], cfg.norm_eps), xs["last_tm"],
            xs["rwkv_state"], n_heads=cfg.n_heads, head_dim=cfg.head_dim,
            norm_eps=cfg.norm_eps, impl=impl)
        x = x + h
        h, last_cm = rwkv_channelmix(p["cm"], rms_norm(x, p["ln2"], cfg.norm_eps),
                                     xs["last_cm"])
        x = bc(x + h)
        return (x, aux), {"rwkv_state": state, "last_tm": last_tm,
                          "last_cm": last_cm}

    xs = {"p": params["layers"], "rwkv_state": cache["rwkv_state"],
          "last_tm": cache["last_tm"], "last_cm": cache["last_cm"]}
    (x, _), ys = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    new_cache = dict(cache)
    new_cache.update({k: v.astype(cache[k].dtype) for k, v in ys.items()})
    new_cache["pos"] = cache["pos"] + S
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, x[:, -1:]), new_cache


def _encdec_prefill_body(cfg, mesh, impl, cache, layer_off):
    bc = _bconstraint(mesh)

    def body(carry, xs):
        x, aux = carry
        p, window = xs["p"], xs["window"]
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        a, (k, v) = attn_forward(p["attn"], xn, rope_theta=cfg.rope_theta,
                                 causal=True, window=window, impl=impl)
        x = x + a
        xn = rms_norm(x, p["ln_x"], cfg.norm_eps)
        B, S, D = x.shape
        enc_len = xs["xk"].shape[1]
        from repro.models.attention import chunked_attention
        q = jnp.einsum("bsd,dhk->bshk", xn, p["xattn"]["wq"])
        out = chunked_attention(q, xs["xk"], xs["xv"], causal=False,
                                window=None)
        x = x + jnp.einsum("bshk,hkd->bsd", out, p["xattn"]["wo"])
        x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return (bc(x), aux), (k, v)

    return body


# ============================================================================
# Loss (chunked CE so (B, S, V) logits are never fully materialized)
# ============================================================================
def loss_fn(cfg: ModelConfig, params, batch, *, mesh=None, impl: str = "ref",
            remat: bool = True, ce_chunk: int = 512):
    tokens, labels = batch["tokens"], batch["labels"]
    mask = batch.get("mask")
    fe = batch.get("frontend_embeds")
    enc_out = None
    if cfg.family == Family.ENCDEC:
        enc_out = encode(cfg, params, batch["frontend_embeds"], mesh=mesh,
                         impl=impl)
        fe = None
    hidden, aux = forward(cfg, params, tokens, frontend_embeds=fe, mesh=mesh,
                          impl=impl, remat=remat, return_hidden=True,
                          enc_out=enc_out)
    if fe is not None:
        hidden = hidden[:, fe.shape[1]:]  # loss only on text positions
    B, S, D = hidden.shape
    C = ce_chunk if S % ce_chunk == 0 else S
    n_chunks = S // C

    def ce_chunk_fn(carry, idx):
        h = jax.lax.dynamic_slice_in_dim(hidden, idx * C, C, axis=1)
        l = jax.lax.dynamic_slice_in_dim(labels, idx * C, C, axis=1)
        m = None if mask is None else \
            jax.lax.dynamic_slice_in_dim(mask, idx * C, C, axis=1)
        logits = unembed(params, h)
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = lse - picked
        if m is not None:
            return (carry[0] + (nll * m).sum(), carry[1] + m.sum()), None
        return (carry[0] + nll.sum(), carry[1] + nll.size), None

    (tot, cnt), _ = jax.lax.scan(ce_chunk_fn, (jnp.float32(0.), jnp.float32(0.)),
                                 jnp.arange(n_chunks))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + cfg.router_aux_coef * aux, {"ce": loss, "aux": aux}
