"""Serve a model through the LIME interleaved-pipeline engine on a virtual
4-stage cluster (CPU devices stand in for pipeline stages), demonstrating:

  * offline planning -> uniform engine plan (resident + streamed layers)
  * prefill on GSPMD, cache adoption into the engine layout
  * bursty vs sporadic request patterns
  * Poisson traffic through the continuous-batching scheduler + metrics
  * losslessness spot-check vs a single-device decode

Because the engine needs multiple devices, this script re-execs itself with
a forced host device count if necessary.

  PYTHONPATH=src python examples/serve_cluster.py
"""
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.configs.registry import get_smoke_config           # noqa: E402
from repro.core.engine import InterleavedEngine, UniformPlan  # noqa: E402
from repro.models import model as M                           # noqa: E402
from repro.serving import LimeServer, SamplerConfig           # noqa: E402


def main():
    cfg = get_smoke_config("internlm2-1.8b")
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=8)   # 2 segments x 4 stages x 1
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    plan = UniformPlan(n_stage=4, n_seg=2, k_res=0, k_off=1)
    print(f"plan: {plan.n_seg} segments x {plan.n_stage} stages, "
          f"k_res={plan.k_res} k_off={plan.k_off} (all layers streamed)")

    for pattern, n_mb in (("sporadic", 1), ("bursty", 4)):
        engine = InterleavedEngine(cfg, mesh, plan, n_mb=n_mb, mb=1,
                                   max_len=64)
        srv = LimeServer(cfg, params, engine=engine, max_len=64,
                         pattern=pattern, sampler=SamplerConfig())
        rng = np.random.default_rng(1)
        n_req = 4
        for i in range(n_req):
            srv.queue.submit(rng.integers(1, cfg.vocab_size, 6),
                             max_new_tokens=8)
        done = srv.serve_all()
        print(f"[{pattern}] served {len(done)} requests:")
        for r in done:
            print(f"   req {r.rid}: {r.output}")

    # LIME-Serve: a seeded Poisson arrival stream through the
    # continuous-batching scheduler, reported with serving metrics
    # (reuses the loop's final bursty engine/server — same plan, and a
    # fresh engine would recompile the slowest program of the demo)
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               make_arrivals, requests_from_arrivals,
                               summarize)
    arrivals = make_arrivals("poisson", 6, rate_rps=2.0, prompt_len=6,
                             max_new_tokens=8, seed=7)
    backend = srv.make_backend()
    reqs = requests_from_arrivals(arrivals)
    for r in reqs:                 # traffic times are relative to "now":
        r.arrival_s += backend.now()   # re-base onto the running clock
    sched = ContinuousBatchingScheduler(backend, SchedulerConfig())
    served = sched.serve(reqs)
    rep = summarize(served, pattern="poisson", backend="engine")
    print(f"[poisson] {rep.n_requests} served, "
          f"ttft p50 {rep.ttft_p50_s:.2f}s, "
          f"latency p99 {rep.latency_p99_s:.2f}s, "
          f"{rep.throughput_tok_s:.1f} tok/s")

    # losslessness spot check: engine greedy tokens == plain decode greedy
    # (the loop's final engine has the same (n_mb=4, mb=1, max_len=64)
    # signature — reuse it rather than recompiling)
    state = engine.init_state(params)
    tok = jnp.arange(4, dtype=jnp.int32)[:, None] + 3
    cache = M.init_cache(cfg, 4, 64)
    agree = 0
    for _ in range(6):
        lg_e, state = engine.decode_step(state, tok)
        lg_r, cache = M.decode_step(cfg, params, cache, tok)
        a = jnp.argmax(lg_e[:, :cfg.vocab_size], -1)
        b = jnp.argmax(lg_r[:, 0, :cfg.vocab_size], -1)
        agree += int((a == b).all())
        tok = b[:, None].astype(jnp.int32)
    print(f"greedy agreement engine vs single-device: {agree}/6")


if __name__ == "__main__":
    main()
