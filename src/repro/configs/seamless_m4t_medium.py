"""SeamlessM4T-medium — encoder-decoder transformer backbone (audio frontend
stubbed: input_specs() feeds conv-feature frame embeddings). [arXiv:2308.11596]"""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family=Family.ENCDEC,
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    n_encoder_layers=12, frontend_tokens=512,
    attn_kind=AttnKind.FULL,
    source="SeamlessM4T [arXiv:2308.11596]",
)
