"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --steps 200 \
      --batch 8 --seq 256 [--smoke] [--ckpt out/]

On this CPU container use --smoke (reduced config); on a pod the full config
with the production mesh applies the same code path.
"""
from __future__ import annotations

import argparse


from repro.configs.registry import get_config, get_smoke_config
from repro.data import make_batches
from repro.training import Trainer
from repro.checkpoint import save


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", action="store_true",
                    help="build the production mesh (needs >=256 devices)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
    tr = Trainer(cfg, mesh=mesh, peak_lr=args.lr,
                 warmup=max(args.steps // 10, 5), total_steps=args.steps)
    params, opt_state = tr.init()
    batches = make_batches(cfg.vocab_size, args.batch, args.seq)
    params, opt_state, hist = tr.fit(params, opt_state, batches, args.steps)
    if args.ckpt:
        save(args.ckpt, params, step=args.steps)
        print(f"saved checkpoint to {args.ckpt}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
