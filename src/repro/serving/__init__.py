from repro.serving.server import LimeServer, Request, RequestQueue, \
    SamplerConfig, sample  # noqa: F401
