from repro.training.trainer import Trainer, make_train_step, \
    zero1_sharding  # noqa: F401
