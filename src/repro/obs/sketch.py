"""Bounded streaming instruments: quantile sketches and windowed rates
(DESIGN.md §17).

PR 7's `Histogram` holds every raw sample — exact percentiles, unbounded
memory. At fleet scale ("millions of users", DESIGN.md §16) a serving run
observes far more latencies than it can afford to keep, and the SLO engine
(obs/slo.py) needs *online* quantiles and rates, not a drain-time sort.
This module provides the bounded replacements, all O(1) or O(capacity)
memory, all with documented error bounds, and — where the fleet layer
needs it — exact-capacity `merge()` so replica sketches pool into one
fleet sketch.

  ReservoirSketch   fixed-capacity uniform reservoir (Vitter's Algorithm R
                    with chained-merge weighting). Quantile error is
                    *rank* error: for capacity m, the estimated q-quantile
                    is an order statistic whose rank deviates by at most
                    eps = 2/sqrt(m) of the population with ~95% confidence
                    (binomial tail on m uniform draws: sd of the empirical
                    CDF at any point is sqrt(q(1-q)/m) <= 1/(2 sqrt(m));
                    two sds = 1/sqrt(m), doubled for the nearest-rank
                    rounding). m=1024 -> rank error ~3%: p99 of a million
                    samples lands between the true p96 and the max —
                    tight enough for burn-rate math, 1000x less memory.
                    merge() subsamples each side proportionally to its
                    population count, so a merged sketch is again a
                    uniform sample of the pooled population (same bound).
  P2Quantile        Jain & Chlamtac's P² estimator: ONE quantile in O(1)
                    memory (5 markers), no samples kept. Asymptotically
                    consistent; empirical error on smooth distributions is
                    well under the reservoir's for the same quantile, but
                    it cannot merge and cannot answer new quantiles after
                    the fact. Used for cheap per-replica live readouts;
                    the registry's bounded histograms use reservoirs so
                    fleet merge stays exact-capacity.
  EWMA              exponentially-weighted mean with a configurable
                    half-life on the *caller's* clock (virtual or wall):
                    weight of a sample aged `t` is 2^(-t/half_life).
  WindowedCounter   good/bad event counts over a ring of fixed-width time
                    buckets — the burn-rate engine's window algebra reads
                    totals over the trailing fast/slow windows in O(ring).

Everything here is clock-explicit: callers pass `now` (the scheduler's
backend clock — virtual for the sim, wall for the engine), nothing reads
time.time(), so sim runs are deterministic and tests seed everything.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

# Documented rank-error bound for ReservoirSketch.quantile (see module
# docstring): eps = RANK_ERROR_FACTOR / sqrt(capacity) at ~95% confidence.
RANK_ERROR_FACTOR = 2.0


def reservoir_rank_error(capacity: int) -> float:
    """The documented rank-error bound eps for a given capacity: the
    estimated q-quantile is within the true [q-eps, q+eps] quantile band
    with ~95% confidence. bench_slo.py enforces this against exact
    nearest-rank on pooled fleet samples."""
    return RANK_ERROR_FACTOR / math.sqrt(max(capacity, 1))


class _LCG:
    """Tiny deterministic RNG (numpy-free hot path; splittable by seed).
    Same constants as glibc's rand48 family."""
    __slots__ = ("state",)

    def __init__(self, seed: int):
        self.state = (seed * 0x5DEECE66D + 0xB) & 0xFFFFFFFFFFFF

    def next_float(self) -> float:
        self.state = (self.state * 0x5DEECE66D + 0xB) & 0xFFFFFFFFFFFF
        return (self.state >> 16) / float(1 << 32)

    def next_below(self, n: int) -> int:
        return int(self.next_float() * n) % max(n, 1)


class ReservoirSketch:
    """Fixed-capacity uniform sample of an unbounded stream, mergeable.

    observe() is Vitter's Algorithm R: sample i (1-based) replaces a
    random slot with probability m/i, which leaves every sample in the
    reservoir with probability exactly m/n. merge() re-samples both sides
    proportionally to their population counts — the result is again a
    uniform m-sample of the pooled population, so the quantile bound
    survives arbitrary merge trees (the fleet's per-replica -> aggregate
    fold)."""

    __slots__ = ("capacity", "count", "samples", "_rng", "_min", "_max")

    def __init__(self, capacity: int = 1024, seed: int = 0):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.count = 0                    # population size seen
        self.samples: List[float] = []
        self._rng = _LCG(seed ^ (capacity << 20))
        self._min = math.inf
        self._max = -math.inf

    @property
    def rank_error(self) -> float:
        return reservoir_rank_error(self.capacity)

    def observe(self, v: float) -> None:
        self.count += 1
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v
        if len(self.samples) < self.capacity:
            self.samples.append(v)
        else:
            j = self._rng.next_below(self.count)
            if j < self.capacity:
                self.samples[j] = v

    def extend(self, vs: Sequence[float]) -> None:
        for v in vs:
            self.observe(v)

    def quantile(self, p: float) -> float:
        """Nearest-rank quantile of the reservoir (p in [0,100], the
        serving.metrics convention); NaN when empty. Min/max are tracked
        exactly, so p=0 and p=100 are always exact."""
        if not self.samples:
            return float("nan")
        if p <= 0:
            return self._min
        if p >= 100:
            return self._max
        xs = sorted(self.samples)
        k = max(math.ceil(p / 100.0 * len(xs)) - 1, 0)
        return xs[min(k, len(xs) - 1)]

    def merge(self, other: "ReservoirSketch") -> "ReservoirSketch":
        """Fold `other` into self. Each side contributes slots in
        proportion to its population count (hypergeometric split of the
        capacity), sampled without replacement from its reservoir — the
        merged reservoir is a uniform sample of the pooled population.
        Returns self so merges chain (MetricsRegistry.merge)."""
        total = self.count + other.count
        if other.count == 0:
            return self
        if self.count == 0:
            self.count = other.count
            self.samples = list(other.samples)
            self._min, self._max = other._min, other._max
            return self
        cap = self.capacity
        mine, theirs = list(self.samples), list(other.samples)
        if total <= cap and len(mine) + len(theirs) <= cap:
            merged = mine + theirs        # everything fits: stay exact
        else:
            take_mine = round(cap * self.count / total)
            take_mine = min(max(take_mine, cap - len(theirs)), len(mine))
            take_theirs = min(cap - take_mine, len(theirs))
            merged = self._sample(mine, take_mine) \
                + self._sample(theirs, take_theirs)
        self.samples = merged
        self.count = total
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)
        return self

    def _sample(self, xs: List[float], k: int) -> List[float]:
        """k distinct elements of xs (partial Fisher-Yates, seeded)."""
        if k >= len(xs):
            return list(xs)
        xs = list(xs)
        for i in range(k):
            j = i + self._rng.next_below(len(xs) - i)
            xs[i], xs[j] = xs[j], xs[i]
        return xs[:k]

    def to_dict(self) -> dict:
        return {"capacity": self.capacity, "count": self.count,
                "p50": self.quantile(50), "p99": self.quantile(99),
                "rank_error": self.rank_error}


class P2Quantile:
    """Jain & Chlamtac's P² algorithm: one streaming quantile, O(1) state.

    Five markers track (min, q/2-ish, q, (1+q)/2-ish, max) heights and
    positions; each observation nudges interior markers toward their
    ideal positions with a piecewise-parabolic height update. No samples
    are retained, so it cannot merge — use ReservoirSketch where fleet
    aggregation matters. Error is not worst-case bounded (the estimate is
    asymptotically consistent for continuous distributions); tests gate
    it empirically at ~2 x the reservoir bound on smooth streams."""

    __slots__ = ("q", "n", "heights", "pos", "ideal", "inc")

    def __init__(self, q: float = 0.99):
        if not 0.0 < q < 1.0:
            raise ValueError(f"q must be in (0,1), got {q}")
        self.q = q
        self.n = 0
        self.heights: List[float] = []
        self.pos = [1, 2, 3, 4, 5]
        self.ideal = [1.0, 1 + 2 * q, 1 + 4 * q, 3 + 2 * q, 5.0]
        self.inc = [0.0, q / 2, q, (1 + q) / 2, 1.0]

    def observe(self, v: float) -> None:
        self.n += 1
        if len(self.heights) < 5:
            self.heights.append(v)
            if len(self.heights) == 5:
                self.heights.sort()
            return
        h = self.heights
        if v < h[0]:
            h[0], k = v, 0
        elif v >= h[4]:
            h[4], k = v, 3
        else:
            k = next(i for i in range(4) if h[i] <= v < h[i + 1])
        for i in range(k + 1, 5):
            self.pos[i] += 1
        for i in range(5):
            self.ideal[i] += self.inc[i]
        # adjust interior markers toward their ideal positions
        for i in range(1, 4):
            d = self.ideal[i] - self.pos[i]
            if (d >= 1 and self.pos[i + 1] - self.pos[i] > 1) or \
               (d <= -1 and self.pos[i - 1] - self.pos[i] < -1):
                s = 1 if d >= 0 else -1
                hp = self._parabolic(i, s)
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp
                else:                     # parabolic overshoots: linear
                    h[i] += s * (h[i + s] - h[i]) \
                        / (self.pos[i + s] - self.pos[i])
                self.pos[i] += s

    def _parabolic(self, i: int, s: int) -> float:
        h, p = self.heights, self.pos
        return h[i] + s / (p[i + 1] - p[i - 1]) * (
            (p[i] - p[i - 1] + s) * (h[i + 1] - h[i]) / (p[i + 1] - p[i])
            + (p[i + 1] - p[i] - s) * (h[i] - h[i - 1]) / (p[i] - p[i - 1]))

    def value(self) -> float:
        """Current estimate of the q-quantile; NaN before any sample.
        With < 5 samples, falls back to the exact small-sample quantile."""
        if not self.heights:
            return float("nan")
        if self.n < 5:
            xs = sorted(self.heights)
            k = max(math.ceil(self.q * len(xs)) - 1, 0)
            return xs[min(k, len(xs) - 1)]
        return self.heights[2]


class EWMA:
    """Exponentially-weighted mean with a half-life on the caller's clock.

    A sample aged `t` seconds weighs 2^(-t / half_life): update() decays
    the accumulated weight by the elapsed time, then adds the new sample
    at weight 1. value() is the weighted mean — a latency tracker. rate()
    divides the decayed event *weight* by the effective window
    (half_life / ln 2, the integral of the decay kernel) — an events-per-
    second tracker that forgets bursts at the same half-life."""

    __slots__ = ("half_life", "weight", "weighted_sum", "last_s")

    def __init__(self, half_life_s: float = 60.0):
        if half_life_s <= 0:
            raise ValueError(f"half_life_s must be positive: {half_life_s}")
        self.half_life = half_life_s
        self.weight = 0.0
        self.weighted_sum = 0.0
        self.last_s: Optional[float] = None

    def _decay_to(self, now: float) -> None:
        if self.last_s is None:
            self.last_s = now
            return
        dt = now - self.last_s
        if dt > 0:
            f = 2.0 ** (-dt / self.half_life)
            self.weight *= f
            self.weighted_sum *= f
            self.last_s = now

    def update(self, v: float, now: float) -> None:
        self._decay_to(now)
        self.weight += 1.0
        self.weighted_sum += v

    def value(self, now: Optional[float] = None) -> float:
        """Weighted mean of observed samples; NaN before any sample.
        (Decay cancels in the ratio, so `now` only matters for rate.)"""
        if now is not None:
            self._decay_to(now)
        return self.weighted_sum / self.weight if self.weight > 0 \
            else float("nan")

    def rate(self, now: float) -> float:
        """Decayed events/second: total decayed event weight over the
        kernel's effective window half_life/ln2."""
        self._decay_to(now)
        return self.weight / (self.half_life / math.log(2.0))


class WindowedCounter:
    """Good/bad event counts over a ring of fixed-width time buckets.

    The burn-rate engine asks "how many bad events in the last W seconds"
    for two W's (fast/slow). One ring sized to the *slow* window answers
    both: `totals(window_s, now)` sums the trailing ceil(W/bucket)
    buckets. Memory is n_buckets regardless of traffic; bucket width
    quantizes window edges (documented algebra: a window of W covers
    between W and W + bucket seconds of events — tests pin this)."""

    __slots__ = ("bucket_s", "n_buckets", "_t0", "_good", "_bad",
                 "_head_idx")

    def __init__(self, window_s: float, n_buckets: int = 60):
        if window_s <= 0 or n_buckets <= 0:
            raise ValueError(f"bad window: {window_s}s x {n_buckets}")
        self.bucket_s = window_s / n_buckets
        self.n_buckets = n_buckets
        self._t0: Optional[float] = None   # epoch of bucket index 0
        self._good = [0.0] * n_buckets
        self._bad = [0.0] * n_buckets
        self._head_idx = 0                 # absolute index of newest bucket

    def _bucket(self, now: float) -> int:
        if self._t0 is None:
            self._t0 = now
        idx = int(max(now - self._t0, 0.0) / self.bucket_s)
        # advance: zero every bucket between the old head and the new
        if idx > self._head_idx:
            for i in range(self._head_idx + 1,
                           min(idx, self._head_idx + self.n_buckets) + 1):
                self._good[i % self.n_buckets] = 0.0
                self._bad[i % self.n_buckets] = 0.0
            if idx - self._head_idx > self.n_buckets:
                for i in range(self.n_buckets):
                    self._good[i] = self._bad[i] = 0.0
            self._head_idx = idx
        return min(idx, self._head_idx)

    def add(self, now: float, *, good: float = 0.0, bad: float = 0.0) -> None:
        i = self._bucket(now) % self.n_buckets
        self._good[i] += good
        self._bad[i] += bad

    def totals(self, window_s: float, now: float) -> Tuple[float, float]:
        """(good, bad) summed over the trailing `window_s` seconds —
        bucket-quantized: covers ceil(window/bucket) whole buckets
        including the (partial) current one."""
        self._bucket(now)                  # roll the ring forward first
        k = min(int(math.ceil(window_s / self.bucket_s)), self.n_buckets)
        good = bad = 0.0
        for j in range(k):
            i = (self._head_idx - j) % self.n_buckets
            if self._head_idx - j < 0:
                break
            good += self._good[i]
            bad += self._bad[i]
        return good, bad

    def bad_fraction(self, window_s: float, now: float) -> float:
        """bad / (good + bad) over the trailing window; 0.0 when empty
        (an idle window burns no budget)."""
        good, bad = self.totals(window_s, now)
        total = good + bad
        return bad / total if total > 0 else 0.0
