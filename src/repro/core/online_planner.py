"""Online memory-aware planner (paper §IV-D, Eq. 5-7, Fig. 9).

As the KV cache grows during decoding, each device eventually can't hold its
resident weights *and* the cache. The planner pre-computes, per device, a
ladder of thresholds TS_i^j (total generated-token counts) with an offload
plan (α MHA blocks, β MLP blocks evicted from residency) attached to each.
Plans are *absolute* states, re-solved per threshold with objective Eq. 6
(minimize the per-segment load the plan adds) under Eq. 7 (the freed
(#Seg-1) block copies must cover the KV growth to the next threshold) — this
reproduces the paper's Fig. 9 behaviour where a later plan may offload the
MLP block and *reload* the previously evicted MHA block, because one big
block is cheaper to stream than two small ones is false — rather because
β=1,α=0 frees more than α=1,β=0 at lower load than α=1,β=1.

The planner applies the same plan to every segment (one extra load per step,
mutually overlapped across segments — paper §IV-D).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.core.cost_model import CostEnv, ExecutionPlan


@dataclasses.dataclass(frozen=True)
class OffloadPlanStep:
    threshold_tokens: int      # TS_i^j: trigger when total tokens reach this
    alpha: int                 # MHA blocks offloaded (absolute, per segment)
    beta: int                  # MLP blocks offloaded (absolute, per segment)
    extra_load_bytes: float    # added per-segment load this plan causes


@dataclasses.dataclass
class DevicePlannerState:
    dev_idx: int
    plan_idx: int = 0          # next threshold to trigger
    alpha: int = 0             # currently offloaded MHA blocks
    beta: int = 0              # currently offloaded MLP blocks
    last_eff: int = 0          # last effective token count seen (rebuild
                               # re-anchors plan_idx to this occupancy)


def _min_load_plan(need_bytes: float, attn_b: float, mlp_b: float,
                   a_max: int, b_max: int, n_seg: int
                   ) -> Optional[Tuple[int, int]]:
    """Smallest extra per-segment load (Eq. 6) with freed >= need (Eq. 7)."""
    factor = max(n_seg - 1, 1)
    best = None
    best_load = float("inf")
    for a in range(a_max + 1):
        freed_a = a * attn_b * factor
        rem = max(need_bytes - freed_a, 0.0)
        b = min(int(math.ceil(rem / (mlp_b * factor))) if rem > 0 else 0,
                b_max)
        if freed_a + b * mlp_b * factor + 1e-9 < need_bytes:
            continue
        load = a * attn_b + b * mlp_b
        if load < best_load:
            best_load, best = load, (a, b)
    return best


class OnlinePlanner:
    """Builds and walks the TS-ladder for every device of a plan."""

    def __init__(self, env: CostEnv, plan: ExecutionPlan, *, horizon_tokens: int,
                 ladder_chunk_tokens: int = 256):
        self.env = env
        self.plan = plan
        self.work = env.work
        self.chunk = ladder_chunk_tokens
        self.base_chunk = ladder_chunk_tokens
        self.horizon = horizon_tokens
        self.rebuilds = 0
        self.states = [DevicePlannerState(i)
                       for i in range(len(plan.stages))]
        self.ladders: List[List[OffloadPlanStep]] = [
            self._build_ladder(i, horizon_tokens)
            for i in range(len(plan.stages))]
        # SLO pressure (DESIGN.md §17): 0 when healthy; a breaching SLO
        # engine pushes (1 - health) here, which scales the effective
        # token count so TS thresholds fire EARLY — weight blocks demote
        # before the next admission would have queued on a dry pool
        self.slo_pressure = 0.0

    def note_slo_pressure(self, pressure: float) -> None:
        """Adopt the serving layer's SLO pressure in [0, 1] (clamped)."""
        self.slo_pressure = min(max(pressure, 0.0), 1.0)

    # -- memory bookkeeping ---------------------------------------------------
    def _free_bytes(self, i: int, alpha: int, beta: int) -> float:
        d = self.plan.stages[i]
        w = self.work
        base = d.resident_bytes(w, self.plan.n_seg)
        freed = (alpha * w.attn_block_bytes + beta * w.mlp_block_bytes) \
            * max(self.plan.n_seg - 1, 1)
        return self.env.devices[i].mem_bytes - (base - freed)

    def _kv_per_token(self, i: int) -> float:
        d = self.plan.stages[i]
        return (d.layers_total(self.plan.n_seg)
                * self.work.kv_bytes_per_token_layer())

    def _block_budget(self, i: int) -> Tuple[int, int]:
        """How many MHA/MLP blocks device i can still evict (per segment):
        its resident layers contribute both blocks; already-split layers
        contribute their pinned half."""
        d = self.plan.stages[i]
        res_seg = d.resident_total // max(self.plan.n_seg, 1)
        a_max = res_seg + d.off_mlp_only_seg      # resident MHA halves
        b_max = res_seg + d.off_attn_only_seg     # resident MLP halves
        return a_max, b_max

    # -- Eq. 5 + ladder construction -------------------------------------------
    def _build_ladder(self, i: int, horizon: int) -> List[OffloadPlanStep]:
        w = self.work
        kv_tok = self._kv_per_token(i)
        if kv_tok <= 0:
            return []
        a_max, b_max = self._block_budget(i)
        ladder: List[OffloadPlanStep] = []
        free0 = self._free_bytes(i, 0, 0)                  # no eviction yet
        alpha = beta = 0
        while True:
            free = self._free_bytes(i, alpha, beta)
            ts = int(free // kv_tok)                       # Eq. 5 (TS^1) / next
            if ts >= horizon:
                break
            # new absolute plan must hold KV through the next chunk (Eq. 7)
            target = min(ts + self.chunk, horizon)
            need = target * kv_tok - free0
            nxt = _min_load_plan(need, w.attn_block_bytes, w.mlp_block_bytes,
                                 a_max, b_max, self.plan.n_seg)
            if nxt is None or nxt == (alpha, beta):
                break                                       # out of blocks
            alpha, beta = nxt
            ladder.append(OffloadPlanStep(
                threshold_tokens=max(ts, 0), alpha=alpha, beta=beta,
                extra_load_bytes=(alpha * w.attn_block_bytes
                                  + beta * w.mlp_block_bytes)))
        return ladder

    # -- runtime: called by the simulator every generated token ----------------
    def on_token(self, total_tokens: int,
                 transferred: Optional[List[int]] = None
                 ) -> List[Tuple[int, OffloadPlanStep]]:
        """Returns [(dev_idx, plan_step)] for plans triggered at this count.
        `transferred[i]` = KV tokens device i has delegated away (Alg. 2):
        they don't occupy its memory, so they delay *its* thresholds —
        per-device, which is exactly how the protocol keeps bottleneck
        devices from offloading early (paper Fig. 10)."""
        fired = []
        for st in self.states:
            lad = self.ladders[st.dev_idx]
            eff = total_tokens - (transferred[st.dev_idx]
                                  if transferred else 0)
            if self.slo_pressure > 0.0:
                # under SLO stress the planner acts as if occupancy were
                # up to 2x what it is: thresholds fire sooner, HBM turns
                # into KV headroom before queueing compounds the breach
                eff = int(eff * (1.0 + self.slo_pressure))
            st.last_eff = max(st.last_eff, int(eff))
            while st.plan_idx < len(lad) \
                    and eff >= lad[st.plan_idx].threshold_tokens:
                step = lad[st.plan_idx]
                st.alpha, st.beta = step.alpha, step.beta
                st.plan_idx += 1
                fired.append((st.dev_idx, step))
        return fired

    # -- re-fit hook (repro.tune.refit, DESIGN.md §18) -------------------------
    def rebuild(self, env: Optional[CostEnv] = None, *,
                chunk_scale: float = 1.0) -> None:
        """Recompute every TS ladder against an updated CostEnv — the
        online re-fit calls this when measured bandwidth/compute drifts
        from the planned model.

        The thresholds themselves are memory-driven (Eq. 5), so the env
        swap mostly matters downstream (all pricing now uses measured
        numbers); what bandwidth drift changes *here* is the ladder
        chunk: `chunk_scale` = measured/planned load bandwidth. A slower
        loader (< 1) shrinks the chunk, so each re-solved plan (Eq. 6/7)
        covers less KV growth and streams fewer extra bytes per segment;
        a faster loader affords bigger chunks and fewer, larger demotion
        steps.

        Physical state is preserved: alpha/beta never decrease across a
        rebuild (un-evicting would be a promotion the runtime hasn't
        performed), and plan_idx re-anchors to each device's last
        effective occupancy so already-passed thresholds don't re-fire.
        """
        if env is not None:
            self.env = env
            self.work = env.work
        self.chunk = max(32, int(round(self.base_chunk
                                       * min(max(chunk_scale, 0.1), 10.0))))
        self.ladders = [self._build_ladder(i, self.horizon)
                        for i in range(len(self.plan.stages))]
        for st in self.states:
            lad = self.ladders[st.dev_idx]
            idx = 0
            while idx < len(lad) and st.last_eff >= lad[idx].threshold_tokens:
                idx += 1
            st.plan_idx = idx
            if idx > 0:
                st.alpha = max(st.alpha, lad[idx - 1].alpha)
                st.beta = max(st.beta, lad[idx - 1].beta)
        self.rebuilds += 1

    def on_pages(self, pages_in_use: int, page_size: int,
                 transferred: Optional[List[int]] = None
                 ) -> List[Tuple[int, OffloadPlanStep]]:
        """Page-granular entry (DESIGN.md §10): walk the TS ladder on
        *allocated* KV occupancy — pages_in_use × page_size tokens — so
        thresholds fire on what the paged admission actually holds,
        including page-rounding slack, rather than a nominal token loop."""
        return self.on_token(pages_in_use * page_size, transferred)

    def extra_load_bytes_seg(self, i: int) -> float:
        st = self.states[i]
        w = self.work
        return st.alpha * w.attn_block_bytes + st.beta * w.mlp_block_bytes

    def next_threshold(self, i: int) -> Optional[int]:
        lad = self.ladders[i]
        st = self.states[i]
        return lad[st.plan_idx].threshold_tokens \
            if st.plan_idx < len(lad) else None
