"""CodeQwen1.5-7B — dense, qwen1.5 arch (GQA kv=32 == MHA). [hf:Qwen/CodeQwen1.5-7B]"""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family=Family.DENSE,
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab_size=92416, head_dim=128,
    attn_kind=AttnKind.FULL, rope_theta=1_000_000.0,
    source="CodeQwen1.5 model card [hf:Qwen/CodeQwen1.5-7B]",
)
