"""Offload-oriented cost model for the interleaved pipeline (paper §IV-B).

All quantities are *per autoregressive step* unless suffixed ``_seg`` (per
segment). The paper's Eq. 1/2 terms are implemented with the per-segment
reading that makes them internally consistent (DESIGN.md §8):

    T_total  = T_comp + T_comm + T_uncover
    T_comp   = Σ_i comp(L_i)                      (all segments)
    T_comm   = #Seg · |D| · h_size / bw_net
    T_uncover= #Seg · max_i max(load_seg(L̃_i) − T_i^idle_seg, 0)
    T_i^idle = comp_seg(L_i − L̃_i) + Σ_{i'≠i} comp_seg(L_i') + |D|·h/bw  (Eq.2)

Workload model: per-layer compute time on a device is
max(FLOPs/dev.flops, bytes_touched/dev.mem_bw) — the second term makes
micro-batch-1 decode bandwidth-bound (the regime where the paper's sporadic
pattern lives) while bursty batches become compute-bound, which is exactly
the sporadic/bursty asymmetry in the paper's results.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core.profiles import DeviceProfile

DTYPE_BYTES = 2  # fp16/bf16 weights + KV


# ============================================================================
# Workload: what one decoder layer costs for a given micro-batch / context
# ============================================================================
@dataclasses.dataclass(frozen=True)
class Workload:
    """One auto-regressive step for `mb` sequences at context length `ctx`."""
    cfg: ModelConfig
    mb: int                     # micro-batch size (tokens per step per stage)
    ctx: int                    # current context length (KV read span)
    n_micro: int = 1            # micro-batches in flight (bursty: |D|)

    # ---- model-side sizes (paper Tab. I symbols) ----
    @property
    def l_size(self) -> float:
        """Bytes of one decoder layer (average over depth)."""
        n = self.cfg.n_layers
        tot = sum(self.cfg.layer_params(i) for i in range(n))
        return tot / n * DTYPE_BYTES

    @property
    def attn_block_bytes(self) -> float:
        return self.cfg.attn_params_per_layer() * DTYPE_BYTES

    @property
    def mlp_block_bytes(self) -> float:
        return self.cfg.mlp_params_per_layer() * DTYPE_BYTES

    @property
    def p_A(self) -> float:
        return self.cfg.p_A()

    @property
    def p_M(self) -> float:
        return self.cfg.p_M()

    @property
    def h_size(self) -> float:
        """Intermediate activation bytes handed between devices (per mb)."""
        return self.mb * self.cfg.d_model * DTYPE_BYTES

    def kv_bytes_per_token_layer(self) -> float:
        """KV-cache bytes one token adds on one layer (whole micro-batch set)."""
        c = self.cfg
        if c.is_attention_free:
            return 0.0
        per_seq = 2 * c.n_kv_heads * (c.head_dim or 0) * DTYPE_BYTES
        return per_seq * self.mb * self.n_micro

    # ---- per-layer step cost on a device ----
    def layer_flops(self) -> float:
        """FLOPs of one layer for one step of `mb` tokens (active params)."""
        c = self.cfg
        if c.is_moe:
            dff = c.moe_d_ff or c.d_ff
            mlp = (c.top_k + c.n_shared_experts) * 3 * c.d_model * dff
        else:
            mlp = 3 * c.d_model * c.d_ff
        dense = c.attn_params_per_layer() + mlp
        flops = 2.0 * dense * self.mb
        if not c.is_attention_free:
            # attention reads: q·K^T and P·V over the live context
            span = min(self.ctx, c.window_size) \
                if c.attn_kind.value in ("sliding",) else self.ctx
            flops += 4.0 * self.mb * span * c.n_heads * (c.head_dim or 0)
        return flops

    def layer_bytes_touched(self, resident_bytes: Optional[float] = None) -> float:
        """HBM traffic of one layer step: active weights + KV read."""
        c = self.cfg
        if c.is_moe:
            dff = c.moe_d_ff or c.d_ff
            active = (c.attn_params_per_layer()
                      + min(self.mb * c.top_k, c.n_experts) * 3 * c.d_model * dff
                      + c.n_shared_experts * 3 * c.d_model * dff) * DTYPE_BYTES
        else:
            active = self.l_size if resident_bytes is None else resident_bytes
        kv = self.kv_bytes_per_token_layer() / max(self.n_micro, 1) * self.ctx \
            / max(self.mb, 1) * self.mb  # read whole per-mb KV span
        return active + kv

    def comp_layer(self, dev: DeviceProfile) -> float:
        """Seconds for one layer's step on `dev` (roofline max of terms)."""
        return max(self.layer_flops() / dev.flops,
                   self.layer_bytes_touched() / dev.mem_bw)


# ============================================================================
# ExecutionPlan (output of the offline scheduler, input to sim AND engine)
# ============================================================================
@dataclasses.dataclass
class StageAlloc:
    """Per-stage (= per-device) allocation. Counts are *per segment* for
    offloaded layers (the interleave repeats the same shape every segment,
    paper Fig. 6). One object serves both consumers: the cost model /
    simulator price the block-granular fields; the engine reads the
    whole-layer view (`k_res` / `k_off`) — a block-split layer streams as
    a whole layer on the engine (the split is a bandwidth refinement the
    simulator prices, not a separate execution mode)."""
    resident_total: int          # fully-resident layers (across all segments)
    off_full_seg: int = 0        # layers fully (re)loaded, per segment
    off_attn_only_seg: int = 0   # MLP resident, MHA loaded, per segment
    off_mlp_only_seg: int = 0    # MHA resident, MLP loaded, per segment

    def off_layers_seg(self) -> int:
        return self.off_full_seg + self.off_attn_only_seg + self.off_mlp_only_seg

    def layers_total(self, n_seg: int) -> int:
        return self.resident_total + n_seg * self.off_layers_seg()

    def load_bytes_seg(self, w: Workload) -> float:
        return (self.off_full_seg * w.l_size
                + self.off_attn_only_seg * w.attn_block_bytes
                + self.off_mlp_only_seg * w.mlp_block_bytes)

    def resident_bytes(self, w: Workload, n_seg: int) -> float:
        """Weight bytes held simultaneously: fully-resident layers + one
        segment's offload buffer + the resident halves of split layers."""
        split_res = (self.off_attn_only_seg * w.mlp_block_bytes
                     + self.off_mlp_only_seg * w.attn_block_bytes) * n_seg
        return (self.resident_total * w.l_size
                + self.load_bytes_seg(w)        # double-buffer: one segment live
                + split_res)

    # -- engine-facing whole-layer view ---------------------------------------
    def k_res(self, n_seg: int) -> int:
        """Resident layers per chunk (ceil: a remainder that doesn't divide
        the segments evenly pads the grid — padded slots are zero/identity
        layers, see engine.plan_layout)."""
        return -(-self.resident_total // max(n_seg, 1))

    @property
    def k_off(self) -> int:
        """Streamed layers per chunk (block-split layers stream whole)."""
        return self.off_layers_seg()


@dataclasses.dataclass
class ExecutionPlan:
    """THE plan object: emitted by the offline scheduler, priced by the
    cost model / simulator, executed by the InterleavedEngine.

    A uniform plan (every stage identical — the homogeneous-TPU case) is
    the degenerate instance built by `ExecutionPlan.uniform(...)`; the
    engine's historical `UniformPlan(...)` constructor delegates here."""
    n_seg: int
    stages: List[StageAlloc]
    t_comp: float = 0.0
    t_comm: float = 0.0
    t_uncover: float = 0.0
    off_trim: int = 0           # padding overshoot when #Seg ∤ |L_left|
                                # (cost terms stay conservative/padded)

    # -- cost view -------------------------------------------------------------
    @property
    def devices(self) -> List[StageAlloc]:
        """Historical alias (device == pipeline stage)."""
        return self.stages

    @property
    def t_total(self) -> float:
        return self.t_comp + self.t_comm + self.t_uncover

    def layers_total(self) -> int:
        return sum(d.layers_total(self.n_seg)
                   for d in self.stages) - self.off_trim

    # -- engine-facing geometry -------------------------------------------------
    @property
    def n_stage(self) -> int:
        return len(self.stages)

    @property
    def n_chunks(self) -> int:
        return self.n_seg * self.n_stage

    @property
    def k_res_list(self):
        """Per-stage resident layers per chunk."""
        return tuple(st.k_res(self.n_seg) for st in self.stages)

    @property
    def k_off_list(self):
        """Per-stage streamed layers per chunk."""
        return tuple(st.k_off for st in self.stages)

    @property
    def k_max(self) -> int:
        """Largest chunk across stages — the padded scan length."""
        return max(r + o for r, o in zip(self.k_res_list, self.k_off_list))

    @property
    def n_layers(self) -> int:
        """Grid capacity (>= layers_total when resident counts don't divide
        the segments; the overhang is zero/identity padding)."""
        return self.n_seg * sum(r + o for r, o in
                                zip(self.k_res_list, self.k_off_list))

    @property
    def is_uniform(self) -> bool:
        return len({(st.resident_total, st.off_full_seg,
                     st.off_attn_only_seg, st.off_mlp_only_seg)
                    for st in self.stages}) <= 1

    # -- uniform-plan scalar compat (dryrun / roofline / tests) -----------------
    @property
    def k_res(self) -> int:
        assert self.is_uniform, "k_res is per-stage on heterogeneous plans"
        return self.stages[0].k_res(self.n_seg)

    @property
    def k_off(self) -> int:
        assert self.is_uniform, "k_off is per-stage on heterogeneous plans"
        return self.stages[0].k_off

    @property
    def k(self) -> int:
        return self.k_res + self.k_off

    @classmethod
    def uniform(cls, n_stage: int, n_seg: int, k_res: int,
                k_off: int) -> "ExecutionPlan":
        return cls(n_seg=n_seg,
                   stages=[StageAlloc(resident_total=k_res * n_seg,
                                      off_full_seg=k_off)
                           for _ in range(n_stage)])


# historical names (PR <= 4 API): one object now serves both consumers
DeviceAlloc = StageAlloc
Plan = ExecutionPlan


# ============================================================================
# Cost environment: devices + network + workload  ->  Eq. 1 terms
# ============================================================================
@dataclasses.dataclass
class CostEnv:
    devices: Sequence[DeviceProfile]
    bw_net: float                      # bytes/s between any two devices
    work: Workload
    net_latency: float = 1e-3          # per-message latency (edge LAN ~1 ms);
                                       # dominates TP's per-layer syncs

    # -- building blocks -----------------------------------------------------
    def replace_device(self, dev_idx: int, dev: DeviceProfile) -> "CostEnv":
        """A copy of this env with one device swapped — how the online
        re-fit (repro.tune.refit) folds a measured bandwidth/flops drift
        into the planning model without mutating shared state."""
        devs = list(self.devices)
        devs[dev_idx] = dev
        return dataclasses.replace(self, devices=devs)

    def comp_layers(self, dev_idx: int, n_layers: float) -> float:
        return n_layers * self.work.comp_layer(self.devices[dev_idx])

    def load_time(self, dev_idx: int, nbytes: float) -> float:
        return nbytes / self.devices[dev_idx].load_bw

    def comm_seg(self) -> float:
        """One segment's activation ring: |D| hops of h_size (Eq. 1)."""
        return len(self.devices) * (self.work.h_size / self.bw_net
                                    + self.net_latency)

    # -- Eq. 2: per-device overlap budget within one segment ------------------
    def idle_seg(self, plan: ExecutionPlan, i: int) -> float:
        d = plan.stages[i]
        own_nonoff = self.comp_layers(i, d.resident_total / plan.n_seg)
        others = sum(
            self.comp_layers(j, plan.stages[j].layers_total(plan.n_seg)
                             / plan.n_seg)
            for j in range(len(plan.stages)) if j != i)
        return own_nonoff + others + self.comm_seg()

    # -- Eq. 1: total latency of a plan ---------------------------------------
    def evaluate(self, plan: ExecutionPlan) -> ExecutionPlan:
        w = self.work
        plan.t_comp = sum(
            self.comp_layers(i, plan.stages[i].layers_total(plan.n_seg))
            for i in range(len(plan.stages)))
        plan.t_comm = plan.n_seg * self.comm_seg()
        unc = 0.0
        for i, d in enumerate(plan.stages):
            load = self.load_time(i, d.load_bytes_seg(w))
            unc = max(unc, max(load - self.idle_seg(plan, i), 0.0))
        plan.t_uncover = plan.n_seg * unc
        return plan

    # -- memory audit ----------------------------------------------------------
    def kv_reserve_bytes(self, layers_on_dev: int, n_tokens: int) -> float:
        return layers_on_dev * n_tokens * self.work.kv_bytes_per_token_layer()

    def mem_ok(self, plan: ExecutionPlan, n_tokens: int) -> bool:
        for i, d in enumerate(plan.stages):
            used = (d.resident_bytes(self.work, plan.n_seg)
                    + self.kv_reserve_bytes(d.layers_total(plan.n_seg),
                                            n_tokens))
            if used > self.devices[i].mem_bytes + 1e-6:
                return False
        return True
