"""Blocked causal flash attention for TPU (Pallas).

Layout (arranged by ops.py): q (B, H, Sq, dh); k, v (B, KV, Skv, dh), dh
padded to a multiple of 128 lanes (MXU alignment). Grid is
``(B, H, n_q_blocks, n_kv_blocks)`` — the last grid dimension is sequential
on TPU, so the online-softmax running state (m, l, acc) lives in VMEM
scratch and is carried across kv blocks; output is written on the final kv
block. GQA is expressed in the k/v index_map (``h // group``), so KV blocks
are fetched once per q-head group member without reshapes.

The sliding window arrives as a scalar-prefetch operand (SMEM), which lets
gemma3-style local:global stacks scan one homogeneous layer body over a
traced per-layer window array.

VMEM working set per program: q/k/v/o blocks + acc =
(3·block_k + 2·block_q)·dh_p·2B + block_q·dh_p·4B ≈ 0.6 MB at the default
128/512 blocks with dh_p=128 — well inside 16 MB VMEM, leaving room for the
compiler's double buffering of the k/v streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e30


def _flash_kernel(scalars_ref,                       # SMEM: [window]
                  q_ref, k_ref, v_ref,               # VMEM blocks
                  o_ref,                             # VMEM out block
                  m_ref, l_ref, acc_ref,             # VMEM scratch
                  *, causal: bool, sq_real: int, skv_real: int, dh_real: int,
                  block_q: int, block_k: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (block_q, dh)
    k = k_ref[0, 0].astype(jnp.float32)              # (block_k, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (dh_real ** -0.5)                        # (block_q, block_k)

    i = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    j = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = j < skv_real
    if causal:
        mask &= j <= i
    window = scalars_ref[0]
    mask &= (i - j) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (block_q, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                           # (block_q, block_k)
    corr = jnp.exp(m_prev - m_new)                   # (block_q, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)              # fully-masked (pad) rows
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, window, *, causal: bool,
                           sq_real: int, skv_real: int, dh_real: int,
                           block_q: int = 128, block_k: int = 512,
                           q_offset: int = 0, interpret: bool = False):
    """q: (B, H, Sq, dh); k, v: (B, KV, Skv, dh); window: (1,) int32.

    Sq % block_q == 0, Skv % block_k == 0, dh % 128 == 0 (ops.py pads).
    Returns (B, H, Sq, dh) in q.dtype.
    """
    B, H, Sq, dh = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    grid = (B, H, Sq // block_q, Skv // block_k)

    kernel = functools.partial(
        _flash_kernel, causal=causal, sq_real=sq_real, skv_real=skv_real,
        dh_real=dh_real, block_q=block_q, block_k=block_k, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_q, dh),
                             lambda b, h, iq, ik, ws: (b, h, iq, 0)),
                pl.BlockSpec((1, 1, block_k, dh),
                             lambda b, h, iq, ik, ws: (b, h // G, ik, 0)),
                pl.BlockSpec((1, 1, block_k, dh),
                             lambda b, h, iq, ik, ws: (b, h // G, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, block_q, dh),
                                   lambda b, h, iq, ik, ws: (b, h, iq, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        interpret=interpret,
    )(window, q, k, v)
