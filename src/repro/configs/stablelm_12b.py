"""StableLM-2-12B — dense GQA with stablelm-2 parallel attn+MLP blocks.
[hf:stabilityai/stablelm-2-1_6b family]"""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="stablelm-12b", family=Family.DENSE,
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab_size=100352, head_dim=160,
    attn_kind=AttnKind.FULL, parallel_block=True,
    source="StableLM-2 model card [hf:stabilityai/stablelm-2-1_6b]",
)
