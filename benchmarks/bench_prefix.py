"""Radix prefix cache + chunked prefill vs cold monolithic prefill
(EXPERIMENTS.md §PrefixCache).

Two headline claims, exit-code enforced on the paper's default 4-device
heterogeneous fleet (E3) over the discrete-event substrate:

  prefix   under `shared_prefix` traffic (N templates x many users) the
           radix cache reaches hit-rate >= 0.5 and cuts TTFT p50 by >= 2x
           vs the cold baseline — cached spans skip their offload rounds
           entirely (DESIGN.md §12)
  chunked  under `bursty` traffic with long cold prompts, chunked prefill
           (prompts drain chunk-by-chunk through mixed rounds alongside
           live decode streams) improves per-request decode tok/s p99 vs
           monolithic prefill, whose joiner passes stall every decoder

Every run also audits page accounting: when the scheduler finishes, the
allocator must hold exactly the live radix-tree pages (zero refcount
leaks), and with the cache off it must hold nothing.

  python benchmarks/bench_prefix.py
  python benchmarks/bench_prefix.py --scenario prefix --n-requests 48
  python benchmarks/bench_prefix.py --out benchmarks/baselines/prefix_sim.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))


def build_backend(args, slots: int, prompt: int):
    from repro.configs.registry import get_config
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.profiles import env_E1, env_E2, env_E3, mbps
    from repro.serving import SimBackend

    fleets = {"E1": env_E1, "E2": env_E2, "E3": env_E3}
    cfg = get_config(args.arch)
    w = Workload(cfg, mb=1, ctx=prompt, n_micro=slots)
    env = CostEnv(fleets[args.fleet](), mbps(args.bw_mbps), w)
    return SimBackend(env, n_slots=slots, prompt_tokens=prompt)


def audit_pages(sched) -> dict:
    """Leak audit: every request released its table, so the allocator
    holds exactly the live radix pages (post-warmup baseline minus the
    tree's holdings — see the acceptance invariant in ISSUE/DESIGN §12)."""
    if sched.mgr is None:
        return {"audited": False}
    pool = sched.mgr.pool
    tree_pages = sched.prefix.n_pages if sched.prefix is not None else 0
    ok = pool.alloc.used_pages == tree_pages
    return {"audited": True, "leak_free": ok,
            "used_pages": pool.alloc.used_pages,
            "radix_pages": tree_pages,
            "free_pages": pool.alloc.free_pages}


def run_shared_prefix(args, prefix_on: bool) -> dict:
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               cli_arrivals, requests_from_arrivals,
                               summarize)

    arrivals = cli_arrivals("shared_prefix", args.n_requests, seed=args.seed,
                            prompt_len=args.prompt_len,
                            max_new_tokens=args.max_new,
                            rate_rps=args.rate_rps,
                            n_templates=args.n_templates,
                            prefix_len=args.prefix_len)
    backend = build_backend(args, args.slots, args.prompt_len)
    sched = ContinuousBatchingScheduler(backend, SchedulerConfig(
        kv_policy="paged", page_size=args.page_size,
        prefix_cache=prefix_on))
    served = sched.serve(requests_from_arrivals(arrivals))
    rep = summarize(served, pattern="shared_prefix",
                    backend=f"sim/{'prefix' if prefix_on else 'cold'}",
                    stats=sched.stats)
    out = rep.to_dict()
    out["prefix_cache"] = prefix_on
    out["page_audit"] = audit_pages(sched)
    return out


def run_chunked(args, chunk) -> dict:
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               cli_arrivals, requests_from_arrivals,
                               summarize)

    arrivals = cli_arrivals("bursty", args.n_requests, seed=args.seed,
                            prompt_len=(args.prompt_len // 2,
                                        2 * args.prompt_len),
                            max_new_tokens=args.max_new,
                            gap_s=args.gap_s, burst_size=args.slots)
    backend = build_backend(args, args.slots, args.prompt_len)
    sched = ContinuousBatchingScheduler(backend, SchedulerConfig(
        kv_policy="paged", page_size=args.page_size,
        prefill_chunk_tokens=chunk))
    served = sched.serve(requests_from_arrivals(arrivals))
    rep = summarize(served, pattern="bursty",
                    backend=f"sim/{'chunk' + str(chunk) if chunk else 'mono'}",
                    stats=sched.stats)
    out = rep.to_dict()
    out["prefill_chunk_tokens"] = chunk
    out["page_audit"] = audit_pages(sched)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", choices=("prefix", "chunked", "all"),
                    default="all")
    ap.add_argument("--arch", default="llama2-13b")
    ap.add_argument("--fleet", default="E3", choices=("E1", "E2", "E3"))
    ap.add_argument("--bw-mbps", type=float, default=200.0)
    ap.add_argument("--n-requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=512)
    ap.add_argument("--prefix-len", type=int, default=448,
                    help="shared template span within each prompt")
    ap.add_argument("--n-templates", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--rate-rps", type=float, default=0.25)
    ap.add_argument("--gap-s", type=float, default=6.0)
    ap.add_argument("--page-size", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=128,
                    help="prefill_chunk_tokens for the chunked scenario")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    results = []
    comparison = {}
    rc = 0
    if args.scenario in ("prefix", "all"):
        cold = run_shared_prefix(args, False)
        warm = run_shared_prefix(args, True)
        results += [cold, warm]
        speedup = cold["ttft_p50_s"] / max(warm["ttft_p50_s"], 1e-12)
        comparison["prefix"] = {
            "hit_rate": warm["prefix_hit_rate"],
            "prefill_tokens_saved": warm["prefill_tokens_saved"],
            "ttft_p50_cold_s": cold["ttft_p50_s"],
            "ttft_p50_prefix_s": warm["ttft_p50_s"],
            "ttft_speedup": speedup,
            "ttft_prefill_p50_cold_s": cold["ttft_prefill_p50_s"],
            "ttft_prefill_p50_prefix_s": warm["ttft_prefill_p50_s"],
        }
        print(f"# shared_prefix: TTFT p50 {warm['ttft_p50_s']:.2f}s vs cold "
              f"{cold['ttft_p50_s']:.2f}s ({speedup:.2f}x) at hit-rate "
              f"{warm['prefix_hit_rate']:.2f}", file=sys.stderr)
        if warm["prefix_hit_rate"] < 0.5:
            print("# WARNING: hit-rate below 0.5 — shared_prefix traffic "
                  "or matching broke", file=sys.stderr)
            rc = 1
        if speedup < 2.0:
            print("# WARNING: prefix-cache TTFT p50 speedup below 2x",
                  file=sys.stderr)
            rc = 1
        for r in (cold, warm):
            if not r["page_audit"]["leak_free"]:
                print(f"# WARNING: page leak: {r['page_audit']}",
                      file=sys.stderr)
                rc = 1
    if args.scenario in ("chunked", "all"):
        mono = run_chunked(args, None)
        chunked = run_chunked(args, args.chunk)
        results += [mono, chunked]
        comparison["chunked"] = {
            "decode_tok_s_p99_mono": mono["decode_tok_s_p99"],
            "decode_tok_s_p99_chunked": chunked["decode_tok_s_p99"],
            "ttft_p50_mono_s": mono["ttft_p50_s"],
            "ttft_p50_chunked_s": chunked["ttft_p50_s"],
        }
        print(f"# bursty chunked: decode tok/s p99 "
              f"{chunked['decode_tok_s_p99']:.3f} vs monolithic "
              f"{mono['decode_tok_s_p99']:.3f}", file=sys.stderr)
        if chunked["decode_tok_s_p99"] <= mono["decode_tok_s_p99"]:
            print("# WARNING: chunked prefill did not improve decode "
                  "tok/s p99 — mixed-round pricing broke", file=sys.stderr)
            rc = 1
        for r in (mono, chunked):
            if not r["page_audit"]["leak_free"]:
                print(f"# WARNING: page leak: {r['page_audit']}",
                      file=sys.stderr)
                rc = 1

    payload = {"config": vars(args), "results": results,
               "comparison": comparison}
    text = json.dumps(payload, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return rc


def run():
    """benchmarks.run harness hook: fast sim-only smoke."""
    class _Row:
        def __init__(self, name, ms):
            self.name, self.ms = name, ms

        def csv(self):
            return f"prefix,{self.name},{self.ms:.1f},ok"

    rc = main(["--n-requests", "16", "--prompt-len", "256",
               "--prefix-len", "192", "--max-new", "8"])
    if rc:
        raise SystemExit("bench_prefix smoke failed")
    return [_Row("shared_prefix_and_chunked", 0.0)]


if __name__ == "__main__":
    raise SystemExit(main())
