"""Traffic generation for LIME-Serve (DESIGN.md §9, EXPERIMENTS.md §Serving).

The paper evaluates two request regimes (§V-A): *sporadic* — one request in
flight, the pipeline drains between requests — and *bursty* — |D| requests
co-scheduled as micro-batches. Serving under real traffic needs those as
explicit arrival timelines plus the patterns a front-end actually sees, so
this module generates deterministic, seeded arrival streams:

  sporadic      requests spaced far enough apart that the pipeline drains
  bursty        groups of `burst_size` simultaneous arrivals
  poisson       memoryless arrivals at `rate_rps` (exponential gaps)
  trace         replay of explicit (time_s, prompt_len, max_new_tokens) rows
  shared_prefix N prompt templates × many users: every request opens with
                one of `n_templates` shared prefix streams (system prompts
                / few-shot templates), then a per-request unique suffix —
                the radix prefix cache's home workload (DESIGN.md §12)
  multiturn     conversational sessions whose follow-up arrivals re-send
                the growing conversation: turn t's prompt extends turn
                t-1's, so a session's own history is a guaranteed prefix
                hit once inserted

Every generator is a pure function of its arguments (numpy Generator seeded
explicitly), so benchmark runs and tests are reproducible bit-for-bit.
Template-bearing events (`template_id` set) carry enough metadata for
`requests_from_arrivals` (serving/scheduler.py) to materialize actual
token ids deterministically — the prefix cache keys on token content, so
these two patterns produce real (synthetic but stable) prompts.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class ArrivalEvent:
    """One request hitting the front door. `template_id`/`template_len`
    mark the leading `template_len` prompt tokens as drawn from shared
    stream `template_id` (see template_tokens); the rest of the prompt is
    unique to the request."""
    time_s: float
    prompt_len: int
    max_new_tokens: int
    template_id: Optional[int] = None
    template_len: int = 0
    session_id: Optional[int] = None   # conversation identity (multiturn):
                                       # follow-up turns carry the same id,
                                       # so routers can pin a session to a
                                       # replica without inspecting tokens


_STREAM_CHUNK = 4096


def template_tokens(template_id: int, n: int, *, vocab_size: int = 32768,
                    seed: int = 0, salt: int = 0) -> np.ndarray:
    """First `n` tokens of shared stream (`seed`, `salt`, `template_id`) —
    prefix-stable by construction: the stream is always drawn in
    _STREAM_CHUNK-sized blocks and sliced, so template_tokens(t, 5) is a
    prefix of template_tokens(t, 9) regardless of generator internals."""
    rng = np.random.default_rng([seed, salt, template_id])
    full = -(-max(n, 1) // _STREAM_CHUNK) * _STREAM_CHUNK
    return rng.integers(1, max(vocab_size, 2),
                        size=full).astype(np.int32)[:n]


def _lengths(rng: np.random.Generator, n: int, lo: int, hi: int) -> np.ndarray:
    if hi <= lo:
        return np.full(n, lo, np.int64)
    return rng.integers(lo, hi + 1, size=n)


def _sample_lengths(rng: np.random.Generator, n: int, prompt_len,
                    max_new_tokens) -> Tuple[np.ndarray, np.ndarray]:
    """Draw per-request prompt/new-token lengths; scalars are fixed,
    (lo, hi) tuples sample uniformly inclusive."""
    pl = prompt_len if isinstance(prompt_len, tuple) else (prompt_len,) * 2
    mn = max_new_tokens if isinstance(max_new_tokens, tuple) \
        else (max_new_tokens,) * 2
    return _lengths(rng, n, *pl), _lengths(rng, n, *mn)


def sporadic(n_requests: int, *, gap_s: float = 4.0, jitter: float = 0.25,
             prompt_len: Union[int, Tuple[int, int]] = 64,
             max_new_tokens: Union[int, Tuple[int, int]] = 32,
             seed: int = 0) -> List[ArrivalEvent]:
    """Lone arrivals, `gap_s` apart (±jitter fraction): the paper's
    1-micro-batch regime — each request owns the pipeline."""
    rng = np.random.default_rng(seed)
    plens, mnews = _sample_lengths(rng, n_requests, prompt_len,
                                   max_new_tokens)
    t, out = 0.0, []
    for i in range(n_requests):
        out.append(ArrivalEvent(t, int(plens[i]), max(int(mnews[i]), 1)))
        t += gap_s * (1.0 + jitter * (2.0 * rng.random() - 1.0))
    return out


def bursty(n_requests: int, *, burst_size: int = 4, gap_s: float = 8.0,
           prompt_len: Union[int, Tuple[int, int]] = 64,
           max_new_tokens: Union[int, Tuple[int, int]] = 32,
           seed: int = 0) -> List[ArrivalEvent]:
    """Simultaneous groups of `burst_size`: the paper's |D|-micro-batch
    regime — the interleaved pipeline is kept full within a burst."""
    rng = np.random.default_rng(seed)
    plens, mnews = _sample_lengths(rng, n_requests, prompt_len,
                                   max_new_tokens)
    out = []
    for i in range(n_requests):
        t = (i // burst_size) * gap_s
        out.append(ArrivalEvent(t, int(plens[i]), max(int(mnews[i]), 1)))
    return out


def poisson(n_requests: int, *, rate_rps: float = 0.5,
            prompt_len: Union[int, Tuple[int, int]] = 64,
            max_new_tokens: Union[int, Tuple[int, int]] = 32,
            seed: int = 0) -> List[ArrivalEvent]:
    """Memoryless arrivals at `rate_rps` requests/second — the open-loop
    load model serving benchmarks default to; bursts and lulls emerge."""
    rng = np.random.default_rng(seed)
    plens, mnews = _sample_lengths(rng, n_requests, prompt_len,
                                   max_new_tokens)
    gaps = rng.exponential(1.0 / max(rate_rps, 1e-9), size=n_requests)
    times = np.concatenate([[0.0], np.cumsum(gaps)[:-1]])
    return [ArrivalEvent(float(times[i]), int(plens[i]),
                         max(int(mnews[i]), 1))
            for i in range(n_requests)]


def trace_replay(rows: Union[str, Iterable[Sequence[float]]],
                 **_ignored) -> List[ArrivalEvent]:
    """Replay explicit arrivals. `rows` is either an iterable of
    (time_s, prompt_len, max_new_tokens) triples or a path to a JSON file
    holding a list of such triples / of {time_s, prompt_len,
    max_new_tokens} objects."""
    if isinstance(rows, str):
        with open(rows) as f:
            rows = json.load(f)
    out = []
    for row in rows:
        if isinstance(row, dict):
            ev = ArrivalEvent(float(row["time_s"]), int(row["prompt_len"]),
                              max(int(row["max_new_tokens"]), 1))
        else:
            t, p, m = row
            ev = ArrivalEvent(float(t), int(p), max(int(m), 1))
        out.append(ev)
    return sorted(out, key=lambda e: e.time_s)


def shared_prefix(n_requests: int, *, n_templates: int = 4,
                  prefix_len: int = 256, rate_rps: float = 1.0,
                  prompt_len: Union[int, Tuple[int, int]] = 320,
                  max_new_tokens: Union[int, Tuple[int, int]] = 32,
                  seed: int = 0) -> List[ArrivalEvent]:
    """N templates × many users (DESIGN.md §12): Poisson arrivals whose
    prompts all open with one of `n_templates` shared `prefix_len`-token
    streams — production front-door traffic dominated by system prompts
    and few-shot templates. The per-request suffix keeps total length at
    `prompt_len` (clamped so at least one unique token follows the
    template: a fully-shared prompt would leave nothing to prefill)."""
    rng = np.random.default_rng(seed)
    plens, mnews = _sample_lengths(rng, n_requests, prompt_len,
                                   max_new_tokens)
    gaps = rng.exponential(1.0 / max(rate_rps, 1e-9), size=n_requests)
    times = np.concatenate([[0.0], np.cumsum(gaps)[:-1]])
    out = []
    for i in range(n_requests):
        total = max(int(plens[i]), prefix_len + 1)
        out.append(ArrivalEvent(
            float(times[i]), total, max(int(mnews[i]), 1),
            template_id=int(rng.integers(0, max(n_templates, 1))),
            template_len=min(prefix_len, total - 1)))
    return out


def multiturn(n_requests: int, *, turns: int = 3,
              prompt_len: Union[int, Tuple[int, int]] = 64,
              user_len: int = 16, think_s: float = 4.0,
              rate_rps: float = 0.5,
              max_new_tokens: Union[int, Tuple[int, int]] = 32,
              seed: int = 0) -> List[ArrivalEvent]:
    """Conversational sessions: each session opens at a Poisson arrival,
    then re-sends its growing conversation every `think_s` (±50% jitter)
    seconds — turn t's prompt is turn t-1's prompt plus the assistant
    turn (max_new tokens) plus `user_len` new user tokens, all drawn from
    the session's template stream so consecutive turns are exact prefix
    extensions. `prompt_len` sizes the first turn; `n_requests` total
    arrivals across ceil(n/turns) sessions."""
    rng = np.random.default_rng(seed)
    n_sessions = -(-max(n_requests, 1) // max(turns, 1))
    gaps = rng.exponential(1.0 / max(rate_rps, 1e-9), size=n_sessions)
    starts = np.concatenate([[0.0], np.cumsum(gaps)[:-1]])
    out = []
    first = prompt_len if isinstance(prompt_len, int) else prompt_len[0]
    for s in range(n_sessions):
        t = float(starts[s])
        plen = first
        for turn in range(turns):
            if len(out) >= n_requests:
                break
            mn = _sample_lengths(rng, 1, plen, max_new_tokens)[1][0]
            out.append(ArrivalEvent(t, plen, max(int(mn), 1),
                                    template_id=s, template_len=plen,
                                    session_id=s))
            plen += int(mn) + user_len     # next turn re-sends everything
            t += think_s * (0.5 + rng.random())
    return sorted(out, key=lambda e: e.time_s)


PATTERNS = {
    "sporadic": sporadic,
    "bursty": bursty,
    "poisson": poisson,
    "trace": trace_replay,
    "shared_prefix": shared_prefix,
    "multiturn": multiturn,
}


def make_arrivals(pattern: str, n_requests: int = 0, *,
                  trace: Optional[Union[str, list]] = None,
                  **kwargs) -> List[ArrivalEvent]:
    """Uniform entry point: make_arrivals("poisson", 32, seed=1, ...)."""
    if pattern == "trace":
        if trace is None:
            raise ValueError("pattern 'trace' needs trace=<path or rows>")
        return trace_replay(trace)
    if pattern not in PATTERNS:
        raise KeyError(f"unknown traffic pattern {pattern!r}; "
                       f"have {sorted(PATTERNS)}")
    return PATTERNS[pattern](n_requests, **kwargs)


def cli_arrivals(pattern: str, n_requests: int, *, seed: int = 0,
                 prompt_len=64, max_new_tokens=32, gap_s: float = 4.0,
                 burst_size: int = 4, rate_rps: float = 1.0,
                 n_templates: int = 4, prefix_len: int = 256,
                 turns: int = 3, trace=None) -> List[ArrivalEvent]:
    """Map the common CLI knob set onto the right generator's kwargs
    (shared by launch/serve.py and benchmarks/bench_serving.py so the
    per-pattern dispatch lives in exactly one place)."""
    if pattern == "trace":
        return make_arrivals("trace", trace=trace)
    kw = dict(seed=seed, prompt_len=prompt_len,
              max_new_tokens=max_new_tokens)
    if pattern == "sporadic":
        kw["gap_s"] = gap_s
    elif pattern == "bursty":
        kw.update(burst_size=burst_size, gap_s=gap_s)
    elif pattern == "poisson":
        kw["rate_rps"] = rate_rps
    elif pattern == "shared_prefix":
        kw.update(n_templates=n_templates, prefix_len=prefix_len,
                  rate_rps=rate_rps)
    elif pattern == "multiturn":
        kw.update(turns=turns, rate_rps=rate_rps)
    return make_arrivals(pattern, n_requests, **kw)
