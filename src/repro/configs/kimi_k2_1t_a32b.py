"""Kimi-K2 1T-A32B — trillion-parameter MoE, 384 experts top-8 + 1 shared.
Assignment specifies GQA kv=8 (paper-table variant). [arXiv:2501.kimi2]"""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family=Family.MOE,
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=18432,           # dense first layer d_ff
    moe_d_ff=2048,        # fine-grained expert d_ff
    vocab_size=163840, head_dim=128,
    n_experts=384, n_shared_experts=1, top_k=8, first_dense_layers=1,
    attn_kind=AttnKind.FULL, rope_theta=50_000.0,
    source="Kimi K2 paper table [arXiv:2501.kimi2]",
)
