"""Draft providers for speculative decoding (DESIGN.md §11).

A draft provider is per-sequence host-side state with three hooks:

  reset(tokens)    start a sequence (prompt + first sampled token)
  observe(tokens)  tokens the verifier actually committed this round
  propose(k)       -> (tokens (k,) int32, probs (k, V) float or None)
                   probs is the proposal distribution q for the
                   stochastic rejection sampler; None declares a
                   point-mass draft (q(token) = 1)

Correctness never depends on the draft: any proposal stream is verified
losslessly, a bad draft only costs acceptance rate. Two built-ins:

  NgramDraft      prompt-lookup self-draft [Saxena'23]: match the longest
                  recent n-gram against earlier context and propose its
                  historical continuation. Zero extra weights, zero extra
                  FLOPs — the draft LIME wants on edge devices, where the
                  whole point is that weight-streaming, not compute,
                  bounds decode.
  SmallModelDraft autoregressive draft from any registered config (smoke-
                  reduced by default): its cache tracks the committed
                  history (snapshot-and-advance, so rejected proposals
                  never pollute it).
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import numpy as np


class NgramDraft:
    """Prompt-lookup: propose the continuation of the most recent earlier
    occurrence of the longest matching tail n-gram."""

    def __init__(self, max_ngram: int = 3):
        assert max_ngram >= 1
        self.max_ngram = max_ngram
        self._hist: List[int] = []

    def reset(self, tokens) -> None:
        self._hist = [int(t) for t in tokens]

    def observe(self, tokens) -> None:
        self._hist.extend(int(t) for t in tokens)

    def propose(self, k: int) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        h = self._hist
        for n in range(min(self.max_ngram, max(len(h) - 1, 0)), 0, -1):
            pat = h[-n:]
            # most recent earlier occurrence wins (locality: repeated
            # spans tend to continue the same way they did last time)
            for i in range(len(h) - n - 1, -1, -1):
                if h[i:i + n] == pat:
                    cont = h[i + n:i + n + k]
                    if cont:
                        out = cont + [cont[-1]] * (k - len(cont))
                        return np.asarray(out[:k], np.int32), None
        last = h[-1] if h else 0
        return np.full(k, last, np.int32), None


class SmallModelDraft:
    """Greedy (or sampled) k-token draft from a small model's own cache.

    The cache only ever contains COMMITTED tokens: propose() decodes from
    a snapshot (jax pytrees are immutable, holding the old reference is
    the snapshot), observe() advances the real cache by teacher-forcing
    the committed tokens through decode_step."""

    def __init__(self, cfg, params, *, max_len: int = 512,
                 temperature: float = 0.0, seed: int = 0):
        import jax

        from repro.models import model as M
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self._rng = np.random.default_rng(seed)
        self._M = M
        self._decode = jax.jit(functools.partial(M.decode_step, cfg))
        self._prefill = jax.jit(functools.partial(M.prefill, cfg))
        self._cache = None
        self._pending: Optional[int] = None   # last token not yet in cache

    def reset(self, tokens) -> None:
        import jax.numpy as jnp
        toks = [int(t) for t in tokens]
        assert toks, "reset needs at least one token"
        cache = self._M.init_cache(self.cfg, 1, self.max_len)
        if len(toks) > 1:
            _, cache = self._prefill(self.params, jnp.asarray(
                [toks[:-1]], jnp.int32), cache)
        self._cache = cache
        self._pending = toks[-1]

    def observe(self, tokens) -> None:
        import jax.numpy as jnp
        for t in tokens:
            _, self._cache = self._decode(
                self.params, self._cache,
                jnp.asarray([[self._pending]], jnp.int32))
            self._pending = int(t)

    def propose(self, k: int) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        import jax.numpy as jnp
        cache = self._cache                    # snapshot
        cur = self._pending
        V = self.cfg.vocab_size
        toks = np.zeros(k, np.int32)
        probs = np.zeros((k, V), np.float64) if self.temperature > 0 \
            else None
        for i in range(k):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray([[cur]], jnp.int32))
            lv = np.asarray(logits, np.float64).reshape(-1)[:V]
            if self.temperature > 0:
                lv = lv / self.temperature
                lv -= lv.max()
                q = np.exp(lv)
                q /= q.sum()
                cur = int(self._rng.choice(V, p=q))
                probs[i] = q
            else:
                cur = int(lv.argmax())
            toks[i] = cur
        return toks, probs


def make_draft_provider(spec, target_cfg, *, target_params=None,
                        resident_ids=None):
    """Build one provider from a SpecConfig (controller.py).

    target_params / resident_ids only matter for draft="resident": the
    resident draft truncates the TARGET's own stacked layers (early-exit
    head), so it needs the real weights and, optionally, the live set of
    resident layer ids (defaults to the bottom spec.resident_layers)."""
    if spec.draft == "ngram":
        return NgramDraft(max_ngram=spec.max_ngram)
    if spec.draft == "resident":
        from repro.specdec.resident_draft import (ResidentDraft,
                                                  default_resident_ids)
        if target_params is None:
            raise ValueError(
                "draft='resident' needs the target params (the draft IS "
                "the target's resident tier)")
        ids = (resident_ids if resident_ids is not None else
               default_resident_ids(target_cfg, spec.resident_layers))
        return ResidentDraft(target_cfg, target_params, ids,
                             temperature=spec.draft_temperature,
                             seed=spec.seed)
    if spec.draft == "model":
        import jax

        from repro.configs.registry import get_smoke_config
        from repro.models import model as M
        cfg = get_smoke_config(spec.draft_arch or "gemma3-1b")
        if cfg.vocab_size != target_cfg.vocab_size:
            import dataclasses
            cfg = dataclasses.replace(cfg,
                                      vocab_size=target_cfg.vocab_size)
        params = M.init_params(cfg, jax.random.PRNGKey(spec.seed))
        return SmallModelDraft(cfg, params,
                               temperature=spec.draft_temperature,
                               seed=spec.seed)
    raise KeyError(f"unknown draft provider {spec.draft!r}")
