"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (1-device) CPU; distributed engine tests re-exec themselves in
a subprocess with a forced device count (the `run_worker` fixture)."""
import importlib.util
import os
import pathlib
import subprocess
import sys

try:
    import hypothesis                                    # noqa: F401
except ModuleNotFoundError:
    # dev extra not installed: register the deterministic stub under the
    # real name so `from hypothesis import given, ...` keeps working
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).parent / "_hypothesis_stub.py")
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod

import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def assert_finite(x, msg=""):
    assert bool(jnp.isfinite(jnp.asarray(x, jnp.float32)).all()), msg


# ----------------------------------------------------------------------------
# shared model/backend factories (hoisted from the per-file copies that
# test_specdec.py / test_engine_hetero.py / test_prefixcache.py grew)
# ----------------------------------------------------------------------------
def _tiny_dense_config(n_layers=2, **overrides):
    """The tiny dense transformer the spec/verify tests all share."""
    from repro.configs.base import Family, ModelConfig
    kw = dict(name="d", family=Family.DENSE, n_layers=n_layers, d_model=32,
              n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8)
    kw.update(overrides)
    return ModelConfig(**kw)


@pytest.fixture
def tiny_dense_cfg():
    """2-layer toy ModelConfig; call the factory for other shapes."""
    return _tiny_dense_config()


@pytest.fixture
def tiny_dense_factory():
    return _tiny_dense_config


@pytest.fixture(scope="session")
def smoke_model():
    """(cfg, params) for reduced gemma3-1b — session-scoped: param init
    dominates the runtime of the serving tests that share it. Params are
    an immutable pytree, so sharing across tests is safe."""
    from repro.configs.registry import get_smoke_config
    from repro.models import model as M
    cfg = get_smoke_config("gemma3-1b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _make_sim_backend(slots, *, spec=None, prompt=64, arch="llama2-13b",
                      plan=None, **kw):
    """SimBackend over the E3 fleet: the serving tests' standard rig."""
    from repro.configs.registry import get_config
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.profiles import env_E3, mbps
    from repro.serving import SimBackend
    cfg = get_config(arch)
    w = Workload(cfg, mb=1, ctx=prompt, n_micro=slots)
    return SimBackend(CostEnv(env_E3(), mbps(200), w), plan, n_slots=slots,
                      prompt_tokens=prompt, spec=spec, **kw)


@pytest.fixture
def sim_backend():
    """Factory: sim_backend(slots, spec=..., prompt=...) -> SimBackend."""
    return _make_sim_backend


# ----------------------------------------------------------------------------
# subprocess worker re-exec (the convention test_engine.py established)
# ----------------------------------------------------------------------------
def _run_worker(worker_src, *argv, devices=8, timeout=900):
    """Re-exec a worker script with src/ on PYTHONPATH and (by default) a
    forced host device count; devices=None keeps the real 1-device CPU.
    Worker output is forwarded so its per-case lines show on failure."""
    env = dict(os.environ)
    if devices:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parent.parent / "src")
    r = subprocess.run([sys.executable, "-c", worker_src, *argv], env=env,
                       capture_output=True, text=True, timeout=timeout)
    sys.stdout.write(r.stdout)
    sys.stderr.write(r.stderr[-2000:])
    return r


@pytest.fixture
def run_worker():
    return _run_worker
