"""Pure-jnp oracle for the flash-attention kernel.

Materializes the full (Sq, Skv) score matrix — only usable at test sizes.
Semantics must match kernel.py exactly: GQA, causal flag, sliding window
(key j visible to query i iff j <= i and i - j < window), fp32 softmax.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None,
                        q_offset: int = 0):
    """q: (B, Sq, H, dh); k, v: (B, Skv, KV, dh) -> (B, Sq, H, dh)."""
    B, Sq, H, dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bikgd,bjkd->bkgij", qf, kf) * (dh ** -0.5)
    i = q_offset + jnp.arange(Sq)[:, None]
    j = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= (i - j) < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgij,bjkd->bikgd", probs, vf)
    return out.reshape(B, Sq, H, dh).astype(q.dtype)
