"""Radix prefix cache: COW KV sharing over the page pool (DESIGN.md §12).

Identical token prefix ⇒ identical KV (the losslessness invariant every
lossless-serving stack shares), so KV pages computed for one request can
back any later request whose prompt starts with the same tokens. The tree
indexes token-id sequences at *page* granularity: each node owns exactly
one full page of `page_size` tokens, keyed by that page's token tuple, and
holds its own incref on the page in the shared `PagePool`. Matching,
insertion and eviction therefore only ever deal in immutable full pages —
the copy-on-write discipline is structural:

  match    walks full-page keys; a hit hands back shared page ids that the
           admission path increfs into the request's BlockTable. The match
           is capped below the prompt's last token (`max_pages`), so every
           request prefills at least one uncached token (the logits that
           seed its first sampled token) and never *writes* a shared page —
           growth past the matched prefix allocates fresh pages.
  insert   adopts a finished request's committed pages node-by-node
           (increfs keep them alive after the request's table releases);
           pages already keyed in the tree are kept (first writer wins —
           both copies hold identical KV by the invariant above).
  evict    LRU leaves first, refcount-pinned pages skipped: a page some
           live BlockTable still shares (refcount > the tree's own hold)
           frees no memory if dropped, so eviction reclaims only pages the
           tree is the sole owner of. Under pool pressure cached pages are
           the *first* thing reclaimed — before any live request is
           preempted (scheduler/_grow_active ordering, DESIGN.md §10).

The tree is substrate-agnostic: over the scheduler's bookkeeping pool it
tracks which simulated pages are reusable; over the engine's real pool the
same structure carries actual K/V bytes (serving/backend.EngineBackend).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.kvcache.pool import PagePool
from repro.obs import trace as tr_ev
from repro.obs.trace import get_tracer
from repro.prefixcache.digest import ROOT_SEED, PrefixDigest, chain_hash


class _Node:
    """One cached page: `key` is its page_size-token tuple, `page` the
    physical page id the tree holds an incref on. `cum` is the cumulative
    chain hash H(parent.cum, key) — it pins down the node's entire root
    path in one integer and is what digest() exports (digest.py)."""
    __slots__ = ("key", "page", "children", "parent", "last_use", "cum")

    def __init__(self, key: Optional[Tuple[int, ...]], page: Optional[int],
                 parent: Optional["_Node"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.last_use = 0
        self.cum = ROOT_SEED if parent is None \
            else chain_hash(parent.cum, key)


class RadixPrefixCache:
    """Token-prefix -> shared KV pages, over a two-tier PagePool."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.page_size = pool.page_size
        self._root = _Node(None, None, None)
        self._clock = 0
        self._n_pages = 0
        self._cum: Dict[int, int] = {}  # chain hash -> node count (hash
                                        # collisions keep both alive)
        # cumulative counters (benchmark / metrics surface)
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.inserted_pages = 0
        self.evicted_pages = 0

    # -- introspection -----------------------------------------------------------
    @property
    def n_pages(self) -> int:
        """Pages the tree currently holds an incref on."""
        return self._n_pages

    def cached_tokens(self) -> int:
        return self._n_pages * self.page_size

    def digest(self) -> PrefixDigest:
        """Router-side snapshot: the cumulative chain hash of every cached
        node (digest.py). O(cached pages) to build, O(prompt pages) to
        query — no token tuples leave the tree."""
        return PrefixDigest(self.page_size, self._cum)

    def _keys(self, tokens: Sequence[int], n_pages: int):
        ps = self.page_size
        for j in range(n_pages):
            yield tuple(int(t) for t in tokens[j * ps:(j + 1) * ps])

    # -- match -------------------------------------------------------------------
    def match(self, tokens: Sequence[int],
              max_pages: Optional[int] = None) -> Tuple[List[int], int]:
        """Longest cached page-aligned prefix of `tokens`. Returns
        (shared page ids, matched token count). `max_pages` caps the walk
        (admission caps it at (prompt_len - 1) // page_size so at least
        one prompt token is always left to prefill)."""
        self._clock += 1
        self.lookups += 1
        cap = len(tokens) // self.page_size
        if max_pages is not None:
            cap = min(cap, max_pages)
        node, pages = self._root, []
        for key in self._keys(tokens, cap):
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = self._clock
            pages.append(child.page)
            node = child
        if pages:
            self.hits += 1
            self.hit_tokens += len(pages) * self.page_size
            tr = get_tracer()
            if tr is not None:
                tr.instant(tr_ev.PREFIX_HIT, track=tr_ev.TRACK_PREFIX,
                           args={"pages": len(pages),
                                 "tokens": len(pages) * self.page_size})
        return pages, len(pages) * self.page_size

    # -- insert ------------------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int],
               n_tokens: Optional[int] = None) -> int:
        """Adopt the full pages of `tokens[:n_tokens]` (token j*ps..(j+1)*ps
        backed by pages[j] — a BlockTable's positional layout). Pages whose
        key already exists are skipped (the tree keeps its copy); new nodes
        incref their page so it outlives the donating table. Returns pages
        newly adopted."""
        self._clock += 1
        n = len(tokens) if n_tokens is None else min(n_tokens, len(tokens))
        n_pages = min(n // self.page_size, len(pages))
        node, new = self._root, 0
        for j, key in enumerate(self._keys(tokens, n_pages)):
            child = node.children.get(key)
            if child is None:
                self.pool.incref_page(pages[j])
                child = _Node(key, pages[j], node)
                node.children[key] = child
                self._cum[child.cum] = self._cum.get(child.cum, 0) + 1
                self._n_pages += 1
                new += 1
            child.last_use = self._clock
            node = child
        self.inserted_pages += new
        if new:
            tr = get_tracer()
            if tr is not None:
                tr.instant(tr_ev.PREFIX_INSERT, track=tr_ev.TRACK_PREFIX,
                           args={"pages": new, "total": self._n_pages})
        return new

    # -- evict -------------------------------------------------------------------
    def _drop(self, node: _Node) -> None:
        node.parent.children.pop(node.key)
        self.pool.decref_page(node.page)
        left = self._cum.get(node.cum, 1) - 1
        if left:
            self._cum[node.cum] = left
        else:
            self._cum.pop(node.cum, None)
        self._n_pages -= 1
        self.evicted_pages += 1

    def evict(self, n_pages: int, tier: Optional[str] = None) -> int:
        """Drop up to `n_pages` LRU leaves whose page the tree solely owns
        (refcount == 1 — dropping a page a live table still shares frees
        nothing). `tier` restricts eviction to pages resident there: a
        caller starved for *device* pages gains nothing from freeing
        host-tier leaves (planner delegation can park cached pages on the
        host). Unlinking a leaf can expose its parent; the sweep repeats
        until the target is met or every remaining leaf is pinned/
        off-tier. Returns pages actually freed."""
        freed = 0
        while freed < n_pages:
            leaves = [n for n in self._iter_nodes() if not n.children]
            progress = False
            for leaf in sorted(leaves, key=lambda n: n.last_use):
                if freed >= n_pages:
                    break
                if self.pool.alloc.refcount(leaf.page) != 1:
                    continue            # pinned: shared with a live table
                if tier is not None and self.pool.tier_of(leaf.page) != tier:
                    continue            # frees the wrong tier's capacity
                self._drop(leaf)
                freed += 1
                progress = True
            if not progress:
                break
        if freed:
            tr = get_tracer()
            if tr is not None:
                tr.instant(tr_ev.PREFIX_EVICT, track=tr_ev.TRACK_PREFIX,
                           args={"pages": freed, "total": self._n_pages})
        return freed

    def release_all(self) -> int:
        """Drop every node regardless of pinning (shutdown / pool teardown);
        returns pages released."""
        n = 0
        for node in list(self._iter_nodes()):
            self.pool.decref_page(node.page)
            n += 1
        self._root.children.clear()
        self._cum.clear()
        self._n_pages = 0
        return n

    def _iter_nodes(self):
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())
