"""Paged vs. worst-case-reservation KV admission (EXPERIMENTS.md §KV-Paging).

Same fleet, same device KV budget, same arrival stream — two admission
policies through the continuous-batching scheduler over the discrete-event
substrate:

  reserve   admit only if prompt + max_new fits alongside every
            co-resident worst case (the pre-§10 scheduler)
  paged     allocate pages as tokens actually materialize; preempt-and-
            spill (or recompute) when the pool runs dry (DESIGN.md §10)

The headline claim: under bursty traffic, paged admission sustains
strictly higher admitted concurrency (peak co-resident requests) at the
same KV budget, because reservations hold `max_new` tokens of headroom
that bursty co-residents never use simultaneously. The run exits non-zero
if that invariant fails.

  python benchmarks/bench_kvcache.py --pattern all
  python benchmarks/bench_kvcache.py --pattern bursty --preempt recompute \
      --budget-factor 2.5 --out /tmp/kvcache.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

PATTERNS = ("sporadic", "bursty", "poisson")


def build_backend(args, slots: int):
    from repro.configs.registry import get_config
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.profiles import env_E1, env_E2, env_E3, mbps
    from repro.serving import SimBackend

    fleets = {"E1": env_E1, "E2": env_E2, "E3": env_E3}
    cfg = get_config(args.arch)
    w = Workload(cfg, mb=1, ctx=args.prompt_len, n_micro=slots)
    env = CostEnv(fleets[args.fleet](), mbps(args.bw_mbps), w)
    return SimBackend(env, n_slots=slots, prompt_tokens=args.prompt_len)


def run_one(args, pattern: str, policy: str) -> dict:
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               cli_arrivals, requests_from_arrivals,
                               summarize)

    slots = 1 if pattern == "sporadic" else args.slots
    arrivals = cli_arrivals(pattern, args.n_requests, seed=args.seed,
                            prompt_len=args.prompt_len,
                            max_new_tokens=args.max_new, gap_s=args.gap_s,
                            burst_size=args.slots, rate_rps=args.rate_rps)
    budget = int(args.budget_factor * (args.prompt_len + args.max_new))
    backend = build_backend(args, slots)
    sched = ContinuousBatchingScheduler(backend, SchedulerConfig(
        kv_budget_tokens=budget, kv_policy=policy,
        page_size=args.page_size, preempt=args.preempt))
    served = sched.serve(requests_from_arrivals(arrivals))
    rep = summarize(served, pattern=pattern, backend=f"sim/{policy}",
                    stats=sched.stats)
    out = rep.to_dict()
    out["kv_policy"] = policy
    out["kv_budget_tokens"] = budget
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pattern", choices=PATTERNS + ("all",), default="all")
    ap.add_argument("--arch", default="llama2-13b")
    ap.add_argument("--fleet", default="E3", choices=("E1", "E2", "E3"))
    ap.add_argument("--bw-mbps", type=float, default=200.0)
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8,
                    help="micro-batch slots for bursty/poisson")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=96)
    ap.add_argument("--gap-s", type=float, default=8.0)
    ap.add_argument("--rate-rps", type=float, default=1.0)
    ap.add_argument("--budget-factor", type=float, default=3.0,
                    help="device KV budget as a multiple of one worst-case "
                         "request (prompt + max_new)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--preempt", choices=("spill", "recompute"),
                    default="recompute",
                    help="pool-dry policy: swap pages to the host tier "
                         "(priced on the wire) or drop + re-prefill; "
                         "recompute wins when ctx is short relative to "
                         "page fetch time (EXPERIMENTS.md §KV-Paging)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    patterns = list(PATTERNS) if args.pattern == "all" else [args.pattern]
    results = []
    comparison = {}
    for pattern in patterns:
        per = {}
        for policy in ("reserve", "paged"):
            r = run_one(args, pattern, policy)
            results.append(r)
            per[policy] = r
        comparison[pattern] = {
            "peak_active_reserve": per["reserve"]["peak_active"],
            "peak_active_paged": per["paged"]["peak_active"],
            "concurrency_gain": (per["paged"]["peak_active"]
                                 / max(per["reserve"]["peak_active"], 1)),
            "throughput_reserve_tok_s": per["reserve"]["throughput_tok_s"],
            "throughput_paged_tok_s": per["paged"]["throughput_tok_s"],
            "paged_preemptions": per["paged"]["n_preempted"],
            "paged_pages_spilled": per["paged"]["kv_pages_spilled"],
        }
    payload = {"config": vars(args), "results": results,
               "comparison": comparison}
    text = json.dumps(payload, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")

    rc = 0
    if "bursty" in comparison:
        c = comparison["bursty"]
        gain = c["concurrency_gain"]
        print(f"# bursty admitted concurrency: paged {c['peak_active_paged']}"
              f" vs reserve {c['peak_active_reserve']} ({gain:.2f}x)",
              file=sys.stderr)
        if c["peak_active_paged"] <= c["peak_active_reserve"]:
            print("# WARNING: paged admission did not beat reservation — "
                  "budget not constraining at this load", file=sys.stderr)
            rc = 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
