"""MetricsRegistry: counters / gauges / histograms for the serving path
(DESIGN.md §15).

Replaces the ad-hoc `stats` dicts that used to flow scheduler ->
`serving.metrics.summarize()`: the scheduler now increments typed
instruments and `ServingReport` is a *derived view* over the flattened
registry (`to_stats_dict()` keeps the exact key vocabulary the legacy
dicts used, so the report is field-identical either way — asserted in
tests/test_obs.py).

Instrument semantics:

  Counter    monotonic; `inc(n)` adds, `set(v)` adopts an externally
             accumulated total (the pool's spilled_pages etc. — counters
             owned by a subsystem the scheduler reads at drain time).
  Gauge      last-written value + high-water mark (`peak`): occupancy
             style quantities where the report wants the max. The peak
             tracks from the FIRST observation — a gauge that only ever
             goes negative peaks at its (negative) maximum, not at the
             0.0 it was constructed with.
  Histogram  nearest-rank percentiles over either every raw observation
             (exact — the default, right for benchmark-sized runs) or a
             bounded reservoir sketch (`MetricsRegistry(hist_capacity=m)`,
             DESIGN.md §17): million-request runs keep m samples per
             histogram instead of all of them, percentiles carry the
             documented reservoir rank-error bound
             (obs/sketch.reservoir_rank_error), and fleet merge() still
             pools correctly (reservoir merge is population-weighted).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.obs.sketch import ReservoirSketch


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def set(self, v: float) -> None:
        self.value = v


class Gauge:
    __slots__ = ("name", "value", "_peak")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._peak: Optional[float] = None   # None until first set():
        # initializing to 0.0 made every never-positive gauge report a
        # phantom peak of 0.0 (satellite fix, ISSUE 9)

    @property
    def peak(self) -> float:
        """High-water mark since the first observation; 0.0 before any
        (the legacy empty-gauge value, kept for report compatibility)."""
        return 0.0 if self._peak is None else self._peak

    def set(self, v: float) -> None:
        self.value = v
        if self._peak is None or v > self._peak:
            self._peak = v


class Histogram:
    """Raw-sample (exact) or reservoir-backed (bounded) percentile
    tracker. `values` is the raw list in exact mode; in bounded mode it
    stays empty and the samples live in `sketch` (percentiles then carry
    the sketch's rank-error bound, not exactness)."""

    __slots__ = ("name", "values", "sketch")

    def __init__(self, name: str, capacity: Optional[int] = None,
                 seed: int = 0):
        self.name = name
        self.sketch: Optional[ReservoirSketch] = None
        if capacity is not None:
            # per-histogram seed: two same-capacity sketches in one
            # registry must not share their replacement schedule
            self.sketch = ReservoirSketch(
                capacity, seed=seed ^ (hash(name) & 0xFFFF))
        self.values: List[float] = []

    @property
    def bounded(self) -> bool:
        return self.sketch is not None

    def observe(self, v: float) -> None:
        if self.sketch is not None:
            self.sketch.observe(v)
        else:
            self.values.append(v)

    @property
    def count(self) -> int:
        return self.sketch.count if self.sketch is not None \
            else len(self.values)

    def percentile(self, p: float) -> float:
        """Nearest-rank (serving.metrics convention); NaN when empty.
        Bounded mode: nearest rank over the reservoir — within the
        documented rank-error bound of the exact answer."""
        if self.sketch is not None:
            return self.sketch.quantile(p)
        if not self.values:
            return float("nan")
        xs = sorted(self.values)
        k = max(math.ceil(p / 100.0 * len(xs)) - 1, 0)
        return xs[min(k, len(xs) - 1)]

    def merge_from(self, other: "Histogram") -> None:
        """Pool `other`'s observations into self (the fleet fold).
        exact+exact concatenates (merged percentiles == pooled, exact);
        any bounded side merges reservoirs (population-weighted — the
        bound survives). An exact self folding a bounded other promotes
        itself to bounded first (the raw samples seed the reservoir);
        mixing modes across a fleet is legal but the result is bounded."""
        if self.sketch is None and other.sketch is None:
            self.values.extend(other.values)
            return
        if self.sketch is None:           # promote: raw -> reservoir
            self.sketch = ReservoirSketch(
                other.sketch.capacity,
                seed=hash(self.name) & 0xFFFF)
            for v in self.values:
                self.sketch.observe(v)
            self.values = []
        if other.sketch is not None:
            self.sketch.merge(other.sketch)
        else:
            for v in other.values:
                self.sketch.observe(v)


class MetricsRegistry:
    """Get-or-create instrument registry with a flat dict view.

    `hist_capacity=None` (default) keeps every histogram observation —
    exact percentiles, memory grows with the run. `hist_capacity=m`
    (DESIGN.md §17) bounds every histogram at an m-sample reservoir:
    constant memory at any request count, percentiles within
    `obs.sketch.reservoir_rank_error(m)` rank error of exact, and
    fleet merge() still pools correctly."""

    def __init__(self, hist_capacity: Optional[int] = None, seed: int = 0):
        self.hist_capacity = hist_capacity
        self._seed = seed
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    # -- instruments -------------------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(name, self.hist_capacity,
                                              seed=self._seed)
        return h

    # -- shorthands (the scheduler's hot-path calls) -----------------------------
    def inc(self, name: str, n: float = 1.0) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v: float) -> None:
        self.counter(name).set(v)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def update(self, stats: Dict[str, float]) -> None:
        """Adopt a subsystem's counter dict (spec stats, adapt stats,
        engine prefix stats — totals owned elsewhere, merged at drain)."""
        for k, v in stats.items():
            self.counter(k).set(v)

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold another registry into this one — the fleet aggregation
        primitive (DESIGN.md §16). Counters sum (totals across replicas),
        gauges take the max (a fleet's peak occupancy is the max of the
        replicas' peaks, not their sum — each replica's pool is its own),
        histograms pool raw samples so merged percentiles equal
        percentiles over the pooled observations *exactly* (asserted in
        tests; merging precomputed percentiles would not be) — unless a
        side is reservoir-bounded, in which case the merge is population-
        weighted and the rank-error bound carries over. Returns self so
        merges chain."""
        for name, c in other._counters.items():
            self.counter(name).inc(c.value)
        for name, g in other._gauges.items():
            mine = self.gauge(name)
            # a never-set local gauge adopts the other's value outright —
            # max() against the constructed 0.0 would invent a zero
            # observation (the negative-gauge peak bug, ISSUE 9)
            if mine._peak is None:
                mine.value, mine._peak = g.value, g._peak
            elif g._peak is not None:
                mine.value = max(mine.value, g.value)
                mine._peak = max(mine._peak, g._peak)
        for name, h in other._hists.items():
            self.histogram(name).merge_from(h)
        return self

    # -- views -------------------------------------------------------------------
    def to_stats_dict(self) -> Dict[str, float]:
        """The legacy flat `stats` vocabulary: counters under their own
        name, gauges under their *peak* when the name says so ("peak_*")
        else current value, histograms as "<name>_p50"/"<name>_p99"."""
        out: Dict[str, float] = {}
        for name, c in self._counters.items():
            out[name] = c.value
        for name, g in self._gauges.items():
            out[name] = g.peak if name.startswith("peak_") else g.value
        for name, h in self._hists.items():
            # empty histogram -> None (not NaN): the stats dict gets
            # json.dumps'd into reports, and NaN is not valid JSON
            p50, p99 = h.percentile(50), h.percentile(99)
            out[f"{name}_p50"] = None if p50 != p50 else p50
            out[f"{name}_p99"] = None if p99 != p99 else p99
            out[f"{name}_count"] = h.count
        return out

    def get(self, name: str, default: float = 0.0) -> float:
        if name in self._counters:
            return self._counters[name].value
        if name in self._gauges:
            g = self._gauges[name]
            return g.peak if name.startswith("peak_") else g.value
        return default
