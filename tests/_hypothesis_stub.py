"""Deterministic fallback for the hypothesis API subset this suite uses.

hypothesis is a dev extra (pyproject `[dev]`); CI installs it and gets real
shrinking + example databases. In environments without it, conftest.py
installs this module under the name ``hypothesis`` so the property tests
still run — each ``@given`` body executes ``max_examples`` times over a
fixed pseudo-random stream (seeded per example index, so failures are
reproducible and runs are order-independent).

Only the surface the tests touch is implemented: ``given``, ``settings``,
and ``strategies.{integers, floats, sampled_from, lists, composite}``.
"""
from __future__ import annotations


import random
import types


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def lists(elements, min_size=0, max_size=10):
    return _Strategy(lambda r: [elements._draw(r)
                                for _ in range(r.randint(min_size,
                                                         max_size))])


def composite(fn):
    def build(*args, **kwargs):
        def draw_one(r):
            return fn(lambda strat: strat._draw(r), *args, **kwargs)
        return _Strategy(draw_one)
    return build


def given(*strategies_):
    def deco(fn):
        # zero-arg wrapper, and no functools.wraps/__wrapped__: pytest
        # must not see the property's drawn parameters as fixtures
        def run():
            n = getattr(run, "_max_examples",
                        getattr(fn, "_max_examples", 25))
            for i in range(n):
                r = random.Random(0x11ED * (i + 1))
                fn(*[s._draw(r) for s in strategies_])
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        return run
    return deco


def settings(max_examples=25, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


strategies = types.SimpleNamespace(
    integers=integers, floats=floats, sampled_from=sampled_from,
    lists=lists, composite=composite)
