"""LIME-Serve benchmark: request patterns through the serving stack
(EXPERIMENTS.md §Serving).

Arrival streams (serving/traffic.py) run through the continuous-batching
scheduler against either substrate and the run is reported as JSON:
ms/token, p50/p99 TTFT, p50/p99 end-to-end latency, token/request
throughput.

  # discrete-event substrate, default 4-device heterogeneous fleet (E3):
  python benchmarks/bench_serving.py --pattern sporadic --backend sim
  python benchmarks/bench_serving.py --pattern bursty   --backend sim
  python benchmarks/bench_serving.py --pattern poisson  --backend sim
  python benchmarks/bench_serving.py --pattern all      --backend sim

  # real execution (1-device smoke fallback; multi-device uses the engine):
  python benchmarks/bench_serving.py --pattern bursty --backend engine \
      --n-requests 6 --max-new 8

The headline sanity check the paper implies: bursty throughput >= sporadic
throughput on the same fleet (micro-batches amortize each segment's weight
streaming). `--pattern all` prints the comparison explicitly.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

PATTERN_CHOICES = ("sporadic", "bursty", "poisson", "trace",
                   "shared_prefix", "multiturn", "all")


def spec_config(args):
    """--spec: speculative decoding on both substrates (DESIGN.md §11)."""
    if not args.spec:
        return None
    from repro.specdec import SpecConfig
    return SpecConfig(k=args.spec_k, draft=args.spec_draft,
                      acceptance=args.spec_acceptance, seed=args.seed)


def build_sim_backend(args, slots: int):
    from repro.configs.registry import get_config
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.profiles import (env_E1, env_E2, env_E3, env_lowmem,
                                     mbps, tpu_pod_stage_devices)
    from repro.serving import SimBackend

    fleets = {"E1": env_E1, "E2": env_E2, "E3": env_E3,
              "lowmem1": lambda: env_lowmem(1),
              "tpu4": lambda: tpu_pod_stage_devices(4)}
    devices = fleets[args.fleet]()
    cfg = get_config(args.arch)
    w = Workload(cfg, mb=1, ctx=args.prompt_len, n_micro=slots)
    env = CostEnv(devices, mbps(args.bw_mbps), w)
    return SimBackend(env, n_slots=slots, prompt_tokens=args.prompt_len,
                      spec=spec_config(args))


def build_engine_backend(args, slots: int, max_prompt: int = 0):
    import jax

    from repro.configs.registry import get_smoke_config
    from repro.models import model as M
    from repro.serving import EngineBackend, SamplerConfig

    engine_arch = args.arch if args.arch in ("gemma3-1b", "internlm2-1.8b") \
        else "gemma3-1b"
    if engine_arch != args.arch:
        print(f"# --backend engine runs smoke configs only: benchmarking "
              f"{engine_arch} (smoke), not {args.arch}", file=sys.stderr)
    cfg = get_smoke_config(engine_arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # size the per-slot cache off the stream's longest prompt — multiturn
    # conversations outgrow the nominal --prompt-len
    max_len = max(max_prompt, args.prompt_len) + args.max_new + 8
    engine = None
    n_dev = len(jax.devices())
    if n_dev >= 4 and n_dev % 4 == 0:   # make_mesh needs prod == n_dev
        import dataclasses

        from repro.core.engine import InterleavedEngine, UniformPlan
        cfg = dataclasses.replace(cfg, n_layers=8)
        mesh = jax.make_mesh((4, n_dev // 4), ("data", "model"))
        plan = UniformPlan(4, 2, 0, 1)
        engine = InterleavedEngine(cfg, mesh, plan, n_mb=slots, mb=1,
                                   max_len=max_len)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
    return EngineBackend(cfg, params, engine=engine, n_slots=slots,
                         max_len=max_len,
                         sampler=SamplerConfig(), spec=spec_config(args),
                         prefix_cache=(args.prefix_cache and engine is None),
                         prefill_chunk_tokens=args.prefill_chunk or 0,
                         page_size=args.page_size)


def trace_path(base: str, pattern: str, multi: bool) -> str:
    """Per-pattern trace file when --pattern all: out.json ->
    out.sporadic.json (one Perfetto file per run, not a concatenation)."""
    if not multi:
        return base
    root, ext = os.path.splitext(base)
    return f"{root}.{pattern}{ext or '.json'}"


def run_pattern(args, pattern: str, trace_out: str = None) -> dict:
    from repro.obs.trace import Tracer, set_tracer
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               cli_arrivals, requests_from_arrivals,
                               summarize)

    slots = 1 if pattern == "sporadic" else args.slots
    arrivals = cli_arrivals(pattern, args.n_requests, seed=args.seed,
                            prompt_len=args.prompt_len,
                            max_new_tokens=args.max_new, gap_s=args.gap_s,
                            burst_size=args.slots, rate_rps=args.rate_rps,
                            n_templates=args.n_templates,
                            prefix_len=args.prefix_len, turns=args.turns,
                            trace=args.arrival_trace)

    if args.replicas > 1 and args.backend != "sim":
        raise SystemExit("--replicas > 1 runs on --backend sim (the "
                         "launcher serves engine-backed fleets)")
    backend = build_sim_backend(args, slots) if args.backend == "sim" \
        else build_engine_backend(args, slots,
                                  max(ev.prompt_len for ev in arrivals))
    kv_policy = args.kv_policy
    if args.prefix_cache and args.backend == "sim":
        kv_policy = "paged"             # the radix tree lives in the pool
    scfg = SchedulerConfig(
        kv_policy=kv_policy, page_size=args.page_size,
        prefix_cache=(args.prefix_cache and args.backend == "sim"),
        prefill_chunk_tokens=args.prefill_chunk)
    # flight recorder: install BEFORE schedulers are built — they cache
    # the tracer and bind its clock to backend.now at construction
    tracer = None
    if trace_out:
        tracer = Tracer(capacity=args.trace_capacity)
        set_tracer(tracer)
    try:
        # template prompts materialize real ids: keep them inside the
        # engine's (smoke) vocab so prefix keys equal what the model
        # actually embeds
        vocab = backend.cfg.vocab_size if args.backend == "engine" else 32768
        reqs = requests_from_arrivals(arrivals, vocab_size=vocab,
                                      seed=args.seed)
        def mk_slo():
            if not args.slo_report:
                return None
            from repro.obs.slo import SLOEngine
            return SLOEngine()

        if args.replicas > 1:
            # fleet mode (DESIGN.md §16): N replica pipelines behind the
            # router; the report's `aggregate` carries the pooled metrics
            from repro.fleet import Fleet, Replica, RouterConfig
            reps = [Replica(0, backend, scfg)]
            reps += [Replica(i, build_sim_backend(args, slots), scfg)
                     for i in range(1, args.replicas)]
            for rep in reps:
                slo = mk_slo()
                if slo is not None:
                    rep.sched.attach_slo(slo)
            fleet = Fleet(reps, config=RouterConfig(policy=args.router,
                                                    seed=args.seed))
            result = fleet.run(reqs)
            out = result.report(
                pattern=pattern,
                backend=f"{args.backend}/fleet{args.replicas}").to_dict()
        else:
            sched = ContinuousBatchingScheduler(backend, scfg)
            slo = mk_slo()
            if slo is not None:
                sched.attach_slo(slo)
            served = sched.serve(reqs)
            out = summarize(served, pattern=pattern, backend=args.backend,
                            stats=sched.stats).to_dict()
            if slo is not None:
                out["slo"] = slo.snapshot(sched.now())
    finally:
        if tracer is not None:
            set_tracer(None)
    if tracer is not None:
        tracer.export(trace_out)
        print(f"# trace: {trace_out} ({tracer.emitted} events, "
              f"{tracer.dropped} dropped)", file=sys.stderr)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pattern", choices=PATTERN_CHOICES, default="all")
    ap.add_argument("--backend", choices=("sim", "engine"), default="sim")
    ap.add_argument("--replicas", type=int, default=1,
                    help="fleet mode (DESIGN.md §16): route the stream "
                         "across N replica pipelines (sim backend)")
    ap.add_argument("--router", default="prefix",
                    choices=("prefix", "sticky", "random", "roundrobin"),
                    help="fleet placement policy (--replicas > 1)")
    ap.add_argument("--arch", default="llama2-13b")
    ap.add_argument("--fleet", default="E3",
                    choices=("E1", "E2", "E3", "lowmem1", "tpu4"),
                    help="device profile set (E3 = the paper's 4-device "
                         "heterogeneous testbed)")
    ap.add_argument("--bw-mbps", type=float, default=200.0)
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4,
                    help="micro-batch slots for bursty/poisson/trace")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--gap-s", type=float, default=4.0)
    ap.add_argument("--rate-rps", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding (DESIGN.md §11): k-token "
                         "draft + one multi-token verify round per step")
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--spec-draft", default="ngram",
                    choices=("ngram", "model"))
    ap.add_argument("--spec-acceptance", type=float, default=0.6,
                    help="sim acceptance model (engine verifies for real)")
    ap.add_argument("--kv-policy", choices=("reserve", "paged"),
                    default="reserve",
                    help="admission accounting: worst-case reservation or "
                         "page-granular (bench_kvcache.py compares both)")
    ap.add_argument("--page-size", type=int, default=64)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache (DESIGN.md §12): match prompt"
                         " prefixes against cached KV pages, prefill only "
                         "the uncached suffix (sim: scheduler-level over "
                         "the paged pool; engine: real KV pages)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: prompts drain this many tokens "
                         "per mixed round alongside live decode streams")
    ap.add_argument("--n-templates", type=int, default=4,
                    help="shared_prefix: distinct prompt templates")
    ap.add_argument("--prefix-len", type=int, default=256,
                    help="shared_prefix: shared template span per prompt")
    ap.add_argument("--turns", type=int, default=3,
                    help="multiturn: conversation turns per session")
    ap.add_argument("--arrival-trace", default=None,
                    help="JSON arrival trace for --pattern trace")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="flight-recorder output (DESIGN.md §15): Chrome "
                         "trace-event JSON loadable in Perfetto, or JSONL "
                         "when PATH ends in .jsonl; --pattern all writes "
                         "one file per pattern")
    ap.add_argument("--trace-capacity", type=int, default=1 << 16,
                    help="flight-recorder ring size (oldest events drop)")
    ap.add_argument("--slo-report", action="store_true",
                    help="attach the online SLO engine (DESIGN.md §17) "
                         "and embed its burn-rate/breach snapshot in the "
                         "report (fleet mode: per-replica under "
                         "membership)")
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)
    if args.pattern == "trace" and not args.arrival_trace:
        ap.error("--pattern trace requires --arrival-trace <arrivals.json>")

    patterns = ["sporadic", "bursty", "poisson"] if args.pattern == "all" \
        else [args.pattern]
    results = [run_pattern(args, p,
                           trace_out=(trace_path(args.trace, p,
                                                 len(patterns) > 1)
                                      if args.trace else None))
               for p in patterns]
    payload = results[0] if len(results) == 1 else results
    text = json.dumps(payload, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")

    if args.pattern == "all":
        by = {r["pattern"]: r.get("aggregate", r) for r in results}
        s, b = by["sporadic"], by["bursty"]
        ratio = b["throughput_tok_s"] / max(s["throughput_tok_s"], 1e-12)
        print(f"# bursty/sporadic throughput: {ratio:.2f}x "
              f"({b['throughput_tok_s']:.2f} vs "
              f"{s['throughput_tok_s']:.2f} tok/s)", file=sys.stderr)
        if ratio < 1.0:
            print("# WARNING: bursty below sporadic — interleave not "
                  "amortizing", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
