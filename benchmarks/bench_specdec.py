"""Speculative decoding vs. autoregressive decode (EXPERIMENTS.md
§SpecDecode).

Same fleet, same arrival stream, two decode disciplines through the
continuous-batching scheduler over the discrete-event substrate:

  autoregressive  one pipeline round per token (the pre-§11 decode)
  speculative     one round verifies k drafted tokens: compute scales
                  with k+1 query positions, but the round's streamed
                  weight bytes — the term that dominates offloaded edge
                  decode — are paid once and amortized over every
                  accepted token (DESIGN.md §11)

The headline claim: at realistic acceptance rates (>= 0.6 per drafted
token) and k = 4, simulated tokens/s with speculation strictly beats the
autoregressive baseline on the paper's default 4-device heterogeneous
fleet (E3). The run exits non-zero if that invariant fails.

  python benchmarks/bench_specdec.py
  python benchmarks/bench_specdec.py --sweep          # k x acceptance grid
  python benchmarks/bench_specdec.py --pattern bursty --k 8 \
      --acceptance 0.8 --out /tmp/specdec.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

PATTERNS = ("sporadic", "bursty", "poisson")


def build_backend(args, slots: int, spec):
    from repro.configs.registry import get_config
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.profiles import env_E1, env_E2, env_E3, mbps
    from repro.serving import SimBackend

    fleets = {"E1": env_E1, "E2": env_E2, "E3": env_E3}
    cfg = get_config(args.arch)
    w = Workload(cfg, mb=1, ctx=args.prompt_len, n_micro=slots)
    env = CostEnv(fleets[args.fleet](), mbps(args.bw_mbps), w)
    return SimBackend(env, n_slots=slots, prompt_tokens=args.prompt_len,
                      spec=spec)


def run_one(args, pattern: str, spec) -> dict:
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               cli_arrivals, requests_from_arrivals,
                               summarize)

    slots = 1 if pattern == "sporadic" else args.slots
    arrivals = cli_arrivals(pattern, args.n_requests, seed=args.seed,
                            prompt_len=args.prompt_len,
                            max_new_tokens=args.max_new, gap_s=args.gap_s,
                            burst_size=args.slots, rate_rps=args.rate_rps)
    backend = build_backend(args, slots, spec)
    sched = ContinuousBatchingScheduler(backend, SchedulerConfig())
    served = sched.serve(requests_from_arrivals(arrivals))
    mode = "spec" if spec is not None else "autoregressive"
    rep = summarize(served, pattern=pattern, backend=f"sim/{mode}",
                    stats=sched.stats)
    out = rep.to_dict()
    out["mode"] = mode
    if spec is not None:
        out["k"] = spec.k
        out["model_acceptance"] = spec.acceptance
    return out


def compare(args, pattern: str, k: int, acceptance: float) -> dict:
    from repro.specdec import SpecConfig

    base = run_one(args, pattern, None)
    spec = run_one(args, pattern,
                   SpecConfig(k=k, acceptance=acceptance, seed=args.seed))
    return {
        "pattern": pattern, "k": k, "acceptance": acceptance,
        "throughput_ar_tok_s": base["throughput_tok_s"],
        "throughput_spec_tok_s": spec["throughput_tok_s"],
        "speedup": (spec["throughput_tok_s"]
                    / max(base["throughput_tok_s"], 1e-12)),
        "measured_acceptance_rate": spec["spec_acceptance_rate"],
        "spec_rounds": spec["spec_rounds"],
        "base": base, "spec": spec,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pattern", choices=PATTERNS + ("all",),
                    default="sporadic",
                    help="sporadic is speculation's home regime: one "
                         "stream, fully weight-streaming-bound")
    ap.add_argument("--arch", default="llama2-13b")
    ap.add_argument("--fleet", default="E3", choices=("E1", "E2", "E3"))
    ap.add_argument("--bw-mbps", type=float, default=200.0)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--gap-s", type=float, default=4.0)
    ap.add_argument("--rate-rps", type=float, default=1.0)
    ap.add_argument("--k", type=int, default=4, help="drafted tokens/round")
    ap.add_argument("--acceptance", type=float, default=0.6,
                    help="per-draft-token acceptance probability of the "
                         "sim's acceptance model")
    ap.add_argument("--sweep", action="store_true",
                    help="k x acceptance grid (EXPERIMENTS.md §SpecDecode)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    patterns = list(PATTERNS) if args.pattern == "all" else [args.pattern]
    results = []
    for pattern in patterns:
        if args.sweep:
            for k in (2, 4, 8):
                for acc in (0.3, 0.6, 0.8):
                    results.append(compare(args, pattern, k, acc))
        else:
            results.append(compare(args, pattern, args.k, args.acceptance))
    payload = {"config": {k: v for k, v in vars(args).items()},
               "results": results}
    text = json.dumps(payload, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")

    # acceptance gate: speculation must beat autoregressive at the
    # headline operating point (k=4, acceptance 0.6 by default)
    rc = 0
    for r in results:
        if r["k"] == args.k and r["acceptance"] == args.acceptance:
            print(f"# {r['pattern']}: spec {r['throughput_spec_tok_s']:.2f} "
                  f"vs AR {r['throughput_ar_tok_s']:.2f} tok/s "
                  f"({r['speedup']:.2f}x) at k={r['k']} "
                  f"acc={r['acceptance']}", file=sys.stderr)
            if r["speedup"] <= 1.0:
                print("# WARNING: speculation did not beat autoregressive "
                      "— verify-round pricing or acceptance model broke",
                      file=sys.stderr)
                rc = 1
    return rc


def run():
    """benchmarks.run harness hook: fast sim-only smoke, one row per
    pattern comparison."""
    class _Row:
        def __init__(self, name, ms):
            self.name, self.ms = name, ms

        def csv(self):
            return f"specdec,{self.name},{self.ms:.1f},ok"

    rows = []
    rc = main(["--pattern", "sporadic", "--n-requests", "4",
               "--max-new", "24"])
    rows.append(_Row("sporadic_k4_acc0.6", 0.0 if rc == 0 else 1.0))
    if rc:
        raise SystemExit("bench_specdec smoke failed")
    return rows


if __name__ == "__main__":
    raise SystemExit(main())
