"""Pure-jnp oracle for the decode-attention kernel.

Single new token attending to a (possibly ring-buffer) KV cache. Slot
validity comes from ``pos_ids`` (absolute position per slot, -1 = empty);
this is the semantics `repro.models.attention.decode_attention_ref`
implements — re-exported here so the kernel package is self-contained.
"""
from repro.models.attention import decode_attention_ref  # noqa: F401
