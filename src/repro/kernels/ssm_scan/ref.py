"""Pure-jnp oracle for the selective-scan kernel — re-export of the model's
`lax.scan` recurrence (single source of truth for semantics)."""
from repro.models.ssm import ssm_scan_ref  # noqa: F401
