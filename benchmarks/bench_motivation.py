"""Paper Fig. 2a: PP+offloading vs TP+offloading latency (motivation).

The paper reports PP+offload 1.2-1.6x faster than TP+offload at 200 Mbps.
That band corresponds to fleets whose TP shards (mostly) fit device memory
— isolating the communication/synchronization difference the figure is
about. Under heavier memory pressure TP's sliding-window streaming blows
the gap out to 5-20x (see bench_paper_e1e2e3 / bench_lowmem), which only
strengthens the paper's conclusion; we report the comm-isolated regime
here to match the figure.
"""
from repro.configs.registry import get_config
from repro.core.baselines import simulate_pp_offload, simulate_tpi_llm
from repro.core.cost_model import CostEnv, Workload
from repro.core.profiles import AGX_ORIN_64, mbps
from benchmarks.common import N_TOKENS, Row


def run():
    rows = []
    for arch, devices in (("llama3.3-70b", [AGX_ORIN_64] * 4),
                          ("qwen3-32b", [AGX_ORIN_64] * 2)):
        cfg = get_config(arch)
        P = 2048
        w = Workload(cfg, mb=1, ctx=P)
        env = CostEnv(devices, mbps(200), w)
        pp = simulate_pp_offload(env, cfg.n_layers, N_TOKENS, prompt=P)
        tp = simulate_tpi_llm(env, cfg.n_layers, N_TOKENS, prompt=P,
                              offload_variant=True)
        sc = f"fig2a/{arch}"
        rows.append(Row(sc, "PP+offload", pp.ms_per_token))
        rows.append(Row(sc, "TP+offload", tp.ms_per_token))
        ratio = tp.ms_per_token / pp.ms_per_token
        print(f"{sc}: PP+off {pp.ms_per_token:.0f} ms/tok, "
              f"TP+off {tp.ms_per_token:.0f} ms/tok -> PP {ratio:.2f}x "
              f"faster (paper: 1.2-1.6x)")
    return rows


if __name__ == "__main__":
    run()
