"""TPU-native LIME: the interleaved pipeline as a JAX shard_map program.

This is implementation (B) of DESIGN.md §2 — the paper's mechanism mapped to
a TPU pod slice:

  Jetson device        -> pipeline stage (one slice of the mesh's stage axis)
  SSD weight offload   -> offloaded layers *sharded across all stages* on
                          their largest divisible weight dim (the pod's
                          aggregate HBM is "the SSD"); restored by an
                          all_to_all — per slot (fetch_mode="slot",
                          paper-literal per-segment streaming) or once per
                          decode step in a two-axis-manual region
                          (fetch_mode="step", optimized; EXPERIMENTS §Perf H1)
  SSD read bandwidth   -> ICI all-to-all bandwidth
  Ethernet activation  -> lax.ppermute ring between stages
  interleaved prefetch -> the restore for the *next* unit of work is issued
                          before the current one's compute consumes its
                          weights, so XLA's async collectives overlap it with
                          compute — the paper's overlap claim, structural.

Layer placement (one ExecutionPlan everywhere — DESIGN.md §13): the L
layers are cut into C = n_seg·n_stage contiguous chunks; chunk c runs on
stage c mod n_stage during segment c // n_stage and holds that stage's
k_d = k_res_d + k_off_d layers (per-stage splits may differ — the offline
scheduler's heterogeneous allocation executes directly; a uniform plan is
the degenerate case). Within a chunk the first k_res_d layers are
resident, the last k_off_d stream in per segment — "positions consistent
across segments" (paper §IV-A). Chunks are padded to the caps and dead
slots masked in the scan, so ONE compiled step serves every stage; the
resident/streamed boundary is a dynamic input, which is what lets
retier() move layers between tiers at runtime without recompiling.

Decode schedule: micro-batch m computes chunk c at slot τ = m + c
(sporadic: n_mb = 1; bursty: n_mb = n_stage). The slot loop is a lax.scan,
so HLO size is O(1) in pipeline depth; fill/drain bubbles are masked
commits, not control flow.

Losslessness is the contract: engine output ≡ single-device decode_step
(test_engine.py asserts equality within bf16 tolerance).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import PARTIAL_AUTO_COLLECTIVES_OK, shard_map

from repro.configs.base import Family, ModelConfig
from repro.core.cost_model import ExecutionPlan, StageAlloc  # noqa: F401
from repro.kvcache import BlockTable, PagePool, PagedKVConfig
from repro.models import model as M
from repro.models import spec as pspec
from repro.obs import trace as tr_ev
from repro.obs.trace import get_tracer


# cache entries stacked on the layer dim (everything else — pos, pos_ids —
# is global; classifying by KEY, not shape, avoids the S_c == n_layers trap)
PER_LAYER_CACHE_KEYS = frozenset({"k", "v", "rwkv_state", "last_tm",
                                  "last_cm", "conv_state", "ssm_state",
                                  "xk", "xv"})


# ============================================================================
# ExecutionPlan (core/cost_model.py) is THE plan object; UniformPlan is the
# degenerate homogeneous-stage constructor kept for the historical API.
# ============================================================================
def UniformPlan(n_stage: int, n_seg: int, k_res: int,
                k_off: int) -> ExecutionPlan:
    """Homogeneous-stage plan (every stage k_res resident + k_off streamed
    per chunk). Delegates to ExecutionPlan.uniform — the engine, simulator
    and offline scheduler all consume the same object."""
    return ExecutionPlan.uniform(n_stage, n_seg, k_res, k_off)


def plan_for(cfg: ModelConfig, n_stage: int, *, hbm_frac_for_weights: float,
             hbm_bytes: float = 16e9) -> ExecutionPlan:
    """Pick (n_seg, k_res, k_off) so resident weights fit the per-stage HBM
    budget. Layers that don't divide evenly fall through to the 2-segment
    fallback, whose chunk is padded (padded slots are zero/identity
    layers); k_res + k_off == ceil(L / n_chunks) by construction, so the
    plan always covers cfg.n_layers AND keeps resident bytes (n_seg ·
    k_res · l_bytes per stage) inside the budget (regression:
    test_plan_for_covers_and_fits_budget)."""
    budget = hbm_bytes * hbm_frac_for_weights
    l_bytes = cfg.layer_params() * 2
    total_per_stage = cfg.n_layers / n_stage * l_bytes
    if total_per_stage <= budget:
        # everything resident: degenerate single-segment pipeline
        k = math.ceil(cfg.n_layers / n_stage)
        return UniformPlan(n_stage, 1, k, 0)
    res_layers = int(budget // l_bytes) * n_stage
    off_layers = cfg.n_layers - res_layers
    for n_seg in range(2, max(3, cfg.n_layers // n_stage + 1)):
        c = n_seg * n_stage
        if cfg.n_layers % c:
            continue
        k = cfg.n_layers // c
        k_off = max(math.ceil(off_layers / c), 1)
        if k_off < k:
            return UniformPlan(n_stage, n_seg, k - k_off, k_off)
    # fallback: 2 segments; resident share sized by the BUDGET (the old
    # fallback derived k_res from floor-divided off_layers, which
    # under-counts the streamed remainder when layer counts don't factor
    # cleanly and could claim far more resident bytes than the stage holds)
    c = 2 * n_stage
    k = math.ceil(cfg.n_layers / c)
    k_res = max(min(int(budget // l_bytes) // 2, k - 1), 0)
    return UniformPlan(n_stage, 2, k_res, k - k_res)


# ============================================================================
# Param / cache reshaping (host-side, once at engine build)
# ============================================================================
def _pad_layers(leaf, L_target: int):
    L = leaf.shape[0]
    if L == L_target:
        return leaf
    pad = [(0, L_target - L)] + [(0, 0)] * (leaf.ndim - 1)
    return jnp.pad(leaf, pad)


def stage_shard_dim(per_layer_shape, n_stage: int):
    """Which weight dim the offload store shards over the stage axis ("the
    SSD" distribution). Largest dim divisible by n_stage wins, so the
    all_to_all moves big contiguous slabs; None -> leaf too small / odd
    shaped, kept replicated across stages (its bytes are noise)."""
    best, best_sz = None, 0
    for i, d in enumerate(per_layer_shape):
        if d % n_stage == 0 and d > best_sz:
            best, best_sz = i, d
    return best


def plan_layout(plan: ExecutionPlan, headroom: int = 0, k_res_live=None):
    """Index maps from the flat (execution-order) layer stack into the
    padded per-stage grid.

    Returns (res_ids, off_ids): int32 arrays of shapes
    (n_seg, n_stage, k_res_cap) and (n_seg, n_stage, headroom + k_off_cap)
    whose entries are flat layer indices, or the sentinel `plan.n_layers`
    (one past the real stack — a guaranteed-zero identity row) for dead
    padding slots. Chunk c = s·n_stage + d holds the k_d = k_res_d +
    k_off_d layers at its cumulative offset: residents first, then the
    streamed tail — same execution order as the flat stack, whatever each
    stage's split.

    `k_res_live` (per-stage, <= build-time k_res) applies the retier
    layout: a demoted resident slot j moves its layer id into off-store
    headroom slot `headroom - (k_res_d - j)`, i.e. demotions fill the
    headroom right-to-left so the streamed tier preserves layer order
    (demoted residents run immediately before the originally-streamed
    tail)."""
    kr, ko = plan.k_res_list, plan.k_off_list
    n_seg, S = plan.n_seg, plan.n_stage
    kr_cap = max(kr) if kr else 0
    ko_cap = headroom + (max(ko) if ko else 0)
    live = list(kr) if k_res_live is None else [int(x) for x in k_res_live]
    assert all(0 <= lv <= k and k - lv <= headroom
               for lv, k in zip(live, kr)), (live, kr, headroom)
    dead = plan.n_layers
    res_ids = np.full((n_seg, S, max(kr_cap, 1)), dead, np.int32)
    off_ids = np.full((n_seg, S, max(ko_cap, 1)), dead, np.int32)
    flat = 0
    for c in range(n_seg * S):
        s, d = c // S, c % S
        for j in range(kr[d]):
            if j < live[d]:
                res_ids[s, d, j] = flat + j
            else:
                off_ids[s, d, headroom - (kr[d] - j)] = flat + j
        for j in range(ko[d]):
            off_ids[s, d, headroom + j] = flat + kr[d] + j
        flat += kr[d] + ko[d]
    return res_ids[:, :, :kr_cap], off_ids[:, :, :ko_cap]


def split_layer_stack(stacked, plan: ExecutionPlan, *, headroom: int = 0,
                      k_res_live=None):
    """(L, ...) pytree -> (resident, offloaded).

    resident:  (n_seg, n_stage, k_res_cap, *dims) — stage-sharded on dim 1.
    offloaded: (n_seg, n_stage, headroom + k_off_cap, *dims) — stage-sharded
               on weight dim `stage_shard_dim(dims) + 3` (or replicated when
               None), so streamed layers stay 'model'-sharded on their other
               dims under GSPMD the whole time — one chip never materializes
               a full MoE layer (kimi-k2: 34 GB/layer).

    Stages whose chunk is smaller than the cap get zero rows — identity
    layers through the residual stream, masked dead in the slot body. A
    uniform plan with headroom 0 reproduces the historical reshape split
    exactly.
    """
    res_ids, off_ids = plan_layout(plan, headroom, k_res_live)

    def do(leaf):
        leaf = _pad_layers(leaf, plan.n_layers + 1)   # +1: the identity row
        return leaf[res_ids], leaf[off_ids]
    pairs = jax.tree.map(do, stacked)
    res = jax.tree.map(lambda p: p[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    off = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return res, off


# ============================================================================
# The engine
# ============================================================================
class InterleavedEngine:
    """LIME decode engine over a mesh axis (default: 'data' doubles as the
    pipeline-stage axis; remaining mesh axes — 'model', 'pod' — stay under
    GSPMD auto-sharding, giving tensor parallelism inside each stage)."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, plan: ExecutionPlan, *,
                 stage_axis: str = "data", n_mb: int = 1, mb: int = 1,
                 max_len: int = 256, long_mode: bool = False,
                 prefetch: bool = True, impl: str = "ref",
                 enc_len: int = 0, fetch_mode: str = "step",
                 paged: bool = False, page_size: int = 64,
                 retier_headroom: int = 0):
        """fetch_mode:
        'slot' — paper-literal per-segment streaming: an all_to_all inside
                 every pipeline slot re-fetches the active chunk's layers.
                 Simple, but each stage re-pulls the same chunk n_stage
                 times per step, and the in-scan collective forces the
                 partitioner to un-shard auto ('model') dims of the slab
                 (§Perf baseline).
        'step' — one two-axis-manual all_to_all per decode step restores
                 every stage's streamed layers for all segments into a
                 double buffer the slot scan indexes; each streamed byte
                 moves once per step and stays 'model'-sharded end to end
                 (§Perf optimized; the beyond-paper variant)."""
        assert mesh.shape[stage_axis] == plan.n_stage, \
            (mesh.shape, plan.n_stage)
        assert fetch_mode in ("slot", "step")
        self.cfg, self.mesh, self.plan = cfg, mesh, plan
        self.axis = stage_axis
        self.n_mb, self.mb = n_mb, mb
        self.max_len = max_len
        self.long_mode = long_mode
        self.prefetch = prefetch
        self.impl = impl
        self.enc_len = enc_len          # ENCDEC: encoder runs outside
        # per-stage tier geometry (DESIGN.md §13): every stage's chunk is
        # padded to the caps so ONE compiled step serves heterogeneous
        # splits; dead slots are zero/identity layers masked in the scan.
        # retier_headroom adds per-stage streamed-store slots so resident
        # layers can demote into the streamed tier at runtime without
        # recompiling (the tier boundary `k_res_live` is a dynamic input).
        self.k_res_b = plan.k_res_list
        self.k_off_b = plan.k_off_list
        self.k_res_cap = max(self.k_res_b) if self.k_res_b else 0
        self.H = max(int(retier_headroom), 0)
        self.k_off_cap = self.H + (max(self.k_off_b) if self.k_off_b else 0)
        self.K = self.k_res_cap + self.k_off_cap
        self.k_res_live = list(self.k_res_b)      # host-side tier boundary
        self.fetch_mode = fetch_mode if self.k_off_cap else "slot"
        if cfg.family == Family.SSM and not PARTIAL_AUTO_COLLECTIVES_OK:
            # Old XLA's partitioner fatally asserts compiling the RWKV
            # family's step-fetch program (manual-subgroup check) even with
            # replicated inputs; the paper-literal slot fetch is verified
            # lossless there, so fall back (new JAX keeps 'step').
            self.fetch_mode = "slot"
        self.S_c = M.kv_cache_len(cfg, max_len, long_mode)
        # paged KV accounting (DESIGN.md §10): the statically-shaped
        # per-slot cache is carved into page_size-token pages owned by a
        # PagePool; slots hold block tables instead of implicit worst-case
        # reservations, so the serving layer sees page-granular occupancy
        # and seed_cache adoption moves real pages (see seed_cache).
        self.paged = paged and self.S_c > 0 and cfg.n_kv_heads > 0
        self.page_size = page_size
        if self.paged:
            self.pages_per_slot = -(-self.S_c // page_size)
            page_bytes = (2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim
                          * page_size * 2.0)            # k+v, bf16
            self.page_pool = PagePool(PagedKVConfig(
                page_size=page_size,
                device_pages=(n_mb * mb) * self.pages_per_slot,
                page_bytes=page_bytes))
            self.slot_tables = [BlockTable(page_size)
                                for _ in range(n_mb * mb)]
            self._paged_pos = 0        # host mirror of glob["pos"]
        self._stage_ids = jnp.arange(plan.n_stage, dtype=jnp.int32)
        self._refresh_tier_inputs()
        self._fetch = self._build_fetch() if self.fetch_mode == "step" \
            else None
        # compiled steps by query length: 1 = autoregressive decode,
        # q_len > 1 = speculative-decoding verification (DESIGN.md §11),
        # built lazily on first use
        self._steps: Dict[Any, Any] = {1: self._build_step(1)}
        self._step = self._steps[1]

    # -- tier boundary (retier) inputs -----------------------------------------
    def _refresh_tier_inputs(self) -> None:
        """(Re)build the layout-dependent step inputs from the live tier
        boundary: the gather maps for state construction, the per-slot
        window table (a layer's window moves with it across tiers), and
        the dynamic `k_res_live` array the compiled step masks against."""
        self._res_ids, self._off_ids = plan_layout(self.plan, self.H,
                                                   self.k_res_live)
        self._cache_ids = np.concatenate([self._res_ids, self._off_ids],
                                         axis=2)        # (n_seg, n_stage, K)
        wins = M.layer_windows(self.cfg, self.plan.n_layers + 1,
                               self.long_mode)
        tab = jnp.asarray(wins)[jnp.asarray(self._cache_ids)]
        tab = jnp.transpose(tab, (1, 0, 2))             # (n_stage, n_seg, K)
        # real-layer mask: grid-overhang slots (ceil-rounded residents,
        # plan capacity past cfg.n_layers) hold zero rows like the dead
        # sentinel does — mask them structurally too, don't rely on
        # zero-weight layers being numerical no-ops
        real = np.transpose(self._cache_ids < self.cfg.n_layers, (1, 0, 2))
        sh = NamedSharding(self.mesh, P(self.axis))
        self._win_dev = jax.device_put(tab.astype(jnp.int32), sh)
        self._live_dev = jax.device_put(jnp.asarray(real), sh)
        self._kl_dev = jax.device_put(
            jnp.asarray(self.k_res_live, jnp.int32), sh)

    def _gather_layer_cache(self, v):
        """Model-layout (L, B, ...) cache leaf -> per-stage grid
        (n_seg, n_stage, K, n_mb, mb, ...), routing each layer's rows to
        its CURRENT slot (resident or streamed/demoted)."""
        x = _pad_layers(v, self.plan.n_layers + 1)
        x = x[self._cache_ids]            # (n_seg, n_stage, K, B, ...)
        shp = x.shape[4:]
        return x.reshape(self.plan.n_seg, self.plan.n_stage, self.K,
                         self.n_mb, self.mb, *shp)

    # -- state construction ----------------------------------------------------
    def init_state(self, params) -> Dict[str, Any]:
        """params: the model's usual pytree (layers stacked on L). Returns the
        engine state with resident/offloaded splits + per-stage caches.
        Respects the live tier boundary: layers demoted by earlier retier
        calls land in the streamed store."""
        cfg, plan = self.cfg, self.plan
        assert "dense_layers" not in params, \
            "engine expects a homogeneous stack; fold dense layers via " \
            "configs with first_dense_layers=0 or pad (see tests)"
        res, off = split_layer_stack(params["layers"], plan,
                                     headroom=self.H,
                                     k_res_live=self.k_res_live)
        cache = M.init_cache(cfg, self.n_mb * self.mb, self.max_len,
                             self.long_mode,
                             enc_out=(jnp.zeros((self.n_mb * self.mb,
                                                 self.enc_len, cfg.d_model),
                                                jnp.bfloat16)
                                      if self.enc_len else None))
        per_layer = {}
        glob = {"pos": cache["pos"]}
        for k, v in cache.items():
            if k == "pos":
                continue
            if k in PER_LAYER_CACHE_KEYS:
                per_layer[k] = self._gather_layer_cache(v)
            else:
                glob[k] = v                      # pos_ids etc. (global)
        others = {k: v for k, v in params.items() if k != "layers"}
        state = {
            "resident": res, "offload": off, "shared": others,
            "cache": per_layer, "glob": glob,
        }
        return jax.device_put(state, self.state_shardings())

    def _model_part(self, dim_size: int, logical_axis) -> Optional[str]:
        """'model' when the rules shard this logical axis there and the dim
        divides (auto-axis at-rest sharding — GSPMD keeps it)."""
        if logical_axis is None or "model" not in self.mesh.shape:
            return None
        from repro.sharding import rules as R
        axes = tuple(a for a in R.RULES.get(logical_axis, ())
                     if a == "model")
        if axes and dim_size % self.mesh.shape["model"] == 0:
            return "model"
        return None

    def _off_pspec(self, per_layer_shape, per_layer_axes=None) -> P:
        sdim = stage_shard_dim(per_layer_shape, self.plan.n_stage)
        parts: list = [None] * (3 + len(per_layer_shape))
        if per_layer_axes is not None:
            for i, (d, la) in enumerate(zip(per_layer_shape, per_layer_axes)):
                mp = self._model_part(d, la)
                if mp and i != sdim:
                    parts[3 + i] = mp
        if sdim is not None:
            parts[3 + sdim] = self.axis
        return P(*parts)

    def _res_pspec(self, per_layer_shape, per_layer_axes=None) -> P:
        parts: list = [None, self.axis] + [None] * (1 + len(per_layer_shape))
        if per_layer_axes is not None:
            for i, (d, la) in enumerate(zip(per_layer_shape, per_layer_axes)):
                mp = self._model_part(d, la)
                if mp:
                    parts[3 + i] = mp
        return P(*parts)

    def _shared_pspec(self, spec: pspec.ParamSpec) -> P:
        parts = [self._model_part(d, la)
                 for d, la in zip(spec.shape, spec.axes)]
        return P(*parts)

    def _cache_pspec(self, shape) -> P:
        """(n_seg, n_stage, k, n_mb, mb, d5, ...): stage on dim 1; the big
        per-layer dim (KV seq / heads / d_model) over 'model' when it
        divides; mb over 'pod' when present (bursty replicas per pod)."""
        parts: list = [None, self.axis] + [None] * (len(shape) - 2)
        if "pod" in self.mesh.shape and len(shape) > 4 \
                and shape[4] % self.mesh.shape["pod"] == 0 and shape[4] > 1:
            parts[4] = "pod"
        if "model" in self.mesh.shape and len(shape) > 5 \
                and shape[5] % self.mesh.shape["model"] == 0:
            parts[5] = "model"
        return P(*parts)

    def state_shardings(self):
        mesh, ax = self.mesh, self.axis
        specs = M.build_param_specs(self.cfg)

        def ns(*spec):
            return NamedSharding(mesh, P(*spec))

        is_spec = pspec.is_spec
        res_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, self._res_pspec(s.shape[1:],
                                                          s.axes[1:])),
            specs["layers"], is_leaf=is_spec)
        off_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, self._off_pspec(s.shape[1:],
                                                          s.axes[1:])),
            specs["layers"], is_leaf=is_spec)
        cs = M.cache_specs(self.cfg, self.n_mb * self.mb, self.max_len,
                           self.long_mode, self.enc_len)
        cache_sh = {}
        for k in self._cache_keys():
            shape = (self.plan.n_seg, self.plan.n_stage, self.K,
                     self.n_mb, self.mb) + cs[k].shape[2:]
            cache_sh[k] = NamedSharding(mesh, self._cache_pspec(shape))
        shared_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, self._shared_pspec(s)),
            {k: v for k, v in specs.items() if k != "layers"},
            is_leaf=is_spec)
        return {"resident": res_sh, "offload": off_sh, "shared": shared_sh,
                "cache": cache_sh,
                "glob": {k: ns() for k in self._glob_keys()}}

    # prototypes for tree-mapping shardings without materialized params
    def _tree_proto(self):
        specs = M.build_param_specs(self.cfg)
        shapes = pspec.shapes(specs["layers"])
        return shapes, shapes

    def _shared_proto(self):
        specs = M.build_param_specs(self.cfg)
        return pspec.shapes({k: v for k, v in specs.items()
                             if k != "layers"})

    def _cache_keys(self):
        cs = M.cache_specs(self.cfg, 1, self.max_len, self.long_mode,
                           self.enc_len)
        return [k for k in cs if k in PER_LAYER_CACHE_KEYS]

    def _glob_keys(self):
        cs = M.cache_specs(self.cfg, 1, self.max_len, self.long_mode,
                           self.enc_len)
        return [k for k in cs if k not in PER_LAYER_CACHE_KEYS]

    # -- step-granular weight restore (fetch_mode="step") ------------------------
    def _fetched_pspec(self, per_layer_shape, per_layer_axes) -> P:
        """(n_stage, n_seg, k_off_cap, *dims): stage dim manual, model dims
        kept — except the stage-store dim, which arrives fully merged."""
        sdim = stage_shard_dim(per_layer_shape, self.plan.n_stage)
        parts: list = [self.axis, None, None] + [None] * len(per_layer_shape)
        for i, (d, la) in enumerate(zip(per_layer_shape, per_layer_axes)):
            mp = self._model_part(d, la)
            if mp and i != sdim:
                parts[3 + i] = mp
        return P(*parts)

    def _build_fetch(self):
        """shard_map with BOTH stage and model axes manual: the all_to_all
        then never forces the partitioner to materialize un-sharded slabs
        (the failure mode of in-scan fetches — EXPERIMENTS.md §Perf)."""
        plan = self.plan
        n_stage = plan.n_stage
        ax = self.axis
        mesh = self.mesh
        specs = M.build_param_specs(self.cfg)["layers"]
        # manual over EVERY mesh axis: the fetch touches only weights (pod
        # never shards them), and leaving an axis auto would make this a
        # partial-auto region whose all_to_all old XLA can't partition
        manual = set(mesh.axis_names)

        def off_in_pspec(s):
            sdim = stage_shard_dim(s.shape[1:], n_stage)
            parts: list = [None] * (3 + len(s.shape[1:]))
            if sdim is not None:
                parts[3 + sdim] = ax
            for i, (d, la) in enumerate(zip(s.shape[1:], s.axes[1:])):
                mp = self._model_part(d, la)
                if mp and i != sdim:
                    parts[3 + i] = mp
            return P(*parts)

        in_specs = jax.tree.map(off_in_pspec, specs, is_leaf=pspec.is_spec)
        out_specs = jax.tree.map(
            lambda s: self._fetched_pspec(s.shape[1:], s.axes[1:]),
            specs, is_leaf=pspec.is_spec)
        sdims = jax.tree.map(
            lambda s: stage_shard_dim(s.shape[1:], n_stage), specs,
            is_leaf=pspec.is_spec)

        def fetch_fn(off):
            def one(leaf, sdim):
                # leaf local: (n_seg, n_stage, k_off, *local_dims)
                contrib = jnp.moveaxis(leaf, 1, 0)  # (n_stage, n_seg, ...)
                if sdim is None:
                    d = jax.lax.axis_index(ax)
                    own = jax.lax.dynamic_index_in_dim(contrib, d, 0, False)
                    return own[None]
                got = jax.lax.all_to_all(contrib, ax, split_axis=0,
                                         concat_axis=2 + sdim)
                shp = list(got.shape)
                merged = shp[:2 + sdim] + [shp[2 + sdim] * shp[3 + sdim]] \
                    + shp[4 + sdim:]
                return got.reshape(merged)[None]
            return jax.tree.map(one, off, sdims)

        return jax.jit(shard_map(fetch_fn, mesh=mesh, in_specs=(in_specs,),
                                 out_specs=out_specs, axis_names=manual,
                                 check_vma=False))

    # -- the SPMD step -----------------------------------------------------------
    def _build_step(self, q_len: int = 1, resident_only: bool = False):
        """q_len = 1: one autoregressive token (the historical step).
        q_len > 1: a speculative verification round — every micro-batch
        carries q_len query positions through the same slot schedule, so
        one pipeline traversal (one weight-stream) scores all of them;
        logits come back per position (DESIGN.md §11).
        resident_only (q_len must be 1): the self-draft step (DESIGN.md
        §14) — the same slot schedule with the streamed tier skipped
        entirely: no offload input, no weight fetch, the per-chunk layer
        scan runs only the k_res_cap resident rows (masked at the LIVE
        `kl` boundary, so retier needs no recompile), and the final norm
        + LM head act as the early-exit draft head. K/V writes land in
        resident rows only; the verify round overwrites every row at the
        drafted positions before reading them, so drafts leak nothing."""
        assert not (resident_only and q_len != 1), (q_len,)
        res_only = resident_only
        cfg, plan = self.cfg, self.plan
        n_stage, n_seg = plan.n_stage, plan.n_seg
        k_res_cap, k_off_cap, H, K = (self.k_res_cap, self.k_off_cap,
                                      self.H, self.K)
        KC = k_res_cap if res_only else K      # layer rows the scan runs
        # per-stage build-time tiers, baked as constants the traced stage
        # id selects from; the LIVE boundary arrives as the kl input
        KR_B = jnp.asarray(self.k_res_b, jnp.int32)
        KO_B = jnp.asarray(self.k_off_b, jnp.int32)
        C = plan.n_chunks
        n_mb, mb = self.n_mb, self.mb
        n_slots = C + n_mb - 1
        ax = self.axis
        impl = self.impl
        PV = M.round_up(cfg.vocab_size, 256)
        prefetch = self.prefetch

        layer_shapes = pspec.shapes(M.build_param_specs(cfg)["layers"])
        is_sds = lambda x: isinstance(x, jax.ShapeDtypeStruct)
        stage_dims = jax.tree.map(
            lambda s: stage_shard_dim(s.shape[1:], n_stage), layer_shapes,
            is_leaf=is_sds)

        def fetch_chunk_weights(off_local, tau, d):
            """all_to_all restore of each stage's streamed layers for the
            chunk it runs at slot `tau`. Stage-sharded leaves arrive via an
            untiled all_to_all on their stage dim; replicated leaves are a
            local gather. 'model'-sharded dims stay sharded throughout
            (GSPMD auto axes). On old XLA the in-scan all_to_all is emulated
            with a psum of offset-scattered shards (compat: partial-auto
            collectives other than psum fatally assert in the partitioner).
            """
            if k_off_cap == 0:
                return None
            e = jnp.arange(n_stage)
            m_e = (tau - e) % n_stage if n_mb > 1 else jnp.zeros_like(e)
            c_e = tau - m_e
            s_e = jnp.clip(c_e // n_stage, 0, n_seg - 1)
            s_d = jnp.clip((tau - ((tau - d) % n_stage if n_mb > 1 else 0))
                           // n_stage, 0, n_seg - 1)

            def one(leaf, sdim):
                if sdim is None:
                    # replicated store: local pick of (my segment, my stage)
                    seg = jax.lax.dynamic_index_in_dim(leaf, s_d, 0, False)
                    return jax.lax.dynamic_index_in_dim(seg, d, 0, False)
                contrib = leaf[s_e, e]        # (n_stage, k_off, *dims_local)
                if PARTIAL_AUTO_COLLECTIVES_OK:
                    # untiled all_to_all: axis0 consumed, new n_stage axis
                    # at the stage-sharded dim; merge it back to full width.
                    got = jax.lax.all_to_all(contrib, ax, split_axis=0,
                                             concat_axis=1 + sdim)
                    # got: (k_off, ..., n_stage, dim/n_stage, ...) at 1+sdim
                    shp = list(got.shape)
                    merged = shp[:1 + sdim] \
                        + [shp[1 + sdim] * shp[2 + sdim]] + shp[3 + sdim:]
                    return got.reshape(merged)
                # psum emulation: every stage writes its shard of each
                # destination's slab at its own offset of the full weight
                # dim (axis 2+sdim of contrib), disjoint across stages, so
                # the psum concatenates; each stage then picks its own row.
                shard = contrib.shape[2 + sdim]
                full = list(contrib.shape)
                full[2 + sdim] = shard * n_stage
                starts = [jnp.int32(0)] * len(full)
                starts[2 + sdim] = d * shard
                buf = jax.lax.dynamic_update_slice(
                    jnp.zeros(tuple(full), contrib.dtype), contrib,
                    tuple(starts))
                buf = jax.lax.psum(buf, ax)
                return jax.lax.dynamic_index_in_dim(buf, d, 0, False)
            return jax.tree.map(one, off_local, stage_dims)

        def ring_shift(x, d):
            """Hand the activation to the next stage. ppermute where the
            partitioner allows it; else a psum of a one-hot-scattered
            buffer (stage d writes slot d+1, reads its own slot)."""
            if PARTIAL_AUTO_COLLECTIVES_OK:
                return jax.lax.ppermute(
                    x, ax, [(i, (i + 1) % n_stage) for i in range(n_stage)])
            buf = jnp.zeros((n_stage,) + x.shape, x.dtype)
            buf = jax.lax.dynamic_update_index_in_dim(
                buf, x, (d + 1) % n_stage, 0)
            buf = jax.lax.psum(buf, ax)
            return jax.lax.dynamic_index_in_dim(buf, d, 0, False)

        def chunk_params(res_local, fetched, s_d):
            """Assemble the K (padded) layers of the active chunk on this
            stage: resident cap first, then the streamed store (headroom +
            streamed tail) — dead slots carry zero/identity layers."""
            res_s = jax.tree.map(
                lambda r: jax.lax.dynamic_index_in_dim(r[:, 0], s_d, 0,
                                                       keepdims=False),
                res_local)                        # (k_res_cap, ...)
            if k_off_cap == 0 or fetched is None:
                return res_s
            return jax.tree.map(
                lambda r, f: jnp.concatenate([r, f.astype(r.dtype)], axis=0),
                res_s, fetched)

        step_mode = self.fetch_mode == "step"

        def step_fn(resident, offload, shared, cache, glob, tokens,
                    stage_id, kl, win_tab, real_tab):
            """One autoregressive token for all n_mb micro-batches.
            tokens: (n_mb, mb, 1) int32 (replicated). Locals per stage:
            resident (n_seg, 1, k_res_cap, ...); cache (n_seg, 1, K, n_mb,
            mb, ...); offload: fetch_mode='slot' -> the sharded store,
            'step' -> the per-stage restored buffer (1, n_seg, k_off_cap,
            ...). stage_id: (1,) int32, stage-sharded iota — the stage's
            own index. Passed in rather than jax.lax.axis_index(ax): in a
            partial-auto shard_map old XLA lowers axis_index to a
            PartitionId op its SPMD partitioner rejects.
            kl: (1,) int32 — the stage's LIVE resident count (the dynamic
            tier boundary; retier changes it without recompiling).
            win_tab: (1, n_seg, K) int32 — per-slot attention windows for
            the stage's CURRENT layout (a layer's window moves with it).
            real_tab: (1, n_seg, K) bool — slot holds a real model layer
            (False on dead padding AND grid overhang past cfg.n_layers)."""
            d = stage_id[0]
            # dead-slot mask (DESIGN.md §13): resident slots past the live
            # boundary, unfilled headroom, and cap padding are identity —
            # zero weights make them so numerically, the mask makes it
            # structural (and exact for every family)
            m_dem = KR_B[d] - kl[0]
            jidx = jnp.arange(KC)
            if res_only:
                # only resident rows below the LIVE boundary run: demoted
                # layers sit in the streamed store the draft never touches
                live_d = jidx < kl[0]
            else:
                live_d = (jidx < kl[0]) \
                    | ((jidx >= k_res_cap + H - m_dem)
                       & (jidx < k_res_cap + H + KO_B[d]))
            win_d = win_tab[0][:, :KC]          # (n_seg, KC)
            real_d = real_tab[0][:, :KC]        # (n_seg, KC) bool
            pos = glob["pos"]
            pos_ids = glob.get("pos_ids")
            slot = jnp.int32(0)
            q_slots = None
            if pos_ids is not None:
                S_c = pos_ids.shape[0]
                slot = pos % S_c
                if q_len == 1:
                    pos_ids = jax.lax.dynamic_update_slice(
                        pos_ids, pos[None].astype(pos_ids.dtype), (slot,))
                else:
                    qpos = pos + jnp.arange(q_len)
                    q_slots = qpos % S_c
                    # contiguous update (no Scatter — old-XLA partial-auto
                    # partitioner fatally asserts on it); the verify
                    # window never wraps (backend caps pos + q_len)
                    pos_ids = jax.lax.dynamic_update_slice(
                        pos_ids, qpos.astype(pos_ids.dtype), (slot,))

            x0 = jnp.zeros((mb, q_len, cfg.d_model), jnp.bfloat16)
            logits0 = jnp.zeros((n_mb, mb, q_len, PV), jnp.float32)
            fetched0 = None if (step_mode or res_only) else \
                fetch_chunk_weights(offload, jnp.int32(0), d)

            def slot_body(carry, tau):
                x, logits_buf, cache_l, fetched = carry
                # my active (chunk, micro-batch) at this slot
                m_d = ((tau - d) % n_stage) if n_mb > 1 else jnp.int32(0)
                m_d = jnp.where(n_mb > 1, m_d, 0)
                c_d = tau - m_d
                valid = (c_d >= 0) & (c_d < C) & (m_d < n_mb) \
                    & (c_d % n_stage == d)
                s_d = jnp.clip(c_d // n_stage, 0, n_seg - 1)

                # interleave: issue next slot's weight fetch BEFORE compute
                if res_only:
                    # self-draft: zero weight streaming — the whole point
                    nxt = cur = None
                elif step_mode:
                    nxt = None
                    cur = None if k_off_cap == 0 else jax.tree.map(
                        lambda w: jax.lax.dynamic_index_in_dim(
                            w[0], s_d, 0, False), offload)
                else:
                    nxt = fetch_chunk_weights(offload, tau + 1, d) \
                        if prefetch else None
                    cur = fetched if prefetch else \
                        fetch_chunk_weights(offload, tau, d)

                # entering micro-batches embed their token at chunk 0
                tok_m = jnp.take(tokens, jnp.clip(m_d, 0, n_mb - 1), axis=0)
                x_in = jnp.where((c_d == 0)[..., None, None],
                                 M.embed(shared, tok_m).astype(jnp.bfloat16),
                                 x)

                p_chunk = chunk_params(resident, cur, s_d)
                cache_chunk = {kk: jax.lax.dynamic_index_in_dim(
                    v[:, 0], s_d, 0, keepdims=False) for kk, v in
                    cache_l.items()}      # (k, n_mb, mb, ...)
                cache_mb = {kk: jax.lax.dynamic_index_in_dim(
                    v, jnp.clip(m_d, 0, n_mb - 1), 1, keepdims=False)
                    for kk, v in cache_chunk.items()}   # (k, mb, ...)
                if res_only:
                    cache_mb = {kk: v[:KC] for kk, v in cache_mb.items()}

                moe_mesh = self.mesh if (cfg.family == Family.MOE
                                         and "model" in self.mesh.shape) \
                    else None
                inner = M._decode_body(cfg, moe_mesh, impl,
                                       cfg.family == Family.MOE, pos, slot,
                                       pos_ids, enc_len=self.enc_len,
                                       moe_mode="auto", q_slots=q_slots)

                def body(carry, xs_l):
                    # dead slots are identity: activation (and MoE aux)
                    # pass through untouched; their cache writes land in
                    # rows nothing ever reads
                    x_prev, aux_prev = carry
                    (x_new, aux_new), ys_l = inner(carry, xs_l)
                    alive = xs_l["live"]
                    return (jnp.where(alive, x_new, x_prev),
                            jnp.where(alive, aux_new, aux_prev)), ys_l

                xs = {"p": p_chunk,
                      "window": jax.lax.dynamic_index_in_dim(win_d, s_d, 0,
                                                             False),
                      "live": live_d & jax.lax.dynamic_index_in_dim(
                          real_d, s_d, 0, False)}
                xs.update(cache_mb)
                (x_out, _), ys = jax.lax.scan(body, (x_in, jnp.float32(0.)),
                                              xs)

                # commit cache only when valid
                m_c = jnp.clip(m_d, 0, n_mb - 1)

                def commit(old, new):
                    cur_s = jax.lax.dynamic_index_in_dim(old[:, 0], s_d, 0,
                                                         False)
                    prev = jax.lax.dynamic_index_in_dim(cur_s, m_c, 1, False)
                    if res_only:
                        # the draft scan produced KC rows: write them back
                        # into the resident prefix, streamed rows untouched
                        upd = jnp.where(valid, new.astype(old.dtype),
                                        prev[:KC])
                        upd = jax.lax.dynamic_update_slice_in_dim(
                            prev, upd, 0, axis=0)
                    else:
                        upd = jnp.where(valid, new.astype(old.dtype), prev)
                    cur_s = jax.lax.dynamic_update_index_in_dim(
                        cur_s, upd, m_c, 1)
                    return jax.lax.dynamic_update_index_in_dim(
                        old, cur_s[None], s_d, 0)
                cache_l = dict(cache_l)      # keep read-only keys (xk/xv)
                cache_l.update({kk: commit(cache_l[kk], ys[kk])
                                for kk in ys})

                # last chunk: unembed and stash logits
                is_last = valid & (c_d == C - 1)
                xn = M.rms_norm(x_out, shared["final_norm"], cfg.norm_eps)
                lg = M.unembed(shared, xn).astype(jnp.float32)
                logits_buf = jnp.where(
                    is_last,
                    jax.lax.dynamic_update_index_in_dim(
                        logits_buf, lg, jnp.clip(m_d, 0, n_mb - 1), 0),
                    logits_buf)

                # hand activation to the next stage (ring)
                x_next = ring_shift(x_out, d)
                dbg = (jnp.abs(x_out.astype(jnp.float32)).sum(),
                       c_d, valid.astype(jnp.int32))
                return (x_next, logits_buf, cache_l,
                        nxt if prefetch else fetched), dbg

            carry0 = (x0, logits0, cache, fetched0)
            (xf, logits_buf, cache_f, _), dbg = jax.lax.scan(
                slot_body, carry0, jnp.arange(n_slots, dtype=jnp.int32))

            logits = jax.lax.psum(logits_buf, ax) / 1.0  # only last stage wrote
            new_glob = dict(glob)
            new_glob["pos"] = pos + q_len
            if pos_ids is not None:
                new_glob["pos_ids"] = pos_ids
            dbg_out = jnp.stack([dbg[0],
                                 dbg[1].astype(jnp.float32),
                                 dbg[2].astype(jnp.float32)], -1)[None]
            return logits, cache_f, new_glob, dbg_out

        proto = self._tree_proto()[0]
        out_specs = (P(), {kk: P(None, ax) for kk in self._cache_keys()},
                     {kk: P() for kk in self._glob_keys()}, P(ax))
        if res_only:
            # no offload leg at all: the draft program never sees the
            # streamed store, so XLA cannot schedule a fetch for it
            def draft_fn(resident, shared, cache, glob, tokens, stage_id,
                         kl, win_tab, real_tab):
                return step_fn(resident, None, shared, cache, glob, tokens,
                               stage_id, kl, win_tab, real_tab)
            in_specs = (jax.tree.map(lambda _: P(None, ax), proto,
                                     is_leaf=is_sds),
                        jax.tree.map(lambda _: P(), self._shared_proto()),
                        {kk: P(None, ax) for kk in self._cache_keys()},
                        {kk: P() for kk in self._glob_keys()},
                        P(), P(ax), P(ax), P(ax), P(ax))
            fn = shard_map(draft_fn, mesh=self.mesh, in_specs=in_specs,
                           out_specs=out_specs, axis_names={ax},
                           check_vma=False)
            return jax.jit(fn, donate_argnums=(2,))
        if step_mode:
            off_in = jax.tree.map(lambda _: P(ax), proto, is_leaf=is_sds)
        else:
            off_in = jax.tree.map(lambda s: self._off_pspec(s.shape[1:]),
                                  proto, is_leaf=is_sds)
        in_specs = (jax.tree.map(lambda _: P(None, ax), proto,
                                 is_leaf=is_sds),
                    off_in,
                    jax.tree.map(lambda _: P(), self._shared_proto()),
                    {kk: P(None, ax) for kk in self._cache_keys()},
                    {kk: P() for kk in self._glob_keys()},
                    P(), P(ax), P(ax), P(ax), P(ax))
        fn = shard_map(step_fn, mesh=self.mesh, in_specs=in_specs,
                       out_specs=out_specs, axis_names={ax},
                       check_vma=False)
        # donate the KV/state caches: the slot scan's functional update
        # would otherwise double-buffer them (kimi-k2: +4.2 GB/chip peak)
        return jax.jit(fn, donate_argnums=(3,))

    # -- paged slot accounting (DESIGN.md §10) -----------------------------------
    def _paged_seed_slots(self, ctx: int) -> None:
        """(Re)build every slot's block table to hold `ctx` tokens."""
        for t in self.slot_tables:
            self.page_pool.release_table(t)
        for t in self.slot_tables:
            self.page_pool.extend_table(t, min(ctx, self.S_c))

    def _through_pages(self, x: np.ndarray, ctx: int) -> np.ndarray:
        """Round-trip a model-layout (L, B, S_c, ...) K or V stack through
        the page pool: scatter each slot's first `ctx` rows into its block
        table's pages, then gather them back. Page placement is whatever
        the free list handed out (LIFO — non-contiguous after any realloc),
        so adoption actually exercises the table indirection; the result is
        bit-identical by construction (pure data movement)."""
        from repro.kvcache.layout import gather_from_pages, scatter_to_pages
        x = np.asarray(x)
        ctx = min(ctx, self.S_c)
        pool_shape = (x.shape[0], self.page_pool.alloc.n_pages,
                      self.page_size) + x.shape[3:]
        pool_buf = scatter_to_pages(np.zeros(pool_shape, x.dtype), x,
                                    self.slot_tables, ctx)
        return gather_from_pages(x.copy(), pool_buf, self.slot_tables, ctx)

    def extend_slot(self, slot: int, n_tokens: Optional[int] = None) -> None:
        """Page-granular growth for one slot (serving calls this per
        decode step for live slots). Raises OutOfPages when the pool is
        dry — cannot happen while every slot's table is capped at
        pages_per_slot, which extend_to guarantees via S_c clamping."""
        t = self.slot_tables[slot]
        target = t.tokens + 1 if n_tokens is None else n_tokens
        self.page_pool.extend_table(t, min(target, self.S_c))

    def free_slot(self, slot: int) -> None:
        """Release a completed request's pages (serving release hook)."""
        self.page_pool.release_table(self.slot_tables[slot])

    def paged_stats(self) -> Dict[str, int]:
        return {"pages_in_use": self.page_pool.pages_in_use(),
                "page_size": self.page_size,
                "slot_tokens": [t.tokens for t in self.slot_tables]}

    def seed_cache(self, state, cache) -> Dict[str, Any]:
        """Adopt a model-layout cache (e.g. produced by M.prefill on
        replicated params) into the engine's per-stage layout.

        Paged mode: adoption is rewritten over block tables — each slot's
        K/V tokens are scattered into its table's pool pages and gathered
        back before the per-stage reshape, so the table indirection (not a
        contiguous memcpy) is what carries the bytes, and slot occupancy
        is page-granular from the first decode step."""
        tr = get_tracer()
        if tr is not None:
            tr.instant(tr_ev.ENGINE_SEED, track=tr_ev.TRACK_ENGINE,
                       args={"pos": int(cache["pos"]),
                             "paged": self.paged})
        plan = self.plan
        paged_ctx = int(cache["pos"]) if self.paged else 0
        if self.paged:
            self._paged_pos = paged_ctx
            self._paged_seed_slots(paged_ctx)
        new_cache = {}
        glob = dict(state["glob"])
        for kk, v in cache.items():
            if kk in PER_LAYER_CACHE_KEYS:
                if self.paged and kk in ("k", "v"):
                    v = jnp.asarray(self._through_pages(v, paged_ctx),
                                    v.dtype)
                new_cache[kk] = self._gather_layer_cache(v)
            else:
                glob[kk] = v
        out = dict(state)
        sh = self.state_shardings()
        out["cache"] = jax.device_put(new_cache, sh["cache"])
        out["glob"] = glob
        return out

    def _defer_model_sharding(self, fetched):
        """Old-XLA compat: a fetched buffer whose leaves mix the manual
        stage dim with at-rest 'model' auto shardings trips the partitioner
        inside the step (hlo_sharding_util manual-subgroup assert, SSM
        leaves). Reshard to stage-only between the two programs — an ICI
        all-gather of the streamed layers' model dims, old JAX only."""
        if PARTIAL_AUTO_COLLECTIVES_OK:
            return fetched
        sh = NamedSharding(self.mesh, P(self.axis))
        return jax.device_put(fetched, jax.tree.map(lambda _: sh, fetched))

    # -- public API ---------------------------------------------------------------
    def decode_step(self, state, tokens):
        """tokens: (n_mb * mb, 1) int32 -> (logits (n_mb*mb, PV), state)."""
        t = tokens.reshape(self.n_mb, self.mb, 1)
        off = state["offload"]
        if self.fetch_mode == "step":
            off = self._defer_model_sharding(self._fetch(off))
        logits, cache, glob, dbg = self._step(
            state["resident"], off, state["shared"],
            state["cache"], state["glob"], t, self._stage_ids,
            self._kl_dev, self._win_dev, self._live_dev)
        new_state = dict(state)
        new_state["cache"] = cache
        new_state["glob"] = glob
        self.last_debug = dbg       # (n_stage, n_slots, [xnorm, chunk, valid])
        return logits.reshape(self.n_mb * self.mb, -1), new_state

    def decode_requests(self, state, tokens, active):
        """Serving entry point (DESIGN.md §9): one decode step for a batch
        of slot-resident requests where only some slots are live.

        tokens: (n_mb*mb, 1) int32; active: (n_mb*mb,) bool. Inactive slots
        ride the pipeline as padding — their tokens are zeroed so the step
        stays deterministic regardless of stale slot contents, their cache
        writes land in slots the scheduler has already released, and their
        logits must be ignored by the caller. This keeps one compiled step
        for every occupancy level (recompiling per occupancy would defeat
        continuous batching).
        """
        tr = get_tracer()
        if tr is not None:
            tr.instant(tr_ev.ENGINE_DECODE, track=tr_ev.TRACK_ENGINE,
                       args={"live": int(np.asarray(active, bool).sum())})
        if self.paged:
            # page-granular occupancy: live slots grow one token (a new
            # page every page_size steps); released slots hold nothing.
            # pos is tracked host-side (seeded in seed_cache, +1 per
            # step) — a device_get here would sync the async dispatch
            # pipeline every decode step.
            self._paged_pos += 1
            for slot, live in enumerate(np.asarray(active, bool)):
                if live:
                    self.extend_slot(slot, self._paged_pos)
        active = jnp.asarray(active, bool)
        toks = jnp.where(active[:, None], tokens.astype(jnp.int32), 0)
        return self.decode_step(state, toks)

    # -- speculative verification (DESIGN.md §11) --------------------------------
    def verify_step(self, state, tokens):
        """Score q_len query positions per slot in ONE pipeline round —
        one weight-stream validates q_len tokens. tokens: (n_mb*mb,
        q_len) int32, column 0 the last committed token, the rest
        drafted. Returns (logits (n_mb*mb, q_len, PV), state) with pos
        advanced by q_len and all q_len K/V written; the caller commits
        an accepted prefix via rollback() (stale entries carry pos_ids >
        pos and are masked out of every later read)."""
        if self.cfg.family not in (Family.DENSE, Family.MOE):
            raise NotImplementedError(
                f"speculative verification needs pure-KV per-layer state "
                f"(DENSE/MOE), not {self.cfg.family}")
        q_len = tokens.shape[1]
        assert 1 <= q_len < max(self.S_c, 2), (q_len, self.S_c)
        if q_len not in self._steps:
            self._steps[q_len] = self._build_step(q_len)
        t = tokens.reshape(self.n_mb, self.mb, q_len)
        off = state["offload"]
        if self.fetch_mode == "step":
            off = self._defer_model_sharding(self._fetch(off))
        logits, cache, glob, dbg = self._steps[q_len](
            state["resident"], off, state["shared"],
            state["cache"], state["glob"], t, self._stage_ids,
            self._kl_dev, self._win_dev, self._live_dev)
        new_state = dict(state)
        new_state["cache"] = cache
        new_state["glob"] = glob
        self.last_debug = dbg
        return logits.reshape(self.n_mb * self.mb, q_len, -1), new_state

    def verify_requests(self, state, tokens, active):
        """Slot-masked verify_step (serving entry): inactive slots ride
        as padding with zeroed tokens, their logits must be ignored.
        Paged slot accounting is the caller's job (note_committed) —
        unlike decode_requests, the tokens actually kept are only known
        after acceptance."""
        tr = get_tracer()
        if tr is not None:
            tr.instant(tr_ev.ENGINE_VERIFY, track=tr_ev.TRACK_ENGINE,
                       args={"q_len": int(tokens.shape[1])})
        active = jnp.asarray(active, bool)
        toks = jnp.where(active[:, None], tokens.astype(jnp.int32), 0)
        return self.verify_step(state, toks)

    # -- resident-tier self-draft (DESIGN.md §14) --------------------------------
    def draft_step(self, state, tokens):
        """One decode step through ONLY the live resident tier: the same
        slot schedule as decode_step with zero weight streaming (no
        offload input at all), the final norm + LM head as the early-exit
        draft head. tokens: (n_mb*mb, 1) int32 -> (logits, state) with pos
        advanced by 1.

        Snapshot-and-advance contract: k draft steps write resident-row
        K/V at positions pos..pos+k-1, then rollback(state, pos) +
        verify_step overwrite every row (resident AND streamed) at those
        positions before attention reads them — drafting leaks nothing
        into the verified stream, and never touches paged accounting
        (note_committed after acceptance is what grows block tables)."""
        if self.cfg.family not in (Family.DENSE, Family.MOE):
            raise NotImplementedError(
                f"resident self-draft needs pure-KV per-layer state "
                f"(DENSE/MOE), not {self.cfg.family}")
        if self.k_res_cap == 0:
            raise ValueError(
                "resident self-draft needs a resident tier (plan has "
                "k_res == 0 on every stage)")
        if "draft" not in self._steps:
            self._steps["draft"] = self._build_step(1, resident_only=True)
        t = tokens.reshape(self.n_mb, self.mb, 1)
        logits, cache, glob, dbg = self._steps["draft"](
            state["resident"], state["shared"], state["cache"],
            state["glob"], t, self._stage_ids, self._kl_dev, self._win_dev,
            self._live_dev)
        new_state = dict(state)
        new_state["cache"] = cache
        new_state["glob"] = glob
        self.last_debug = dbg
        return logits.reshape(self.n_mb * self.mb, -1), new_state

    def draft_requests(self, state, tokens, active):
        """Slot-masked draft_step (serving entry): inactive slots ride as
        padding with zeroed tokens. Deliberately NO paged extend — drafted
        positions own no pages until verification commits them."""
        tr = get_tracer()
        if tr is not None:
            tr.instant(tr_ev.ENGINE_DRAFT, track=tr_ev.TRACK_ENGINE)
        active = jnp.asarray(active, bool)
        toks = jnp.where(active[:, None], tokens.astype(jnp.int32), 0)
        return self.draft_step(state, toks)

    def prefill_partial(self, state, tokens, *, chunk: int = 0):
        """Partial-context prefill through the interleaved pipeline
        (DESIGN.md §12): run `tokens` ((n_mb*mb, T) prompt positions
        starting at the state's current pos — 0 for a cold prompt, the
        cached span for a prefix hit) as ceil(T/chunk) multi-query rounds
        of the verify step, each one pipeline traversal (one
        weight-stream) scoring `chunk` positions. No separate prefill
        program on replicated params is needed — the pipeline itself
        builds the cache. Returns (last round's logits (n_mb*mb, q, PV),
        state) with pos advanced by T; the final position's row seeds the
        first sampled token."""
        if self.cfg.family not in (Family.DENSE, Family.MOE):
            raise NotImplementedError(
                "partial-context prefill rides the multi-query verify "
                "step (pure-KV families only)")
        tokens = jnp.asarray(tokens, jnp.int32)
        T = int(tokens.shape[1])
        chunk = T if chunk <= 0 else min(chunk, T)
        assert chunk < max(self.S_c, 2), (chunk, self.S_c)
        tr = get_tracer()
        logits = None
        for off in range(0, T, chunk):
            if tr is not None:
                tr.instant(tr_ev.ENGINE_PREFILL, track=tr_ev.TRACK_ENGINE,
                           args={"offset": off,
                                 "chunk": min(chunk, T - off)})
            logits, state = self.verify_step(state,
                                             tokens[:, off:off + chunk])
        if self.paged:
            # slot tables rebuilt at the prefilled span (the serving
            # layer's page-granular occupancy view; release-then-extend
            # so a later epoch's shorter prompt doesn't try to shrink)
            pos = int(jax.device_get(state["glob"]["pos"]))
            self._paged_pos = pos
            self._paged_seed_slots(pos)
        return logits, state

    def rollback(self, state, pos: int):
        """Reset the decode position to `pos` (commit an accepted prefix
        of a verify round, rejecting the suffix). Purely a pos reset:
        rejected positions' cache entries hold pos_ids > pos, so they
        are invisible to attention and overwritten when decode reaches
        their position again."""
        new_state = dict(state)
        glob = dict(state["glob"])
        glob["pos"] = jnp.asarray(pos, glob["pos"].dtype)
        new_state["glob"] = glob
        return new_state

    def note_committed(self, pos: int, active) -> None:
        """Paged bookkeeping after a spec round: live slots grow to the
        committed context (several tokens per round, unlike the +1 of
        decode_requests); rejected-candidate pages were never allocated
        — the engine's dense per-slot cache only accounts committed
        tokens."""
        if not self.paged:
            return
        self._paged_pos = pos
        for slot, live in enumerate(np.asarray(active, bool)):
            if live:
                self.extend_slot(slot, pos)

    # -- online memory adaptation (DESIGN.md §13) --------------------------------
    def demoted(self, stage: int) -> int:
        """Resident slots of `stage` currently demoted into the streamed
        tier."""
        return self.k_res_b[stage] - self.k_res_live[stage]

    def demote_capacity(self, stage: int) -> int:
        """How many more resident slots `stage` can demote (bounded by its
        build-time residents and the streamed-store headroom)."""
        return min(self.k_res_b[stage], self.H) - self.demoted(stage)

    def slot_hbm_bytes(self) -> float:
        """HBM one demoted resident slot returns: the slot holds one layer
        per segment, and the streamed tier keeps a one-layer load buffer —
        Eq. 7's (#Seg − 1) factor (n_seg == 1 degenerates to the single
        copy)."""
        return max(self.plan.n_seg - 1, 1) * self.cfg.layer_params() * 2.0

    def resident_layer_ids(self) -> List[int]:
        """Flat ids of real model layers currently in the resident tier
        (the live boundary: demoted layers are excluded)."""
        ids = np.unique(self._res_ids[self._res_ids < self.cfg.n_layers])
        return [int(i) for i in ids]

    def resident_fraction(self) -> float:
        """Live resident share of the real layer stack — the draft-quality
        signal the depth controller's rung priors scale with."""
        return len(self.resident_layer_ids()) / max(self.cfg.n_layers, 1)

    def retier_stats(self) -> Dict[str, Any]:
        return {"k_res_build": list(self.k_res_b),
                "k_res_live": list(self.k_res_live),
                "demoted": [self.demoted(d)
                            for d in range(self.plan.n_stage)]}

    def retier(self, state, stage: int, delta: int):
        """Move `delta` resident layer slots of `stage` across the tier
        boundary on the LIVE pipeline (positive: demote resident ->
        streamed, negative: promote back). No recompilation: the compiled
        step's shapes are fixed at the caps; the boundary is the dynamic
        `k_res_live` input, and demotions fill the streamed store's
        headroom right-to-left so layer execution order is preserved.

        Per unit move: the slot's weights are copied into (or back from)
        the streamed store, and its KV/state cache rows move to the slot
        the layer now occupies — so a mid-stream retier changes no emitted
        token (test_engine_hetero). The vacated HBM (slot_hbm_bytes() per
        demotion) is returned to the caller for crediting to the serving
        KV page pool; on the statically-shaped TPU mapping this is an
        accounting transfer, priced for real by the simulator.

        With state=None only the tier counters move (between serving
        epochs, before init_state materializes a state — init_state then
        builds the demoted layout directly).

        Returns (new_state, freed_bytes); freed_bytes < 0 on promotion.
        """
        if delta == 0:
            return state, 0.0
        assert self.H > 0 or delta < 0, \
            "retier needs retier_headroom > 0 at engine build"
        live = state is not None
        res = state["resident"] if live else None
        off = state["offload"] if live else None
        cache = dict(state["cache"]) if live else None
        kr_b = self.k_res_b[stage]
        freed = 0.0
        moves = 0
        for _ in range(abs(delta)):
            if delta > 0:
                if self.k_res_live[stage] <= 0 \
                        or self.demote_capacity(stage) <= 0:
                    break
                j = self.k_res_live[stage] - 1
                h = self.H - (kr_b - j)
                if live:
                    w_mv = jax.tree.map(lambda r: r[:, stage, j], res)
                    off = jax.tree.map(
                        lambda o, wv: o.at[:, stage, h]
                        .set(wv.astype(o.dtype)), off, w_mv)
                    cache = {kk: v.at[:, stage, self.k_res_cap + h]
                             .set(v[:, stage, j]) for kk, v in cache.items()}
                self.k_res_live[stage] = j
                freed += self.slot_hbm_bytes()
            else:
                if self.k_res_live[stage] >= kr_b:
                    break
                j = self.k_res_live[stage]
                h = self.H - (kr_b - j)
                if live:
                    w_mv = jax.tree.map(lambda o: o[:, stage, h], off)
                    res = jax.tree.map(
                        lambda r, wv: r.at[:, stage, j]
                        .set(wv.astype(r.dtype)), res, w_mv)
                    cache = {kk: v.at[:, stage, j]
                             .set(v[:, stage, self.k_res_cap + h])
                             for kk, v in cache.items()}
                self.k_res_live[stage] = j + 1
                freed -= self.slot_hbm_bytes()
            moves += 1
        if not moves:
            return state, 0.0
        self._refresh_tier_inputs()
        if not live:
            return None, freed
        sh = self.state_shardings()
        new_state = dict(state)
        new_state["resident"] = jax.device_put(res, sh["resident"])
        new_state["offload"] = jax.device_put(off, sh["offload"])
        new_state["cache"] = jax.device_put(cache, sh["cache"])
        return new_state, freed

    def lower_step(self):
        """For the dry-run: lower the full serve_step (restore + pipeline)
        without materializing state."""
        shapes = self._abstract_state()
        t = jax.ShapeDtypeStruct((self.n_mb, self.mb, 1), jnp.int32)
        sid = jax.ShapeDtypeStruct((self.plan.n_stage,), jnp.int32)
        kl = jax.ShapeDtypeStruct((self.plan.n_stage,), jnp.int32)
        win = jax.ShapeDtypeStruct(
            (self.plan.n_stage, self.plan.n_seg, self.K), jnp.int32)
        real = jax.ShapeDtypeStruct(
            (self.plan.n_stage, self.plan.n_seg, self.K), jnp.bool_)
        if self.fetch_mode == "step":
            def full(res, off, shared, cache, glob, tokens, stage_id,
                     kl_in, win_in, real_in):
                w = self._fetch(off)
                return self._step(res, w, shared, cache, glob, tokens,
                                  stage_id, kl_in, win_in, real_in)
            return jax.jit(full, donate_argnums=(3,)).lower(
                shapes["resident"], shapes["offload"], shapes["shared"],
                shapes["cache"], shapes["glob"], t, sid, kl, win, real)
        return self._step.lower(
            shapes["resident"], shapes["offload"], shapes["shared"],
            shapes["cache"], shapes["glob"], t, sid, kl, win, real)

    def _abstract_state(self):
        cfg, plan = self.cfg, self.plan
        specs = M.build_param_specs(cfg)
        sh = self.state_shardings()

        def res_shape(s):
            per = (plan.n_seg, plan.n_stage, self.k_res_cap) + s.shape[1:]
            return jax.ShapeDtypeStruct(per, s.dtype)

        def off_shape(s):
            return jax.ShapeDtypeStruct(
                (plan.n_seg, plan.n_stage, self.k_off_cap) + s.shape[1:],
                s.dtype)

        layer_shapes = pspec.shapes(specs["layers"])
        res = jax.tree.map(res_shape, layer_shapes,
                           is_leaf=lambda x: isinstance(
                               x, jax.ShapeDtypeStruct))
        off = jax.tree.map(off_shape, layer_shapes,
                           is_leaf=lambda x: isinstance(
                               x, jax.ShapeDtypeStruct))
        shared = pspec.shapes({k: v for k, v in specs.items()
                               if k != "layers"})
        cs = M.cache_specs(cfg, self.n_mb * self.mb, self.max_len,
                           self.long_mode, self.enc_len)
        cache = {}
        glob = {}
        for kk, v in cs.items():
            shp = v.shape
            if kk in PER_LAYER_CACHE_KEYS:
                per = (plan.n_seg, plan.n_stage, self.K, self.n_mb,
                       self.mb) + shp[2:]
                cache[kk] = jax.ShapeDtypeStruct(per, v.dtype)
            else:
                glob[kk] = jax.ShapeDtypeStruct(shp, v.dtype)

        def with_sh(tree, shtree):
            return jax.tree.map(
                lambda s, n: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                  sharding=n),
                tree, shtree,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        return {"resident": with_sh(res, sh["resident"]),
                "offload": with_sh(off, sh["offload"]),
                "shared": with_sh(shared, sh["shared"]),
                "cache": {kk: jax.ShapeDtypeStruct(
                    v.shape, v.dtype, sharding=sh["cache"][kk])
                    for kk, v in cache.items()},
                "glob": {kk: jax.ShapeDtypeStruct(v.shape, v.dtype)
                         for kk, v in glob.items()}}
