"""Version-tolerant JAX API surface (DESIGN.md §2).

The engine and the MoE layer are written against the *new* ``shard_map``
API (``jax.shard_map`` with ``axis_names`` / ``check_vma``, JAX >= 0.6).
Older JAX only ships ``jax.experimental.shard_map.shard_map`` with the
``auto`` / ``check_rep`` spelling — same semantics, inverted axis set:
``axis_names`` lists the MANUAL axes, ``auto`` lists everything else.

Import ``shard_map`` from here, never from jax directly, so the repo runs
unchanged on both sides of the rename.
"""
from __future__ import annotations

from typing import Optional, Set

try:                                     # JAX >= 0.6: public, new kwargs
    from jax import shard_map as _shard_map_new      # type: ignore
    _HAS_NEW = True
except ImportError:                      # JAX <= 0.5: experimental, old kwargs
    from jax.experimental.shard_map import shard_map as _shard_map_old
    _HAS_NEW = False

# Old XLA's SPMD partitioner fatally asserts (spmd_partitioner.cc
# IsManualSubgroup check) on ppermute / all_to_all issued inside a
# *partial*-auto shard_map region; psum survives. Callers that need a
# collective inside a partial-auto region must emulate it with psum when
# this is False (see engine._build_step's ring/fetch paths).
PARTIAL_AUTO_COLLECTIVES_OK = _HAS_NEW

# Same partitioner vintage rejects with_sharding_constraint inside a
# partial-auto region (the constraint's sharding spans the manual axes).
# When False, constraint-based pins (moe_forward mode="auto") are dropped:
# still correct — GSPMD just loses the hint that keeps expert weights
# sharded, so huge-MoE perf degrades on old JAX.
PARTIAL_AUTO_SHARDING_CONSTRAINT_OK = _HAS_NEW


def top_k(x, k: int):
    """jax.lax.top_k, usable inside partial-auto shard_map on old JAX.

    The old partitioner also dies on the sort custom-call top_k lowers to
    when it appears under a manual subgroup, so pre-0.6 we take k rounds of
    argmax + mask instead — identical values/indices ordering (descending,
    first occurrence wins ties), O(k·E) instead of O(E log E), and k is the
    MoE top_k (≤ 8) so the difference is noise.
    """
    import jax
    import jax.numpy as jnp
    if _HAS_NEW:
        return jax.lax.top_k(x, k)
    vals, idxs = [], []
    work = x
    pos = jnp.arange(x.shape[-1])
    for _ in range(k):
        i = jnp.argmax(work, axis=-1)
        vals.append(jnp.take_along_axis(x, i[..., None], -1)[..., 0])
        idxs.append(i)
        work = jnp.where(pos == i[..., None], -jnp.inf, work)
    return jnp.stack(vals, -1), jnp.stack(idxs, -1).astype(jnp.int32)


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Set[str]] = None, check_vma: bool = True):
    """New-style shard_map on any JAX.

    axis_names: mesh axes to run manually (None = all of them); the rest
    stay under GSPMD auto-sharding. check_vma maps to check_rep on old JAX.
    """
    if _HAS_NEW:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return _shard_map_new(f, **kwargs)
    manual = set(axis_names) if axis_names is not None \
        else set(mesh.axis_names)
    auto = frozenset(mesh.axis_names) - manual
    return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=bool(check_vma),
                          auto=auto)
