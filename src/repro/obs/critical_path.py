"""Critical-path attribution over flight-recorder events (DESIGN.md §17).

The tracer (§15) records *what overlapped*; this module answers *what the
time went to*. Every pipeline round (`step` span) is decomposed into
wall-clock buckets by partitioning the round window into atomic slices at
every span boundary and classifying each slice by priority:

  compute       some device is executing a stage (stage.compute)
  weight_stall  no device computes, but one waits on a weight fetch
                (weight.stall — the uncovered-load window, paper Eq. 3)
  act_hop       only activation hand-offs are in flight (act.hop)
  kv_migration  only KV movement spans are in flight (kv.*)
  bubble        nothing recorded — pipeline bubble / scheduling idle

Because the slices partition the window, the buckets sum to the measured
round time *by construction* — the conservation property tests and
bench_slo assert (within float rounding). A round's bottleneck device is
the one busy (compute + stall) the largest share of the window.

Requests decompose the same way: `req.queue` is the queue bucket, and the
service window (admit -> finish) is clipped against the classified
timeline, so one request's latency splits into queue / compute / stall /
hop / kv / bubble and sums to its measured latency.

Works live (Tracer.events()) and offline (exporters.read_jsonl), on
single-pipeline traces and on fleet traces (pass the replica namespace,
e.g. "r0", to attribute one replica's timeline).
"""
from __future__ import annotations

import dataclasses
from bisect import bisect_right
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs import trace as tr_ev
from repro.obs.trace import (EVT_DUR, EVT_NAME, EVT_PH, EVT_TRACK, EVT_TS,
                             Event)

# classification priority (first match wins on each atomic slice)
BUCKETS = ("compute", "weight_stall", "act_hop", "kv_migration", "bubble")

_SPAN_CLASS = {
    tr_ev.STAGE_COMPUTE: "compute",
    tr_ev.WEIGHT_STALL: "weight_stall",
    tr_ev.ACT_HOP: "act_hop",
}

Interval = Tuple[float, float]


# -- track helpers -----------------------------------------------------------
def split_track(track: str) -> Tuple[Optional[str], str]:
    """'r2:dev:3' -> ('r2', 'dev:3'); 'dev:3' -> (None, 'dev:3')."""
    ns, sep, rest = track.partition(":")
    if sep and rest and len(ns) > 1 and ns[0] == "r" and ns[1:].isdigit():
        return ns, rest
    return None, track


def namespaces(events: Sequence[Event]) -> List[Optional[str]]:
    """Distinct replica namespaces present (None = un-namespaced)."""
    seen = {split_track(e[EVT_TRACK])[0] for e in events}
    return sorted(seen, key=lambda x: (x is not None, x))


# -- interval algebra --------------------------------------------------------
def _merge(ivs: List[Interval]) -> List[Interval]:
    """Sorted union of possibly-overlapping intervals."""
    out: List[Interval] = []
    for a, b in sorted(ivs):
        if b <= a:
            continue
        if out and a <= out[-1][1]:
            if b > out[-1][1]:
                out[-1] = (out[-1][0], b)
        else:
            out.append((a, b))
    return out


def _clip_total(ivs: List[Interval], lo: float, hi: float) -> float:
    """Total length of (merged, sorted) `ivs` inside [lo, hi]."""
    total = 0.0
    for a, b in ivs:
        if b <= lo:
            continue
        if a >= hi:
            break
        total += min(b, hi) - max(a, lo)
    return total


def _covers(ivs: List[Interval], starts: List[float], a: float,
            b: float) -> bool:
    """Does some interval of merged `ivs` contain the atomic [a, b]?
    `starts` is the precomputed list of interval starts (bisect key)."""
    i = bisect_right(starts, a) - 1
    return i >= 0 and ivs[i][1] >= b


# -- report dataclasses ------------------------------------------------------
@dataclasses.dataclass
class RoundBreakdown:
    """One pipeline round, bucket-decomposed (buckets sum to dur)."""
    ts: float
    dur: float
    buckets: Dict[str, float]
    bottleneck: Optional[str]          # "dev:<i>" busiest this round
    dev_busy: Dict[str, float]         # per-device compute+stall seconds

    def to_dict(self) -> dict:
        return {"ts": self.ts, "dur": self.dur,
                "buckets": dict(self.buckets),
                "bottleneck": self.bottleneck,
                "dev_busy": dict(self.dev_busy)}


@dataclasses.dataclass
class RequestBreakdown:
    """One finished request, bucket-decomposed (queue + buckets = total)."""
    rid: int
    arrival_s: float
    queue_s: float
    prefill_s: float
    decode_s: float
    total_s: float
    buckets: Dict[str, float]          # service-window share per bucket

    def to_dict(self) -> dict:
        return {"rid": self.rid, "arrival_s": self.arrival_s,
                "queue_s": self.queue_s, "prefill_s": self.prefill_s,
                "decode_s": self.decode_s, "total_s": self.total_s,
                "buckets": dict(self.buckets)}


@dataclasses.dataclass
class CriticalPathReport:
    namespace: Optional[str]
    rounds: List[RoundBreakdown]
    requests: List[RequestBreakdown]
    totals: Dict[str, float]           # bucket seconds over all rounds
    bottlenecks: Dict[str, int]        # device -> rounds it dominated

    @property
    def round_time_s(self) -> float:
        return sum(r.dur for r in self.rounds)

    @property
    def fractions(self) -> Dict[str, float]:
        t = self.round_time_s
        return {k: (v / t if t > 0 else 0.0) for k, v in self.totals.items()}

    def conservation_error(self) -> float:
        """max over rounds of |sum(buckets) - dur| / dur — ~0 by
        construction; bench_slo enforces < 1%."""
        worst = 0.0
        for r in self.rounds:
            if r.dur <= 0:
                continue
            err = abs(sum(r.buckets.values()) - r.dur) / r.dur
            worst = max(worst, err)
        return worst

    def to_dict(self) -> dict:
        return {"namespace": self.namespace,
                "n_rounds": len(self.rounds),
                "round_time_s": self.round_time_s,
                "totals": dict(self.totals),
                "fractions": self.fractions,
                "bottlenecks": dict(self.bottlenecks),
                "conservation_error": self.conservation_error(),
                "requests": [r.to_dict() for r in self.requests]}

    # -- text rendering -----------------------------------------------------------
    def render(self, *, max_requests: int = 12, width: int = 40) -> str:
        lines = [f"critical path: {len(self.rounds)} rounds, "
                 f"{self.round_time_s:.3f}s on the pipeline"
                 + (f" [{self.namespace}]" if self.namespace else "")]
        fr = self.fractions
        for k in BUCKETS:
            lines.append(f"  {k:<13} {self.totals.get(k, 0.0):>9.3f}s "
                         f"{100.0 * fr.get(k, 0.0):5.1f}%")
        if self.bottlenecks:
            top = sorted(self.bottlenecks.items(),
                         key=lambda kv: -kv[1])
            lines.append("  bottleneck: " + "  ".join(
                f"{d} x{n}" for d, n in top[:4]))
        if self.requests:
            lines.append(render_waterfall(self.requests,
                                          max_requests=max_requests,
                                          width=width))
        return "\n".join(lines)


def render_waterfall(requests: List[RequestBreakdown], *,
                     max_requests: int = 12, width: int = 40) -> str:
    """Per-request latency waterfall: queue '.', prefill '=', decode '#',
    one scaled lane per request, slowest requests first."""
    if not requests:
        return "  (no finished requests in trace)"
    show = sorted(requests, key=lambda r: -r.total_s)[:max_requests]
    t_max = max(r.total_s for r in show)
    scale = width / t_max if t_max > 0 else 0.0
    lines = [f"  slowest {len(show)}/{len(requests)} requests "
             f"(. queue  = prefill  # decode):"]
    for r in show:
        nq = int(round(r.queue_s * scale))
        np_ = int(round(r.prefill_s * scale))
        nd = max(int(round(r.decode_s * scale)), 1)
        bar = "." * nq + "=" * np_ + "#" * nd
        lines.append(f"  req {r.rid:>5} |{bar:<{width}}| "
                     f"q {r.queue_s:.3f}s p {r.prefill_s:.3f}s "
                     f"d {r.decode_s:.3f}s = {r.total_s:.3f}s")
    return "\n".join(lines)


# -- attribution -------------------------------------------------------------
def _collect(events: Sequence[Event], namespace: Optional[str]):
    """Split one namespace's events into classified span-interval pools,
    step windows, per-device busy intervals, and request phase spans."""
    class_iv: Dict[str, List[Interval]] = {
        "compute": [], "weight_stall": [], "act_hop": [],
        "kv_migration": []}
    steps: List[Tuple[float, float]] = []
    dev_iv: Dict[str, List[Interval]] = {}
    req_phase: Dict[int, Dict[str, Tuple[float, float]]] = {}
    for e in events:
        ns, base = split_track(e[EVT_TRACK])
        if ns != namespace or e[EVT_PH] != "X":
            continue
        name, ts, dur = e[EVT_NAME], e[EVT_TS], e[EVT_DUR]
        if name == tr_ev.STEP and base == tr_ev.TRACK_PIPELINE:
            steps.append((ts, ts + dur))
            continue
        cls = _SPAN_CLASS.get(name)
        if cls is None and name.startswith("kv."):
            cls = "kv_migration"
        if cls is not None and dur > 0:
            class_iv[cls].append((ts, ts + dur))
            if cls in ("compute", "weight_stall") \
                    and base.startswith("dev:"):
                dev = base.split(":")[0] + ":" + base.split(":")[1]
                dev_iv.setdefault(dev, []).append((ts, ts + dur))
            continue
        if base.startswith("req:") and name in (
                tr_ev.REQ_QUEUE, tr_ev.REQ_PREFILL, tr_ev.REQ_DECODE,
                tr_ev.REQ_SPAN):
            rid = int(base.split(":", 1)[1])
            req_phase.setdefault(rid, {})[name] = (ts, dur)
    return class_iv, steps, dev_iv, req_phase


def _classified_timeline(class_iv: Dict[str, List[Interval]]
                         ) -> Dict[str, List[Interval]]:
    """Priority-resolve overlapping class intervals into disjoint,
    merged per-class interval lists (compute wins, then stall, ...)."""
    merged = {k: _merge(v) for k, v in class_iv.items()}
    starts = {k: [a for a, _ in v] for k, v in merged.items()}
    pts = sorted({p for ivs in merged.values() for ab in ivs for p in ab})
    out: Dict[str, List[Interval]] = {k: [] for k in merged}
    for a, b in zip(pts, pts[1:]):
        if b <= a:
            continue
        for cls in ("compute", "weight_stall", "act_hop", "kv_migration"):
            if _covers(merged[cls], starts[cls], a, b):
                out[cls].append((a, b))
                break
    return {k: _merge(v) for k, v in out.items()}


def analyze(events: Sequence[Event], *,
            namespace: Optional[str] = None) -> CriticalPathReport:
    """Attribute one namespace's timeline. Events may come straight from
    Tracer.events() (live) or exporters.read_jsonl (offline)."""
    class_iv, steps, dev_iv, req_phase = _collect(events, namespace)
    timeline = _classified_timeline(class_iv)
    dev_merged = {d: _merge(v) for d, v in dev_iv.items()}

    rounds: List[RoundBreakdown] = []
    totals = {k: 0.0 for k in BUCKETS}
    bottlenecks: Dict[str, int] = {}
    for t0, t1 in sorted(steps):
        if t1 <= t0:
            continue
        buckets = {k: _clip_total(timeline[k], t0, t1)
                   for k in timeline}
        classified = sum(buckets.values())
        buckets["bubble"] = max((t1 - t0) - classified, 0.0)
        busy = {d: _clip_total(v, t0, t1) for d, v in dev_merged.items()}
        busy = {d: s for d, s in busy.items() if s > 0}
        bott = max(busy, key=lambda d: busy[d]) if busy else None
        if bott is not None:
            bottlenecks[bott] = bottlenecks.get(bott, 0) + 1
        rounds.append(RoundBreakdown(ts=t0, dur=t1 - t0, buckets=buckets,
                                     bottleneck=bott, dev_busy=busy))
        for k, v in buckets.items():
            totals[k] += v

    requests: List[RequestBreakdown] = []
    for rid, phases in sorted(req_phase.items()):
        span = phases.get(tr_ev.REQ_SPAN)
        if span is None:
            continue
        arr, total = span
        q_ts, q_dur = phases.get(tr_ev.REQ_QUEUE, (arr, 0.0))
        p_dur = phases.get(tr_ev.REQ_PREFILL, (0.0, 0.0))[1]
        d_dur = phases.get(tr_ev.REQ_DECODE, (0.0, 0.0))[1]
        svc_lo, svc_hi = q_ts + q_dur, arr + total
        buckets = {k: _clip_total(timeline[k], svc_lo, svc_hi)
                   for k in timeline}
        svc = max(svc_hi - svc_lo, 0.0)
        buckets["bubble"] = max(svc - sum(buckets.values()), 0.0)
        requests.append(RequestBreakdown(
            rid=rid, arrival_s=arr, queue_s=q_dur, prefill_s=p_dur,
            decode_s=d_dur, total_s=total, buckets=buckets))

    return CriticalPathReport(namespace=namespace, rounds=rounds,
                              requests=requests, totals=totals,
                              bottlenecks=bottlenecks)


def analyze_all(events: Sequence[Event]) -> Dict[Optional[str],
                                                 CriticalPathReport]:
    """One report per namespace present (fleet traces: one per replica)."""
    return {ns: analyze(events, namespace=ns)
            for ns in namespaces(events)}


def analyze_jsonl(path: str, *,
                  namespace: Optional[str] = None) -> CriticalPathReport:
    """Offline entry point: attribute an exported JSONL trace."""
    from repro.obs.exporters import read_jsonl
    _, events = read_jsonl(path)
    return analyze(events, namespace=namespace)
