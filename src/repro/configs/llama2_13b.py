"""Llama2-13B-Instruct — paper Tab. III row 1 (MHA: kv=40)."""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="llama2-13b", family=Family.DENSE,
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=13824, vocab_size=32000, head_dim=128,
    attn_kind=AttnKind.FULL,
    source="LIME paper Tab. III / Llama2 [arXiv:2307.09288]",
)
