"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (1-device) CPU; distributed engine tests re-exec themselves in
a subprocess with a forced device count (see test_engine.py)."""
import importlib.util
import pathlib
import sys

try:
    import hypothesis                                    # noqa: F401
except ModuleNotFoundError:
    # dev extra not installed: register the deterministic stub under the
    # real name so `from hypothesis import given, ...` keeps working
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).parent / "_hypothesis_stub.py")
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod

import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def assert_finite(x, msg=""):
    assert bool(jnp.isfinite(jnp.asarray(x, jnp.float32)).all()), msg
