"""Token sampling (shared by every serving backend)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0        # 0 => greedy
    top_k: int = 0                  # 0 => full softmax
    seed: int = 0


def sample(logits, cfg: SamplerConfig, key, real_vocab: int):
    """logits: (B, PV) -> (B,) int32."""
    lv = logits[:, :real_vocab]
    if cfg.temperature <= 0.0:
        return jnp.argmax(lv, axis=-1).astype(jnp.int32)
    lv = lv / cfg.temperature
    if cfg.top_k:
        vals, idx = jax.lax.top_k(lv, cfg.top_k)
        choice = jax.random.categorical(key, vals)
        return jnp.take_along_axis(idx, choice[:, None], 1)[:, 0] \
            .astype(jnp.int32)
    return jax.random.categorical(key, lv).astype(jnp.int32)
