"""Jit'd public wrapper for the selective-scan kernel.

Model layout in (`repro.models.ssm.ssm_scan_ref`): xh (B, S, H, dh),
dt (B, S, H), B_in/C_in (B, S, N), A (H,), state (B, H, N, dh) fp32.
Pads time with dt = 0 (identity steps) and dh to the lane width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.ssm_scan.kernel import ssm_scan_kernel


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def ssm_scan(xh, dt, B_in, C_in, A, state, *, block_t=None,
             interpret=None):
    """Returns (y (B, S, H, dh) fp32, new_state (B, H, N, dh) fp32).
    block_t=None consults the tuned table (repro.kernels.tuning); 256
    with none installed."""
    if interpret is None:
        interpret = _auto_interpret()
    B, S, H, dh = xh.shape
    N = B_in.shape[-1]
    block_t = tuning.resolve("ssm_scan", S, dh, "block_t", block_t)
    bt = min(block_t, max(S, 8))
    pad_t = (-S) % bt
    pad_d = (-dh) % 128 if not interpret else 0

    x = jnp.moveaxis(xh.astype(jnp.float32), 1, 2)       # (B, H, S, dh)
    d = jnp.moveaxis(dt.astype(jnp.float32), 1, 2)[..., None]  # (B,H,S,1)
    if pad_t or pad_d:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_t), (0, pad_d)))
        d = jnp.pad(d, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
    bmat = jnp.pad(B_in.astype(jnp.float32), ((0, 0), (0, pad_t), (0, 0)))
    cmat = jnp.pad(C_in.astype(jnp.float32), ((0, 0), (0, pad_t), (0, 0)))
    a = A.astype(jnp.float32).reshape(H, 1)
    s = jnp.pad(state, ((0, 0), (0, 0), (0, 0), (0, pad_d))) if pad_d \
        else state

    y, sT = ssm_scan_kernel(x, d, bmat, cmat, a, s, block_t=bt,
                            interpret=interpret)
    y = jnp.moveaxis(y[:, :, :S, :dh], 1, 2)             # (B, S, H, dh)
    return y, sT[..., :dh]
