"""Page-granular KV admission: the scheduler-facing facade (DESIGN.md §10).

Worst-case reservation admits a request only if `prompt + max_new` tokens
fit the budget for its whole lifetime. Page-granular admission allocates
ceil((prompt+1)/page_size) pages up front and one page per `page_size`
generated tokens after that, so co-residency is bounded by *actual*
occupancy — the 3.7× bursty-concurrency regime the paper targets. The
price is that the pool can run dry mid-generation; the manager exposes the
two standard outs:

  spill      preempt a victim by migrating its whole table to the host
             tier (kept warm; resume = fetch back, priced in bytes)
  recompute  drop the victim's pages entirely; resume re-prefills
             prompt + generated-so-far (priced in compute by the backend)

Victim choice is the caller's policy (the scheduler preempts the
latest-admitted request, vLLM-style); the manager keeps the bookkeeping
honest: a request is either resident (all pages DEVICE), suspended (its
solely-owned pages HOST — pages shared with the radix tree or a
co-resident COW fork stay DEVICE, see PagePool.spill_table — or none),
or released.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.kvcache.allocator import BlockTable, OutOfPages
from repro.kvcache.pool import DEVICE, HOST, PagePool

SPILL = "spill"
RECOMPUTE = "recompute"


class PagedKVManager:
    def __init__(self, pool: PagePool):
        self.pool = pool
        self._tables: Dict[int, BlockTable] = {}
        self._suspended: Dict[int, bool] = {}

    # -- introspection -----------------------------------------------------------
    @property
    def page_size(self) -> int:
        return self.pool.page_size

    def table(self, rid: int) -> BlockTable:
        return self._tables[rid]

    def tokens_of(self, rid: int) -> int:
        return self._tables[rid].tokens

    def pages_of(self, rid: int) -> int:
        return len(self._tables[rid].pages)

    def device_pages_in_use(self) -> int:
        return self.pool.pages_in_use(DEVICE)

    def is_suspended(self, rid: int) -> bool:
        return self._suspended.get(rid, False)

    # -- admission ---------------------------------------------------------------
    def can_admit(self, n_tokens: int, headroom_pages: int = 0) -> bool:
        """`headroom_pages`: free device pages that must remain *after*
        the allocation (admission watermark — each already-resident
        request will want another page within page_size steps, so
        admitting into the last free pages guarantees preemption churn)."""
        need = self.pool.pages_for(n_tokens) + max(headroom_pages, 0)
        return self.pool.can_alloc(need, DEVICE)

    def admit(self, rid: int, n_tokens: int) -> bool:
        """Allocate a fresh table holding `n_tokens` (prompt + first token).
        False (and no side effects) when the device tier can't hold it."""
        if rid in self._tables:
            raise ValueError(f"request {rid} already admitted")
        if not self.can_admit(n_tokens):
            return False
        t = BlockTable(self.pool.page_size)
        self.pool.extend_table(t, n_tokens, DEVICE)
        self._tables[rid] = t
        self._suspended[rid] = False
        return True

    # -- prefix-cache admission (DESIGN.md §12) ----------------------------------
    def can_admit_prefix(self, n_tokens: int, prefix_pages: List[int],
                         headroom_pages: int = 0) -> bool:
        """Admission check for a radix prefix hit: only the *uncached
        suffix* needs fresh device pages, plus one device slot for every
        matched page currently delegated to the host tier (the hit fetches
        them back before decode attends them)."""
        new = self.pool.pages_for(n_tokens) - len(prefix_pages)
        host = sum(1 for p in prefix_pages
                   if self.pool.tier_of(p) == HOST)
        need = max(new, 0) + host + max(headroom_pages, 0)
        return self.pool.free_pages(DEVICE) >= need \
            and self.pool.alloc.can_alloc(max(new, 0))

    def admit_with_prefix(self, rid: int, prefix_pages: List[int],
                          prefix_tokens: int, n_tokens: int) -> float:
        """Admit `rid` copy-on-write over a matched radix prefix: the
        shared pages are increfed into a fresh table (never written — the
        match is page-aligned and capped below the prompt end, so growth
        only allocates new pages), host-resident shared pages are fetched
        back to the device tier, and the uncached suffix is allocated
        fresh. Returns bytes fetched (the spill-priced part of a hit)."""
        if rid in self._tables:
            raise ValueError(f"request {rid} already admitted")
        t = BlockTable(self.pool.page_size)
        for pid in prefix_pages:
            self.pool.incref_page(pid)
        t.pages = list(prefix_pages)
        t.tokens = prefix_tokens
        try:
            moved = self.pool.migrate(prefix_pages, DEVICE)
            self.pool.extend_table(t, n_tokens, DEVICE)
        except OutOfPages:              # caller raced can_admit_prefix
            for pid in t.pages:
                self.pool.decref_page(pid)
            raise
        self._tables[rid] = t
        self._suspended[rid] = False
        return moved

    def extend(self, rid: int, n_tokens: Optional[int] = None) -> bool:
        """Grow `rid` to `n_tokens` (default: +1 token). False on a dry
        pool — the caller preempts someone and retries."""
        t = self._tables[rid]
        target = t.tokens + 1 if n_tokens is None else n_tokens
        try:
            self.pool.extend_table(t, target, DEVICE)
            return True
        except OutOfPages:
            return False

    def truncate(self, rid: int, n_tokens: int) -> int:
        """Roll back `rid` to `n_tokens` (speculative decoding rejected a
        drafted suffix — DESIGN.md §11); frees the pages past the kept
        prefix. Returns pages dropped."""
        return self.pool.truncate_table(self._tables[rid], n_tokens)

    def release(self, rid: int) -> None:
        t = self._tables.pop(rid)
        self._suspended.pop(rid, None)
        self.pool.release_table(t)

    # -- preemption / resumption -------------------------------------------------
    def preempt(self, rid: int, mode: str = SPILL) -> float:
        """Suspend `rid`; returns bytes moved (0 for recompute — its cost
        is compute, charged by the backend at resume). A spill that finds
        the host tier full (e.g. Eq. 8 delegation occupying it) degrades
        to recompute — the victim's pages are dropped, not leaked; callers
        detect the fallback via an empty table (pages == [])."""
        t = self._tables[rid]
        self._suspended[rid] = True
        if mode == SPILL:
            try:
                return self.pool.spill_table(t)
            except OutOfPages:
                mode = RECOMPUTE
        if mode != RECOMPUTE:
            raise ValueError(f"unknown preemption mode {mode!r}")
        tokens = t.tokens
        self.pool.release_table(t)
        t.tokens = tokens               # remember how much to re-prefill
        return 0.0

    def can_resume(self, rid: int, headroom_pages: int = 0) -> bool:
        t = self._tables[rid]
        if t.pages:                     # spilled: fetch back
            need = len(t.pages) - self.pool.device_pages_of(t) \
                + max(headroom_pages, 0)
            return self.pool.free_pages(DEVICE) >= need
        # recompute: fresh allocation
        return self.can_admit(t.tokens, headroom_pages)

    def resume(self, rid: int) -> Optional[float]:
        """Back to resident; returns bytes fetched (0.0 for recompute
        re-allocation) or None when the device tier still can't hold it."""
        t = self._tables[rid]
        if not self.can_resume(rid):
            return None
        self._suspended[rid] = False
        if t.pages:
            return self.pool.fetch_table(t)
        tokens, t.tokens = t.tokens, 0
        self.pool.extend_table(t, tokens, DEVICE)
        return 0.0

    # -- Eq. 8 mapping: token volumes -> page migrations -------------------------
    def delegate_tail(self, rid: int, n_tokens: int) -> float:
        """Migrate the pages backing `rid`'s trailing `n_tokens` to the
        host tier — the paper's KV-transfer volume (Eq. 8) expressed as
        actual page movement. Partial pages round *down* (a page migrates
        only when every slot in it is delegated); returns bytes moved."""
        t = self._tables[rid]
        n_pages = min(n_tokens // self.pool.page_size, len(t.pages))
        if n_pages <= 0:
            return 0.0
        return self.pool.migrate(t.pages[-n_pages:], HOST)

    def resident_tokens(self, rid: int) -> int:
        """Tokens whose pages are on-device (delegated tail excluded)."""
        t = self._tables[rid]
        if not t.pages:
            return 0
        dev = self.pool.device_pages_of(t)
        if dev == len(t.pages):
            return t.tokens
        return min(dev * self.pool.page_size, t.tokens)

    def active_requests(self) -> List[int]:
        return [rid for rid, s in self._suspended.items() if not s]
