"""LIME core: the paper's contribution (DESIGN.md §1-2).

Reproduction (simulator): cost_model, offline_scheduler, online_planner,
kv_transfer, pipeline_sim, baselines.
TPU runtime: engine (interleaved pipeline under shard_map).
"""
from repro.core.cost_model import (CostEnv, Workload, ExecutionPlan,  # noqa: F401
                                   StageAlloc, Plan, DeviceAlloc)
from repro.core.offline_scheduler import allocate, ScheduleResult  # noqa: F401
from repro.core.online_planner import OnlinePlanner  # noqa: F401
from repro.core.kv_transfer import KVTransferProtocol  # noqa: F401
from repro.core.pipeline_sim import InterleavedPipelineSim, simulate_lime, SimResult  # noqa: F401
from repro.core.engine import InterleavedEngine, UniformPlan  # noqa: F401
