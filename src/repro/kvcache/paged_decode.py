"""Single-device decode over a paged KV cache (DESIGN.md §10).

The dense decode path (models/model.decode_step) owns a contiguous
(L, B, S_c, KV, dh) cache per batch. This module is the same decode with
the cache paged: K/V live in a shared physical pool (L, P, page_size, KV,
dh), each sequence names its pages through a block table, and attention
gathers through the table (kernels/decode_attention/paged.py). Pages are
allocated from a PagePool as generation crosses page boundaries and
released when the sequence completes — the engine-tier half of the
losslessness contract: paged decode must equal decode_step (test_kvcache
asserts logits parity).

Supported families: standard-attention stacks (DENSE incl. parallel-block
and local:global/sliding windows). SSM/MoE/hybrid state is not paged —
their recurrent state is O(1) per sequence, there is nothing to page.

Host/device split: BlockTable + PagePool bookkeeping is host-side python
(one int per page); the jitted step consumes a device copy of the tables.
`PagedDecodeCache.step` bridges the two — extend tables for the incoming
token, then run the compiled step.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import Family, ModelConfig
from repro.kvcache.allocator import BlockTable
from repro.kvcache.pool import PagedKVConfig, PagePool
from repro.models import model as M
from repro.models.attention import paged_attn_decode, paged_attn_decode_multi


def _check_family(cfg: ModelConfig) -> None:
    if cfg.family != Family.DENSE:
        raise NotImplementedError(
            f"paged decode supports standard-attention stacks, not "
            f"{cfg.family} (recurrent state is O(1)/sequence — nothing to "
            f"page)")


@functools.partial(jax.jit, static_argnames=("cfg", "impl"),
                   donate_argnums=(2, 3))
def _paged_decode_step(cfg: ModelConfig, params, k_pool, v_pool,
                       block_tables, pos, token, impl: str = "ref"):
    """One token for the whole batch. k/v_pool: (L, P, ps, KV, dh);
    block_tables: (B, max_pages); pos: scalar int32 (shared — prompts are
    left-padded, the decode_step convention); token: (B, 1) int32.
    Returns (logits (B, 1, PV), k_pool, v_pool)."""
    B = token.shape[0]
    ps = k_pool.shape[2]
    x = M.embed(params, token).astype(jnp.bfloat16)

    page_idx = pos // ps
    slot = pos % ps
    page_ids = jnp.take(block_tables, page_idx, axis=1)       # (B,)
    ctx = jnp.full((B,), pos + 1, jnp.int32)

    def body(carry, xs):
        x, = carry
        p = xs["p"]
        xn = M.rms_norm(x, p["ln1"], cfg.norm_eps)
        a_out, ck, cv = paged_attn_decode(
            p["attn"], xn, xs["k"], xs["v"], page_ids, slot, block_tables,
            ctx, pos, rope_theta=cfg.rope_theta, window=xs["window"],
            impl=impl)
        if cfg.parallel_block:
            x = x + a_out + M.mlp(p["mlp"], xn)
        else:
            x = x + a_out
            x = x + M.mlp(p["mlp"], M.rms_norm(x, p["ln2"], cfg.norm_eps))
        return (x,), {"k": ck, "v": cv}

    xs = {"p": params["layers"],
          "window": M.layer_windows(cfg, cfg.n_layers),
          "k": k_pool, "v": v_pool}
    (x,), ys = jax.lax.scan(body, (x,), xs)
    x = M.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return M.unembed(params, x), ys["k"], ys["v"]


@functools.partial(jax.jit, static_argnames=("cfg", "impl"),
                   donate_argnums=(2, 3))
def _paged_verify_step(cfg: ModelConfig, params, k_pool, v_pool,
                       block_tables, page_ids, slots, pos, token,
                       impl: str = "ref"):
    """q_len tokens scored in one stack traversal (speculative-decoding
    verification, DESIGN.md §11). k/v_pool: (L, P, ps, KV, dh);
    page_ids: (B, q_len) physical page per new token; slots: (q_len,)
    offsets inside those pages; pos: scalar int32 position of token 0;
    token: (B, q_len) int32. Returns (logits (B, q_len, PV), k_pool,
    v_pool) with all q_len K/V written — rollback is the caller
    truncating tables and resetting pos (garbage left in rejected slots
    is masked by ctx and overwritten when decode reaches them)."""
    B, Q = token.shape
    x = M.embed(params, token).astype(jnp.bfloat16)
    ctx = jnp.full((B,), pos + Q, jnp.int32)

    def body(carry, xs):
        x, = carry
        p = xs["p"]
        xn = M.rms_norm(x, p["ln1"], cfg.norm_eps)
        a_out, ck, cv = paged_attn_decode_multi(
            p["attn"], xn, xs["k"], xs["v"], page_ids, slots, block_tables,
            ctx, pos, rope_theta=cfg.rope_theta, window=xs["window"],
            impl=impl)
        if cfg.parallel_block:
            x = x + a_out + M.mlp(p["mlp"], xn)
        else:
            x = x + a_out
            x = x + M.mlp(p["mlp"], M.rms_norm(x, p["ln2"], cfg.norm_eps))
        return (x,), {"k": ck, "v": cv}

    xs = {"p": params["layers"],
          "window": M.layer_windows(cfg, cfg.n_layers),
          "k": k_pool, "v": v_pool}
    (x,), ys = jax.lax.scan(body, (x,), xs)
    x = M.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return M.unembed(params, x), ys["k"], ys["v"]


class PagedDecodeCache:
    """Owns the pools + tables for one decode batch."""

    def __init__(self, cfg: ModelConfig, batch: int, max_len: int, *,
                 page_size: int = 64, pool: Optional[PagePool] = None,
                 impl: str = "ref"):
        _check_family(cfg)
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.impl = impl
        self.max_pages = -(-max_len // page_size)
        if pool is None:
            pool = PagePool(PagedKVConfig(
                page_size=page_size,
                device_pages=batch * self.max_pages))
        assert pool.page_size == page_size
        self.pool = pool
        self.tables: List[BlockTable] = [BlockTable(page_size)
                                         for _ in range(batch)]
        P = pool.alloc.n_pages
        shp = (cfg.n_layers, P, page_size, cfg.n_kv_heads, cfg.head_dim)
        self.k_pool = jnp.zeros(shp, jnp.bfloat16)
        self.v_pool = jnp.zeros(shp, jnp.bfloat16)
        self.pos = 0
        self._bt_dev = None

    # -- table <-> device bridge -------------------------------------------------
    def _device_tables(self):
        if self._bt_dev is None:
            bt = np.full((self.batch, self.max_pages), -1, np.int32)
            for b, t in enumerate(self.tables):
                bt[b, :len(t.pages)] = t.pages
            self._bt_dev = jnp.asarray(bt)
        return self._bt_dev

    def _extend_all(self, n_tokens: int) -> None:
        for t in self.tables:
            if self.pool.extend_table(t, n_tokens):
                self._bt_dev = None      # table grew: refresh device copy

    # -- epoch lifecycle over a persistent pool (DESIGN.md §12) ------------------
    def reset_tables(self) -> None:
        """Release this batch's tables (radix-shared pages survive their
        increfs) and rewind `pos` — the pool and its K/V bytes persist, so
        a prefix cache built over it carries state across epochs."""
        for i, t in enumerate(self.tables):
            self.pool.release_table(t)
            self.tables[i] = BlockTable(self.pool.page_size)
        self.pos = 0
        self._bt_dev = None

    def adopt_tables(self, tables: List[BlockTable], pos: int) -> None:
        """Take ownership of externally-built tables (radix prefix forks:
        shared full pages up front, `pos` tokens committed). The caller
        has already increfed the shared pages into them."""
        assert len(tables) == self.batch, (len(tables), self.batch)
        self.tables = tables
        self.pos = pos
        self._bt_dev = None

    # -- seeding from a dense prefill cache --------------------------------------
    def seed(self, cache: Dict) -> None:
        """Adopt a model-layout cache (M.prefill output): scatter its K/V
        through freshly allocated block tables into the pools. Scatters
        into the *live* pool buffers — pages owned by a radix prefix
        cache keep their bytes across epoch re-seeds."""
        from repro.kvcache.layout import scatter_to_pages
        pos = int(cache["pos"])
        self._extend_all(pos)
        # np.array (not asarray): a same-dtype jax array converts to a
        # read-only zero-copy view — scatter needs a writable host copy
        kp = scatter_to_pages(np.array(self.k_pool, np.float32),
                              np.asarray(cache["k"][:, :self.batch],
                                         np.float32), self.tables, pos)
        vp = scatter_to_pages(np.array(self.v_pool, np.float32),
                              np.asarray(cache["v"][:, :self.batch],
                                         np.float32), self.tables, pos)
        self.k_pool = jnp.asarray(kp, self.k_pool.dtype)
        self.v_pool = jnp.asarray(vp, self.v_pool.dtype)
        self.pos = pos

    # -- suffix / chunked prefill (DESIGN.md §12) --------------------------------
    def prefill(self, params, tokens, *, chunk: int = 0):
        """Process `tokens` (B, T) — the prompt, or just its uncached
        suffix when the tables already hold a radix-matched prefix at
        `pos` — through ceil(T/chunk) multi-query rounds (`chunk` 0 =
        monolithic). Each round is the speculative verify pass scoring
        chunk query positions and writing their K/V through the block
        tables, so chunked output is bitwise-equal to monolithic: every
        query row sees exactly the same pages, masks and block walk
        either way. Returns the final position's logits (B, PV) — the
        distribution the first sampled token draws from."""
        tokens = np.asarray(tokens, np.int32)
        T = tokens.shape[1]
        if T == 0:
            raise ValueError("prefill needs at least one uncached token "
                             "(the match cap guarantees it)")
        chunk = T if chunk <= 0 else min(chunk, T)
        last = None
        for off in range(0, T, chunk):
            q = tokens[:, off:off + chunk]
            logits = self.verify(params, q)
            self.commit(q.shape[1])
            last = logits[:, -1]
        return last

    # -- one decode step ---------------------------------------------------------
    def step(self, params, token):
        """token: (B, 1) int32 -> logits (B, 1, PV). Allocates the next
        page for every sequence when `pos` crosses a page boundary."""
        if self.pos >= self.max_len:
            raise ValueError(f"decode past max_len ({self.max_len})")
        self._extend_all(self.pos + 1)
        logits, self.k_pool, self.v_pool = _paged_decode_step(
            self.cfg, params, self.k_pool, self.v_pool,
            self._device_tables(), jnp.int32(self.pos),
            jnp.asarray(token, jnp.int32), self.impl)
        self.pos += 1
        return logits

    # -- speculative verify / commit (DESIGN.md §11) -----------------------------
    def verify(self, params, tokens):
        """Score q_len positions in one pass. tokens: (B, q_len) int32,
        column 0 = last committed token, the rest drafted. Allocates
        pages for all q_len candidate positions and writes their K/V;
        returns logits (B, q_len, PV). `pos` does NOT advance — call
        commit() with the accepted count."""
        tokens = np.asarray(tokens, np.int32)
        B, Q = tokens.shape
        if self.pos + Q > self.max_len:
            raise ValueError(f"verify past max_len ({self.pos}+{Q} > "
                             f"{self.max_len})")
        self._extend_all(self.pos + Q)
        ps = self.pool.page_size
        qpos = np.arange(self.pos, self.pos + Q)
        page_ids = np.stack([[t.pages[p // ps] for p in qpos]
                             for t in self.tables]).astype(np.int32)
        logits, self.k_pool, self.v_pool = _paged_verify_step(
            self.cfg, params, self.k_pool, self.v_pool,
            self._device_tables(), jnp.asarray(page_ids),
            jnp.asarray(qpos % ps, jnp.int32), jnp.int32(self.pos),
            jnp.asarray(tokens), self.impl)
        self._spec_len = Q
        return logits

    def commit(self, n_tokens: int) -> None:
        """Advance `pos` by the accepted count and roll back the rejected
        suffix: tables truncate to the committed length, pages backing
        only-rejected slots return to the pool."""
        assert 0 <= n_tokens <= getattr(self, "_spec_len", 0), n_tokens
        new_pos = self.pos + n_tokens
        for t in self.tables:
            if self.pool.truncate_table(t, new_pos):
                self._bt_dev = None      # table shrank: refresh device copy
        self.pos = new_pos
        self._spec_len = 0

    def release(self) -> None:
        for t in self.tables:
            self.pool.release_table(t)
        self._bt_dev = None

    @property
    def pages_in_use(self) -> int:
        return sum(len(t.pages) for t in self.tables)
