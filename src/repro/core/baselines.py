"""The paper's six baselines (§V-A), on the same cost substrate as LIME.

Every baseline consumes the same `CostEnv` (device profiles, network
bandwidth, workload) so comparisons isolate the *scheduling* differences —
exactly what the paper varies. Memory-infeasible configurations return
OOM, mirroring Figs 15-17; callers apply the paper's OOT thresholds.

  PP              GPipe-style pipeline, layers allocated by memory; OOM if
                  the model + KV doesn't fit in aggregate.
  PP+offload      traditional pipeline with in-stage offloading (Fig 3a/4a):
                  loads overlap only the *owning* device's resident compute,
                  and bursty steps reload per micro-batch group (the
                  "multiple loading delay" failure).
  EdgeShard       compute-balanced DP layer partition, no offloading.
  Galaxy          TP + SP hybrid; per-layer allreduce traffic; no offloading
                  (OOM when a proportional shard doesn't fit).
  TPI-LLM         TP with sliding-window weight streaming: never OOM, but
                  every step re-streams the out-of-window weights and pays
                  TP allreduce latency on edge links.
  TPI-LLM+offload TPI-LLM with a window large enough to also hold KV spill
                  (paper: "larger sliding window instead of re-computation").

KV-cache pressure: baselines without native memory-constrained support
(PP, EdgeShard, Galaxy) recompute evicted K/V on demand (paper §V-A), which
adds a growing per-step compute term once the cache no longer fits.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.cost_model import CostEnv
from repro.core.pipeline_sim import SimResult, StepTrace

INF = float("inf")


# ----------------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------------
def _balanced_partition(env: CostEnv, n_layers: int,
                        by_compute: bool) -> Optional[List[int]]:
    """Contiguous layer counts per device. by_compute: EdgeShard DP;
    else memory-greedy (classic PP). None -> OOM."""
    w = env.work
    caps = [int(d.mem_bytes // (w.l_size
                                + 512 * w.kv_bytes_per_token_layer()))
            for d in env.devices]
    if sum(caps) < n_layers:
        return None
    if not by_compute:
        alloc, left = [], n_layers
        for c in caps:
            take = min(c, left)
            alloc.append(take)
            left -= take
        return None if left else alloc
    # EdgeShard: minimize max stage time subject to memory caps
    speeds = [1.0 / w.comp_layer(d) for d in env.devices]
    total_speed = sum(speeds)
    ideal = [n_layers * s / total_speed for s in speeds]
    alloc = [min(int(round(x)), c) for x, c in zip(ideal, caps)]
    # fix rounding to sum exactly, respecting caps
    diff = n_layers - sum(alloc)
    order = sorted(range(len(alloc)), key=lambda i: ideal[i] - alloc[i],
                   reverse=(diff > 0))
    k = 0
    while diff != 0 and k < 10 * len(alloc):
        i = order[k % len(alloc)]
        step = 1 if diff > 0 else -1
        if 0 <= alloc[i] + step <= caps[i]:
            alloc[i] += step
            diff -= step
        k += 1
    return alloc if diff == 0 else None


def _kv_overflow_recompute(env: CostEnv, layers_i: float, ctx: int,
                           dev_idx: int, mem_free: float) -> float:
    """Extra seconds to recompute evicted K/V (paper §V-A baseline patch)."""
    w = env.work
    kv_need = layers_i * ctx * w.kv_bytes_per_token_layer()
    if kv_need <= mem_free:
        return 0.0
    evicted_frac = (kv_need - mem_free) / kv_need
    # recompute = rerun the evicted tokens' K/V projections for these layers
    c = w.cfg
    kv_flops = 2 * 2 * c.d_model * c.n_kv_heads * (c.head_dim or 0)
    flops = evicted_frac * ctx * w.mb * w.n_micro * layers_i * kv_flops
    return flops / env.devices[dev_idx].flops


def _pipeline_timeline(env: CostEnv, alloc: Sequence[int], ctx: int,
                       n_micro: int, *, off_layers: Sequence[int] = (),
                       loads_per_mb_group: int = 1,
                       overlap_own_compute_only: bool = True,
                       recompute: bool = True) -> float:
    """One token step of a (possibly offloading) traditional pipeline."""
    w = env.work
    D = len(env.devices)
    hop = w.h_size / env.bw_net + env.net_latency
    off = list(off_layers) if off_layers else [0] * D
    t = 0.0
    dev_free = [0.0] * D
    ready = [0.0] * n_micro
    for i in range(D):
        comp1 = w.comp_layer(env.devices[i])
        res_t = alloc[i] * comp1
        load_t = off[i] * w.l_size / env.devices[i].load_bw \
            + off[i] * w.l_size / max(env.devices[i].load_write_bw,
                                      env.devices[i].load_bw) * 0.0
        mem_free = env.devices[i].mem_bytes - alloc[i] * w.l_size
        rec = _kv_overflow_recompute(env, alloc[i] + off[i], ctx, i,
                                     max(mem_free, 0.0)) if recompute else 0.0
        for m in range(n_micro):
            start = max(ready[m], dev_free[i])
            stage = res_t + off[i] * comp1 + rec
            # in-stage offloading: load hides only behind own resident compute
            if off[i]:
                reload_here = (m % max(n_micro // loads_per_mb_group, 1) == 0) \
                    if loads_per_mb_group > 1 else (m == 0)
                if loads_per_mb_group >= n_micro:
                    reload_here = True     # reload for every micro-batch
                if reload_here:
                    uncovered = max(load_t - (res_t if overlap_own_compute_only
                                              else 0.0), 0.0)
                    stage += uncovered
            end = start + stage
            dev_free[i] = end
            ready[m] = end + hop
    return max(ready)


# ----------------------------------------------------------------------------
# PP / PP+offload / EdgeShard
# ----------------------------------------------------------------------------
def simulate_pp(env: CostEnv, n_layers: int, n_tokens: int, *,
                n_micro: int = 1, by_compute: bool = False,
                prompt: int = 64,
                oot_s_per_token: Optional[float] = None) -> SimResult:
    alloc = _balanced_partition(env, n_layers, by_compute)
    if alloc is None:
        return SimResult([], oom=True, reason="model+KV exceeds memory")
    traces = []
    for tok in range(n_tokens):
        ctx = prompt + tok
        lat = _pipeline_timeline(env, alloc, ctx, n_micro)
        traces.append(StepTrace(tok, lat, 0.0, 0.0))
        if oot_s_per_token and lat > oot_s_per_token:
            return SimResult(traces, oot=True, reason=f"{lat:.1f}s/token")
    return SimResult(traces)


def simulate_pp_offload(env: CostEnv, n_layers: int, n_tokens: int, *,
                        n_micro: int = 1, prompt: int = 64,
                        oot_s_per_token: Optional[float] = None) -> SimResult:
    """Traditional pipeline + in-stage offloading (paper Figs 3a/4a)."""
    w = env.work
    kv512 = 512 * w.kv_bytes_per_token_layer()
    caps = [int(d.mem_bytes // (w.l_size + kv512)) for d in env.devices]
    total_cap = sum(caps)
    res = []
    left = n_layers
    for c in caps:
        take = min(max(c - 1, 0), left)   # keep a buffer layer for swapping
        res.append(take)
        left -= take
    if left > 0 and total_cap == 0:
        return SimResult([], oom=True, reason="no device can hold one layer")
    # leftover layers offloaded, spread by load bandwidth
    bw_tot = sum(d.load_bw for d in env.devices)
    off = [int(round(left * d.load_bw / bw_tot)) for d in env.devices]
    off[-1] += left - sum(off)
    traces = []
    for tok in range(n_tokens):
        ctx = prompt + tok
        # Fig 4a: each full forward needs 2 offload operations per mb group
        lat = _pipeline_timeline(env, res, ctx, n_micro, off_layers=off,
                                 loads_per_mb_group=n_micro,
                                 overlap_own_compute_only=True)
        traces.append(StepTrace(tok, lat, 0.0, 0.0))
        if oot_s_per_token and lat > oot_s_per_token:
            return SimResult(traces, oot=True, reason=f"{lat:.1f}s/token")
    return SimResult(traces)


def simulate_edgeshard(env: CostEnv, n_layers: int, n_tokens: int, *,
                       n_micro: int = 1, prompt: int = 64,
                       oot_s_per_token: Optional[float] = None) -> SimResult:
    return simulate_pp(env, n_layers, n_tokens, n_micro=n_micro,
                       by_compute=True, prompt=prompt,
                       oot_s_per_token=oot_s_per_token)


# ----------------------------------------------------------------------------
# TP family: Galaxy / TPI-LLM / TPI-LLM+offload
# ----------------------------------------------------------------------------
def _tp_step(env: CostEnv, n_layers: int, ctx: int, n_micro: int, *,
             stream_bytes_per_dev: float = 0.0, window_overlap: float = 1.0,
             recompute: bool = True, seq_parallel: bool = False,
             shards: Optional[Sequence[float]] = None) -> float:
    """One token step of tensor-parallel decoding across all devices."""
    w = env.work
    D = len(env.devices)
    # compute: every layer split over devices; slowest shard gates the layer
    shard = max(w.comp_layer(d) for d in env.devices) / D
    comp = n_layers * shard * n_micro
    # comms: 2 allreduce per layer; ring allreduce moves 2(D-1)/D x h_size
    # across 2(D-1) sequential messages (the latency term is what kills TP
    # on edge LANs — the paper's motivation, Fig. 2a)
    ar = 2 * (D - 1) / D * (w.h_size * n_micro) / env.bw_net \
        + 2 * (D - 1) * env.net_latency
    n_ar = 1 if seq_parallel else 2     # Galaxy's SP halves sync points
    comm = n_layers * n_ar * ar
    # sliding-window weight streaming (TPI-LLM stages from host RAM)
    stream = 0.0
    if stream_bytes_per_dev > 0:
        per_dev = [stream_bytes_per_dev / (d.host_bw or d.load_bw)
                   for d in env.devices]
        stream = max(per_dev)
        stream = max(stream - window_overlap * (comp + comm), 0.0)
    rec = 0.0
    if recompute:
        total = w.cfg.total_params() * 2
        for i, d in enumerate(env.devices):
            sh = shards[i] if shards is not None else total / D
            mem_free = d.mem_bytes - sh
            rec = max(rec, _kv_overflow_recompute(env, n_layers / D, ctx, i,
                                                  max(mem_free, 0.0)))
    return comp + comm + stream + rec


def simulate_galaxy(env: CostEnv, n_layers: int, n_tokens: int, *,
                    n_micro: int = 1, prompt: int = 64,
                    oot_s_per_token: Optional[float] = None) -> SimResult:
    w = env.work
    total = w.cfg.total_params() * 2
    D = len(env.devices)
    kv_reserve = 512 * w.kv_bytes_per_token_layer() * n_layers / D
    # Galaxy's workload partitioner: shards proportional to compute, capped
    # by memory, overflow waterfalled to devices with headroom.
    speeds = [d.flops for d in env.devices]
    tot_speed = sum(speeds)
    shards = [total * s / tot_speed for s in speeds]
    caps = [max(d.mem_bytes - kv_reserve, 0.0) for d in env.devices]
    for _ in range(D):
        over = sum(max(sh - c, 0.0) for sh, c in zip(shards, caps))
        if over <= 1e-6:
            break
        head = [(c - sh) for sh, c in zip(shards, caps)]
        room = sum(max(h, 0.0) for h in head)
        if room < over:
            return SimResult([], oom=True,
                             reason="aggregate memory below model size")
        shards = [min(sh, c) for sh, c in zip(shards, caps)]
        for i in range(D):
            if head[i] > 0:
                shards[i] += over * max(head[i], 0.0) / room
    if any(sh > c + 1e-6 for sh, c in zip(shards, caps)):
        return SimResult([], oom=True, reason="TP shard exceeds device memory")
    traces = []
    for tok in range(n_tokens):
        lat = _tp_step(env, n_layers, prompt + tok, n_micro,
                       seq_parallel=True, shards=shards)
        traces.append(StepTrace(tok, lat, 0.0, 0.0))
        if oot_s_per_token and lat > oot_s_per_token:
            return SimResult(traces, oot=True, reason=f"{lat:.1f}s/token")
    return SimResult(traces)


def simulate_tpi_llm(env: CostEnv, n_layers: int, n_tokens: int, *,
                     n_micro: int = 1, offload_variant: bool = False,
                     prompt: int = 64,
                     oot_s_per_token: Optional[float] = None) -> SimResult:
    w = env.work
    total = w.cfg.total_params() * 2
    traces = []
    for tok in range(n_tokens):
        ctx = prompt + tok
        lat = 0.0
        for i, d in enumerate(env.devices):
            shard = total / len(env.devices)
            kv = ctx * w.kv_bytes_per_token_layer() * n_layers \
                / len(env.devices)
            window = max(d.mem_bytes - (kv if offload_variant else 0.0), 0.0)
            window = min(window, shard)
            lat = max(lat, max(shard - window, 0.0)
                      / (d.host_bw or d.load_bw))
        step = _tp_step(env, n_layers, ctx, n_micro,
                        recompute=not offload_variant)
        # streaming overlaps compute+comm (TPI-LLM's prefetch)
        lat = step + max(lat - step, 0.0)
        traces.append(StepTrace(tok, lat, 0.0, 0.0))
        if oot_s_per_token and lat > oot_s_per_token:
            return SimResult(traces, oot=True, reason=f"{lat:.1f}s/token")
    return SimResult(traces)


# ----------------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------------
BASELINES = {
    "pp": simulate_pp,
    "pp+offload": simulate_pp_offload,
    "edgeshard": simulate_edgeshard,
    "galaxy": simulate_galaxy,
    "tpi-llm": simulate_tpi_llm,
    "tpi-llm+offload": lambda env, L, n, **kw: simulate_tpi_llm(
        env, L, n, offload_variant=True, **kw),
}
