"""Page allocator + per-request block tables (DESIGN.md §10).

The KV cache is carved into fixed-size pages of `page_size` token slots.
A request's cache is then a *block table* — an ordered list of page ids —
instead of a contiguous reservation, so admission can be page-granular
(vLLM-style paged attention, the natural counterpart to LIME's
token-granular Eq. 5/Eq. 8 accounting):

  PageAllocator   free-list over a fixed pool of page ids, with per-page
                  refcounts so a page can back more than one block table
                  (prefix sharing: fork() increfs every page of a prefix).
  BlockTable      one request's ordered pages + its token count. The last
                  page is usually partially filled; `capacity_tokens`
                  rounds up, `tokens` is exact.

Allocation is LIFO (`free` pushes back onto the stack), so recently
released pages are reused first — the hot end of HBM stays hot, and tests
get deterministic, non-contiguous tables for free.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


class OutOfPages(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list."""


class PageAllocator:
    """Free-list allocator over `n_pages` fixed-size pages."""

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 0 or page_size <= 0:
            raise ValueError(f"bad pool geometry ({n_pages=}, {page_size=})")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._ref = [0] * n_pages

    # -- capacity ---------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold `n_tokens` token slots (ceil)."""
        return -(-max(n_tokens, 0) // self.page_size)

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def add_pages(self, n: int) -> None:
        """Extend the pool with `n` fresh page ids (online adaptation:
        HBM returned by weight retiering becomes KV pages — DESIGN.md
        §13). Existing ids, refcounts, and tables are untouched."""
        if n <= 0:
            return
        start = self.n_pages
        self.n_pages += n
        self._ref.extend([0] * n)
        self._free.extend(range(start + n - 1, start - 1, -1))

    # -- alloc / refcount --------------------------------------------------------
    def alloc(self) -> int:
        if not self._free:
            raise OutOfPages(f"pool exhausted ({self.n_pages} pages)")
        pid = self._free.pop()
        self._ref[pid] = 1
        return pid

    def alloc_many(self, n: int) -> List[int]:
        """All-or-nothing: either n pages or OutOfPages (no partial grab)."""
        if not self.can_alloc(n):
            raise OutOfPages(
                f"need {n} pages, {len(self._free)} free of {self.n_pages}")
        return [self.alloc() for _ in range(n)]

    def incref(self, pid: int) -> None:
        if self._ref[pid] <= 0:
            raise ValueError(f"incref on free page {pid}")
        self._ref[pid] += 1

    def decref(self, pid: int) -> None:
        if self._ref[pid] <= 0:
            raise ValueError(f"decref on free page {pid}")
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            self._free.append(pid)

    def refcount(self, pid: int) -> int:
        return self._ref[pid]


@dataclasses.dataclass
class BlockTable:
    """One request's ordered pages. `tokens` counts filled slots; the last
    page holds `tokens - (len(pages)-1) * page_size` of them."""
    page_size: int
    pages: List[int] = dataclasses.field(default_factory=list)
    tokens: int = 0

    @property
    def capacity_tokens(self) -> int:
        return len(self.pages) * self.page_size

    def extend_to(self, n_tokens: int, alloc: PageAllocator) -> List[int]:
        """Grow the table to hold `n_tokens`; returns the newly allocated
        page ids (all-or-nothing — raises OutOfPages leaving the table
        unchanged). Shrinking is not supported (tokens only grow)."""
        if n_tokens < self.tokens:
            raise ValueError(f"cannot shrink table ({self.tokens} -> "
                             f"{n_tokens} tokens)")
        need = alloc.pages_for(n_tokens) - len(self.pages)
        new = alloc.alloc_many(need) if need > 0 else []
        self.pages.extend(new)
        self.tokens = n_tokens
        return new

    def append_token(self, alloc: PageAllocator) -> Optional[int]:
        """Room for one more token; returns the new page id if a page
        boundary was crossed, else None."""
        new = self.extend_to(self.tokens + 1, alloc)
        return new[0] if new else None

    def truncate_to(self, n_tokens: int, alloc: PageAllocator) -> List[int]:
        """Shrink the table to hold `n_tokens` (speculative-decoding
        rollback: reject a drafted suffix, DESIGN.md §11). Pages past
        ceil(n_tokens/page_size) are decrefed; returns the page ids this
        table dropped (freed iff refcount hit zero)."""
        if n_tokens > self.tokens:
            raise ValueError(f"truncate_to past end ({self.tokens} -> "
                             f"{n_tokens} tokens)")
        keep = alloc.pages_for(n_tokens)
        dropped = self.pages[keep:]
        for pid in dropped:
            alloc.decref(pid)
        self.pages = self.pages[:keep]
        self.tokens = max(n_tokens, 0)
        return dropped

    def release(self, alloc: PageAllocator) -> None:
        for pid in self.pages:
            alloc.decref(pid)
        self.pages = []
        self.tokens = 0

    def fork(self, alloc: PageAllocator) -> "BlockTable":
        """Copy-on-write prefix share: the fork references the same pages
        (increfed). Callers must copy-out before writing a shared page —
        the allocator only tracks lifetime, not mutability."""
        for pid in self.pages:
            alloc.incref(pid)
        return BlockTable(self.page_size, list(self.pages), self.tokens)

    def slot_of(self, pos: int) -> tuple:
        """(page_id, offset) of absolute token position `pos`."""
        if not 0 <= pos < self.tokens:
            raise IndexError(f"pos {pos} outside [0, {self.tokens})")
        return self.pages[pos // self.page_size], pos % self.page_size
