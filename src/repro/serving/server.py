"""LimeServer: the serving front door (DESIGN.md §9).

Composes the LIME-Serve pieces — a RequestQueue clients submit to, an
execution backend (engine or single-device fallback), and the
continuous-batching scheduler — behind the one-call API the examples and
launchers use:

    srv = LimeServer(cfg, params, engine=engine, pattern="bursty")
    srv.queue.submit(prompt, max_new_tokens=32)
    finished = srv.serve_all()

The paper's request patterns map to slot counts: sporadic serves one
request at a time (n_mb = 1, the pipeline drains between requests); bursty
fills every micro-batch slot (n_mb = n_stage). Richer arrival processes
(Poisson, trace replay) live in `serving/traffic.py` and run through the
same scheduler — see `benchmarks/bench_serving.py`.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import InterleavedEngine
from repro.obs.trace import Tracer, get_tracer, set_tracer
from repro.serving.backend import EngineBackend
from repro.serving.sampling import SamplerConfig, sample  # noqa: F401
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     SchedulerConfig)


class RequestQueue:
    """Client-facing submission queue (rid assignment + FIFO order)."""

    def __init__(self):
        self._q: deque[Request] = deque()
        self._next = 0

    def submit(self, prompt, max_new_tokens: int, now: float = 0.0) -> Request:
        r = Request(self._next, np.asarray(prompt, np.int32),
                    max_new_tokens, arrival_s=now)
        self._next += 1
        self._q.append(r)
        return r

    def pop_up_to(self, n: int) -> List[Request]:
        out = []
        while self._q and len(out) < n:
            out.append(self._q.popleft())
        return out

    def drain(self) -> List[Request]:
        out = list(self._q)
        self._q.clear()
        return out

    def __len__(self):
        return len(self._q)


class LimeServer:
    """Pattern-aware serving over an InterleavedEngine (or a plain
    single-host decode fallback when engine is None — 1-device runs)."""

    def __init__(self, cfg: ModelConfig, params, *,
                 engine: Optional[InterleavedEngine] = None,
                 max_len: int = 512, sampler: SamplerConfig = SamplerConfig(),
                 pattern: str = "sporadic", spec=None,
                 prefix_cache: bool = False, prefill_chunk_tokens: int = 0,
                 page_size: int = 64, planner=None, refit: bool = False,
                 trace: Optional[str] = None,
                 trace_capacity: int = 1 << 16):
        self.cfg = cfg
        self.params = params
        self.engine = engine
        self.max_len = max_len
        self.sampler = sampler
        self.pattern = pattern
        self.spec = spec              # SpecConfig -> speculative decoding
        self.prefix_cache = prefix_cache      # radix KV reuse (DESIGN §12)
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.page_size = page_size
        self.planner = planner                # OnlinePlanner (DESIGN §13)
        self.refit = refit                    # online re-fit (DESIGN §18)
        # flight recorder (DESIGN.md §15): a path arms tracing for every
        # serve_all() — Chrome trace-event JSON (Perfetto), or JSONL when
        # the suffix is .jsonl
        self.trace = trace
        self.trace_capacity = trace_capacity
        self.queue = RequestQueue()
        self._backend: Optional[EngineBackend] = None

    @property
    def slots(self) -> int:
        if self.engine is None:
            return 1 if self.pattern == "sporadic" else 4
        return 1 if self.pattern == "sporadic" else self.engine.n_mb

    def make_backend(self) -> EngineBackend:
        # cached: a fresh backend would re-jit prefill/decode (new
        # functools.partial objects miss jax's jit cache) on every
        # serve_all() call
        if self._backend is None:
            self._backend = EngineBackend(
                self.cfg, self.params, engine=self.engine,
                n_slots=self.slots, max_len=self.max_len,
                sampler=self.sampler, spec=self.spec,
                prefix_cache=self.prefix_cache and self.engine is None,
                prefill_chunk_tokens=self.prefill_chunk_tokens,
                page_size=self.page_size, planner=self.planner,
                refit=self.refit)
        return self._backend

    def serve_all(self) -> List[Request]:
        """Drain the queue through the continuous-batching scheduler
        according to the request pattern. Submitted arrival times are
        relative to this call: the cached backend's clock keeps running
        across serve_all() calls, so requests are re-based onto it (else
        a second batch would report the first batch's elapsed time as
        queueing latency)."""
        reqs = self.queue.drain()
        if not reqs:
            return []
        backend = self.make_backend()
        base = backend.now()
        for r in reqs:
            r.arrival_s += base
        # arm the flight recorder before the scheduler is built (it binds
        # the tracer clock to backend.now at construction); an externally
        # installed tracer wins — the caller owns its export then
        tracer = None
        if self.trace and get_tracer() is None:
            tracer = Tracer(capacity=self.trace_capacity)
            set_tracer(tracer)
        try:
            sched = ContinuousBatchingScheduler(backend, SchedulerConfig())
            return sched.serve(reqs)
        finally:
            if tracer is not None:
                set_tracer(None)
                tracer.export(self.trace)
