"""Block-size autotuner for the Pallas kernel families.

For each kernel entry point this times a candidate list of static block
configs on representative shapes and records the winner in a TuneCache,
keyed by (device_kind, kernel, shape-bucket). `repro.kernels.tuning`
then answers wrapper lookups with the winner — so a sweep run once per
device kind speeds up every later trace of a bucketed shape, and no
sweep at all leaves the historical defaults byte-for-byte in place.

Why this wins even on CPU/interpret mode (where CI runs it): interpret
mode executes one Python-level kernel invocation per grid step, so a
larger block means fewer grid steps and less interpreter overhead; on
real hardware the same sweep trades VMEM residency against grid
parallelism. Either way the clock decides — candidates are timed with
the same ``timeit_median`` discipline as everything else in the repo.

The paged kernels have no block argument: their blocking knob is the
pool's ``page_size`` (a real config flag), so the sweep times whole
pool layouts across page sizes and records the winning ``page_size``.

Candidates always include the historical default, so ``speedup`` (the
default's time over the winner's) is >= 1.0 by construction up to
timing noise.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.kernels import tuning
from repro.obs.log import get_logger
from repro.tune.measure import timeit_median

KERNELS = ("decode_attention", "mq_decode_attention", "flash_attention",
           "rwkv6_scan", "ssm_scan", "paged_decode_attention",
           "mq_paged_decode_attention")

# candidate block configs per kernel; the historical default is always
# a member so speedup is measured against a timed baseline, not a guess
CANDIDATES: Dict[str, List[Dict[str, int]]] = {
    "decode_attention": [{"block_k": b} for b in (128, 256, 512, 1024, 2048)],
    "mq_decode_attention": [{"block_k": b}
                            for b in (128, 256, 512, 1024, 2048)],
    "flash_attention": [{"block_q": q, "block_k": k}
                        for q in (128, 256)
                        for k in (256, 512, 1024, 2048)],
    "rwkv6_scan": [{"block_t": t} for t in (64, 128, 256, 512)],
    "ssm_scan": [{"block_t": t} for t in (64, 128, 256, 512)],
    "paged_decode_attention": [{"page_size": p} for p in (16, 32, 64, 128)],
    "mq_paged_decode_attention": [{"page_size": p}
                                  for p in (16, 32, 64, 128)],
}

# representative shapes: (span of the blocked axis, head dim, extras);
# modest sizes so the CI interpret-mode dry-run stays in seconds
DEFAULT_SHAPES: Dict[str, Dict[str, int]] = {
    "decode_attention": dict(B=2, H=8, KV=2, dh=64, span=2048),
    "mq_decode_attention": dict(B=2, H=8, KV=2, dh=64, span=2048, Q=4),
    "flash_attention": dict(B=1, H=4, KV=4, dh=64, Sq=256, span=2048),
    "rwkv6_scan": dict(B=1, H=4, dh=64, span=512),
    "ssm_scan": dict(B=1, H=4, dh=64, span=512, N=16),
    "paged_decode_attention": dict(B=2, H=8, KV=2, dh=64, span=512),
    "mq_paged_decode_attention": dict(B=2, H=8, KV=2, dh=64, span=512, Q=4),
}


@dataclasses.dataclass(frozen=True)
class SweepResult:
    kernel: str
    bucket: str
    default_cfg: Dict[str, int]
    default_s: float
    best_cfg: Dict[str, int]
    best_s: float

    @property
    def speedup(self) -> float:
        return self.default_s / self.best_s if self.best_s > 0 else 1.0

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["speedup"] = self.speedup
        return d


# -- per-kernel runners --------------------------------------------------------
# Each builder returns (bucket, run(cfg) -> blocked result); inputs are
# built once per shape (paged rebuilds the pool per page_size because
# the pool layout *is* the knob).

def _decode_inputs(shape, multi_query: bool):
    import jax
    import jax.numpy as jnp
    B, H, KV, dh = shape["B"], shape["H"], shape["KV"], shape["dh"]
    S_c, Q = shape["span"], shape.get("Q", 1)
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Q, H, dh), jnp.float32)
    k = jax.random.normal(kk, (B, S_c, KV, dh), jnp.float32)
    v = jax.random.normal(kv, (B, S_c, KV, dh), jnp.float32)
    pos_ids = jnp.arange(S_c, dtype=jnp.int32)
    pos = jnp.asarray(S_c - Q, jnp.int32)
    return q, k, v, pos_ids, pos


def _run_decode(shape, interpret):
    import jax
    from repro.kernels.decode_attention.ops import decode_attention
    args = _decode_inputs(shape, False)

    def run(cfg):
        return jax.block_until_ready(
            decode_attention(*args, block_k=cfg["block_k"],
                             interpret=interpret))
    return tuning.shape_bucket(shape["span"], shape["dh"]), run


def _run_mq_decode(shape, interpret):
    import jax
    from repro.kernels.decode_attention.multiquery import mq_decode_attention
    args = _decode_inputs(shape, True)

    def run(cfg):
        return jax.block_until_ready(
            mq_decode_attention(*args, block_k=cfg["block_k"],
                                interpret=interpret))
    return tuning.shape_bucket(shape["span"], shape["dh"]), run


def _run_flash(shape, interpret):
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_attention
    B, H, KV, dh = shape["B"], shape["H"], shape["KV"], shape["dh"]
    Sq, Skv = shape["Sq"], shape["span"]
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Sq, H, dh), jnp.float32)
    k = jax.random.normal(kk, (B, Skv, KV, dh), jnp.float32)
    v = jax.random.normal(kv, (B, Skv, KV, dh), jnp.float32)

    def run(cfg):
        return jax.block_until_ready(
            flash_attention(q, k, v, causal=True, block_q=cfg["block_q"],
                            block_k=cfg["block_k"],
                            q_offset=Skv - Sq, interpret=interpret))
    return tuning.shape_bucket(Skv, dh), run


def _run_rwkv6(shape, interpret):
    import jax
    import jax.numpy as jnp
    from repro.kernels.rwkv6_scan.ops import wkv
    B, H, dh, S = shape["B"], shape["H"], shape["dh"], shape["span"]
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    r, k, v = (jax.random.normal(ki, (B, S, H, dh), jnp.float32)
               for ki in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, dh), jnp.float32))
    u = jax.random.normal(ks[4], (H, dh), jnp.float32)
    state = jnp.zeros((B, H, dh, dh), jnp.float32)

    def run(cfg):
        return jax.block_until_ready(
            wkv(r, k, v, w, u, state, block_t=cfg["block_t"],
                interpret=interpret))
    return tuning.shape_bucket(S, dh), run


def _run_ssm(shape, interpret):
    import jax
    import jax.numpy as jnp
    from repro.kernels.ssm_scan.ops import ssm_scan
    B, H, dh, S, N = (shape["B"], shape["H"], shape["dh"], shape["span"],
                      shape["N"])
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    xh = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    B_in = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    C_in = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    A = -jnp.ones((H,), jnp.float32)
    state = jnp.zeros((B, H, N, dh), jnp.float32)

    def run(cfg):
        return jax.block_until_ready(
            ssm_scan(xh, dt, B_in, C_in, A, state, block_t=cfg["block_t"],
                     interpret=interpret))
    return tuning.shape_bucket(S, dh), run


def _paged_runner(shape, interpret, multi_query: bool):
    """Paged sweeps rebuild the KV pool per candidate: the page size IS
    the layout, so each candidate times a differently-paged pool holding
    the same `span` context tokens per request."""
    import jax
    import jax.numpy as jnp
    if multi_query:
        from repro.kernels.decode_attention.multiquery import \
            mq_paged_decode_attention as fn
    else:
        from repro.kernels.decode_attention.paged import \
            paged_decode_attention as fn
    B, H, KV, dh = shape["B"], shape["H"], shape["KV"], shape["dh"]
    ctx, Q = shape["span"], shape.get("Q", 1)
    key = jax.random.PRNGKey(0)
    kq, kp = jax.random.split(key)
    q = jax.random.normal(kq, (B, Q, H, dh), jnp.float32)

    def run(cfg):
        ps = cfg["page_size"]
        pages_per = -(-ctx // ps)
        P = B * pages_per
        k_pool = jax.random.normal(kp, (P, ps, KV, dh), jnp.float32)
        v_pool = k_pool * 0.5
        block_tables = jnp.arange(P, dtype=jnp.int32).reshape(B, pages_per)
        ctx_lens = jnp.full((B,), ctx, jnp.int32)
        return jax.block_until_ready(
            fn(q, k_pool, v_pool, block_tables, ctx_lens,
               interpret=interpret))
    return tuning.shape_bucket(ctx, dh), run


_RUNNERS: Dict[str, Callable] = {
    "decode_attention": _run_decode,
    "mq_decode_attention": _run_mq_decode,
    "flash_attention": _run_flash,
    "rwkv6_scan": _run_rwkv6,
    "ssm_scan": _run_ssm,
    "paged_decode_attention":
        lambda s, i: _paged_runner(s, i, multi_query=False),
    "mq_paged_decode_attention":
        lambda s, i: _paged_runner(s, i, multi_query=True),
}


# -- driver --------------------------------------------------------------------

def sweep_kernel(kernel: str, *, shape: Optional[Mapping[str, int]] = None,
                 candidates: Optional[Sequence[Mapping[str, int]]] = None,
                 reps: int = 3, interpret: Optional[bool] = None
                 ) -> SweepResult:
    """Time every candidate config for one kernel on one shape; returns
    the winner vs the historical default. Explicit block values are
    always passed, so the sweep never reads (or needs) the installed
    tuning table."""
    shape = dict(DEFAULT_SHAPES[kernel], **(shape or {}))
    cands = [dict(c) for c in (candidates or CANDIDATES[kernel])]
    default = dict(tuning.DEFAULTS[kernel])
    if default not in cands:
        cands.append(default)

    bucket, run = _RUNNERS[kernel](shape, interpret)
    timed: List[Tuple[Dict[str, int], float]] = []
    for cfg in cands:
        med, _ = timeit_median(lambda c=cfg: run(c), reps=reps, warmup=1)
        timed.append((cfg, med))

    default_s = next(t for c, t in timed if c == default)
    best_cfg, best_s = min(timed, key=lambda ct: ct[1])
    return SweepResult(kernel=kernel, bucket=bucket, default_cfg=default,
                       default_s=default_s, best_cfg=dict(best_cfg),
                       best_s=best_s)


def run_sweep(kernels: Optional[Sequence[str]] = None, *,
              cache=None, device_kind: Optional[str] = None,
              shapes: Optional[Mapping[str, Mapping[str, int]]] = None,
              candidates: Optional[Mapping[str, Sequence[Mapping]]] = None,
              reps: int = 3, interpret: Optional[bool] = None
              ) -> List[SweepResult]:
    """Sweep a set of kernels (default: all) and record winners into
    `cache` (a TuneCache) under `device_kind`. Returns every
    SweepResult so callers/benchmarks can report speedups."""
    log = get_logger("repro.tune")
    if device_kind is None:
        from repro.tune.measure import device_kind as dk
        device_kind = dk()
    results = []
    for kernel in (kernels or KERNELS):
        r = sweep_kernel(
            kernel, shape=(shapes or {}).get(kernel),
            candidates=(candidates or {}).get(kernel),
            reps=reps, interpret=interpret)
        results.append(r)
        log.info("kernel sweep", kernel=kernel, bucket=r.bucket,
                 best=r.best_cfg, default_us=f"{r.default_s * 1e6:.0f}",
                 best_us=f"{r.best_s * 1e6:.0f}",
                 speedup=f"{r.speedup:.2f}x")
        if cache is not None:
            cache.put_kernel(device_kind, kernel, r.bucket, r.best_cfg,
                             speedup=round(r.speedup, 4),
                             us=round(r.best_s * 1e6, 2))
    return results


def main(argv=None) -> int:
    """CLI: ``python -m repro.tune.sweep --reps 1 --out cache.json`` —
    the CI interpret-mode dry-run entry point."""
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernels", default=None,
                    help="comma-separated subset (default: all families)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--device-kind", default=None,
                    help="cache key override (default: local device)")
    ap.add_argument("--out", default=None,
                    help="TuneCache JSON to load + update with winners")
    args = ap.parse_args(argv)
    from repro.tune.cache import TuneCache
    cache = TuneCache.load(args.out) if args.out else TuneCache()
    results = run_sweep(args.kernels.split(",") if args.kernels else None,
                        cache=cache, device_kind=args.device_kind,
                        reps=args.reps)
    for r in results:
        print(f"{r.kernel:28s} {r.bucket:12s} "
              f"default {r.default_s * 1e6:9.0f}us  "
              f"best {r.best_s * 1e6:9.0f}us  "
              f"{r.speedup:5.2f}x  {r.best_cfg}")
    if args.out:
        cache.save(args.out)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
