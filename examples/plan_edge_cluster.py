"""Paper-faithful planning walkthrough: take a heterogeneous edge fleet and
a target model, run the full LIME stack from the paper — offline scheduler
(Alg. 1), online planner thresholds (Eq. 5-7), KV transfer pairing (Alg. 2)
— then simulate a serving session under memory pressure and a bandwidth
drop, and compare against the strongest baseline.

  PYTHONPATH=src python examples/plan_edge_cluster.py
"""
from repro.configs.registry import get_config
from repro.core.baselines import simulate_edgeshard, simulate_tpi_llm
from repro.core.cost_model import CostEnv, Workload
from repro.core.kv_transfer import KVTransferProtocol
from repro.core.offline_scheduler import allocate
from repro.core.online_planner import OnlinePlanner
from repro.core.pipeline_sim import InterleavedPipelineSim
from repro.core.profiles import env_lowmem, mbps


def main():
    cfg = get_config("llama3.3-70b")
    devices = env_lowmem(1)
    P, N = 2048, 200
    w = Workload(cfg, mb=1, ctx=P, n_micro=1)
    env = CostEnv(devices, mbps(200), w)

    print("== Alg. 1: fine-grained offline allocation ==")
    r = allocate(env, cfg.n_layers, n_emp=P)
    plan = r.plan
    print(f"#Seg={plan.n_seg}  (candidates: "
          f"{[(s, round(t*1e3)) for s, t in r.candidates[:5]]})")
    for d, dev in zip(plan.devices, devices):
        print(f"  {dev.name:22s} resident={d.resident_total:2d} "
              f"off/seg: full={d.off_full_seg} attn-only={d.off_attn_only_seg} "
              f"mlp-only={d.off_mlp_only_seg}")

    print("\n== Eq. 5-7: online planner thresholds (first 3 per device) ==")
    pl = OnlinePlanner(env, plan, horizon_tokens=2 ** 18)
    for i, lad in enumerate(pl.ladders):
        steps = [(s.threshold_tokens, s.alpha, s.beta) for s in lad[:3]]
        print(f"  {devices[i].name:22s} TS/(a,b): {steps}")

    print("\n== Alg. 2: KV transfer pairing ==")
    proto = KVTransferProtocol(env, plan, pl)
    proto.init_transfers(ctx_tokens=P)
    for st, dev in zip(proto.states, devices):
        role = "target" if st.target is None else \
            f"-> {devices[st.target].name} (n_trans={st.n_trans})"
        print(f"  {dev.name:22s} {role}")

    print("\n== simulate 200 tokens with a mid-run bandwidth drop ==")

    def bw(tok):
        return mbps(80 if 80 <= tok < 140 else 200)

    sim = InterleavedPipelineSim(env, plan, bandwidth_schedule=bw,
                                 prompt_tokens=P)
    res = sim.run(N, n_micro=1)
    print(f"LIME: {res.ms_per_token:.0f} ms/token "
          f"(load stall {sum(t.load_stall for t in res.per_token):.1f}s "
          f"over {N} tokens)")
    es = simulate_edgeshard(env, cfg.n_layers, N, prompt=P)
    tp = simulate_tpi_llm(env, cfg.n_layers, N, prompt=P)
    for name, b in (("EdgeShard", es), ("TPI-LLM", tp)):
        s = "OOM" if b.oom else f"{b.ms_per_token:.0f} ms/token " \
            f"({b.ms_per_token / res.ms_per_token:.1f}x LIME)"
        print(f"{name}: {s}")


if __name__ == "__main__":
    main()
