"""Measured-profile autotuner (DESIGN.md §18).

measure  - microbenchmark harness -> MeasuredProfile
profiles - MeasuredProfile (DeviceProfile + provenance/confidence)
sweep    - Pallas kernel block-size autotuner
cache    - TuneCache JSON persistence + kernel-table install
refit    - online EWMA re-fit of CostEnv from serving telemetry
"""
from repro.tune.profiles import (MEASURED_FIELDS, SANITY_FACTOR,
                                 MeasuredProfile, from_analytic)
from repro.tune.cache import TuneCache, default_cache_path

__all__ = ["MEASURED_FIELDS", "SANITY_FACTOR", "MeasuredProfile",
           "from_analytic", "TuneCache", "default_cache_path"]
