"""Quickstart: the whole stack in two minutes on one CPU.

1. Pick an assigned architecture (reduced config), train it briefly on the
   synthetic corpus, checkpoint it.
2. Plan a LIME deployment for the paper's E3 Jetson fleet with the offline
   scheduler (Alg. 1) and print the allocation + predicted latency (Eq. 1).
3. Serve a few requests through the (single-device) serving layer.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro.checkpoint import save
from repro.configs.registry import get_config, get_smoke_config
from repro.core.cost_model import CostEnv, Workload
from repro.core.offline_scheduler import allocate
from repro.core.profiles import env_E3, mbps
from repro.data import make_batches
from repro.serving import LimeServer, SamplerConfig
from repro.training import Trainer


def main():
    # ------------------------------------------------------------------ 1
    print("== train a reduced gemma3-1b on the synthetic corpus ==")
    cfg = get_smoke_config("gemma3-1b")
    tr = Trainer(cfg, mesh=None, total_steps=40, warmup=5, peak_lr=1e-3)
    params, opt_state = tr.init()
    batches = make_batches(cfg.vocab_size, batch=8, seq_len=64)
    params, opt_state, hist = tr.fit(params, opt_state, batches, steps=30,
                                     log_every=10)
    with tempfile.TemporaryDirectory() as d:
        save(d, params, step=30)
        print(f"checkpointed to {d}")

    # ------------------------------------------------------------------ 2
    print("\n== LIME offline allocation (Alg. 1) for Llama3.3-70B on E3 ==")
    cfg70 = get_config("llama3.3-70b")
    env = CostEnv(env_E3(), mbps(200), Workload(cfg70, mb=1, ctx=4096))
    r = allocate(env, cfg70.n_layers, n_emp=4096)
    plan = r.plan
    print(f"feasible={r.feasible}  #Seg={plan.n_seg}")
    for i, (d, dev) in enumerate(zip(plan.devices, env.devices)):
        print(f"  {dev.name:16s} resident={d.resident_total:2d} "
              f"offload/seg={d.off_layers_seg()} "
              f"(attn-only={d.off_attn_only_seg} mlp-only={d.off_mlp_only_seg})")
    print(f"predicted: comp={plan.t_comp*1e3:.0f}ms "
          f"comm={plan.t_comm*1e3:.0f}ms uncovered={plan.t_uncover*1e3:.0f}ms "
          f"-> {plan.t_total*1e3:.0f} ms/token")

    # ------------------------------------------------------------------ 3
    print("\n== serve a few requests (greedy + sampled) ==")
    srv = LimeServer(cfg, params, engine=None, max_len=96, pattern="bursty",
                     sampler=SamplerConfig(temperature=0.8, top_k=40))
    rng = np.random.default_rng(0)
    for i in range(3):
        srv.queue.submit(rng.integers(1, cfg.vocab_size, 8),
                         max_new_tokens=12)
    for r in srv.serve_all():
        print(f"  req {r.rid}: {r.output}")
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
