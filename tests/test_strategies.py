"""Sharding strategies (DP / FSDP) + Adafactor — the §Perf/§Dry-run
machinery that keeps kimi-k2-scale configs inside HBM."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.adafactor import Adafactor
from repro.optim.adamw import constant_schedule
from repro.sharding.rules import dp_rules, fsdp_rules, spec_for


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})


def test_dp_rules_replicate_weights_and_widen_batch():
    r = dp_rules()
    assert spec_for((2048, 8192), ("embed", "ffn"), MESH, r) == P()
    assert spec_for((256, 4096), ("batch", "seq"), MESH, r) \
        == P(("data", "model"))


def test_fsdp_rules_shard_dmodel_rows():
    r = fsdp_rules()
    # expert dim -> model, d_model rows -> data: 2 TB / 256 ways
    assert spec_for((384, 7168, 2048), ("expert", "embed", None), MESH, r) \
        == P("model", "data")
    # batch unchanged
    assert spec_for((256, 4096), ("batch", "seq"), MESH, r) == P("data")


def test_adafactor_converges_quadratic():
    # the RMS-normalized update behaves like sign-SGD near the optimum, so
    # the residual oscillation is O(lr) — assert within that band
    opt = Adafactor(lr=constant_schedule(0.02))
    params = {"w": jnp.full((8, 4), 3.0)}
    state = opt.init(params)
    assert set(state.vs["w"]) == {"vr", "vc"}
    for _ in range(400):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.06


def test_adafactor_state_is_factored():
    opt = Adafactor(lr=constant_schedule(1e-3))
    params = {"big": jnp.zeros((1024, 2048)), "vec": jnp.zeros((64,))}
    state = opt.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(state.vs))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    assert n_state < 0.01 * n_params + 64   # factored: ~ (d1+d2), not d1*d2
    assert state.vs["vec"]["v"].shape == (64,)


def test_adafactor_jit_train_step():
    """Adafactor slots into the same train_step interface as AdamW."""
    from repro.configs.registry import get_smoke_config
    from repro.models import model as M
    from repro.training.trainer import make_train_step
    cfg = get_smoke_config("deepseek-moe-16b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = Adafactor(lr=constant_schedule(1e-3))
    st = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, None))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    p2, st2, metrics = step(params, st, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(st2.step) == 1
