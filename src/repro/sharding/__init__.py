from repro.sharding.rules import (RULES, spec_for,  # noqa: F401
                                  shardings, partition_specs,  # noqa: F401
                                  activation_sharding)  # noqa: F401
