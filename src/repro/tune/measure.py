"""Microbenchmark harness: time the device, emit a MeasuredProfile.

The analytic profiles in `repro.core.profiles` are knobs; this module
replaces them with clocks. Four primitive measurements map one-to-one
onto the fields the cost model prices:

  - ``measure_flops``      -> DeviceProfile.flops    (bf16 matmul loop)
  - ``measure_mem_bw``     -> DeviceProfile.mem_bw   (triad read+write)
  - ``measure_stream_bw``  -> load_bw / host_bw (H2D) and
                              load_write_bw (D2H) via real device_put /
                              host round-trips of a weight-sized buffer
  - ``measure_decode_loop``-> extras: a MaxText-style timed
                              prefill / insert / generate loop on a real
                              smoke model (end-to-end cross-check that
                              the primitives above aren't fantasy)

``measure_profile`` assembles them into a MeasuredProfile carrying
per-field confidence (coefficient of variation across trials). Memory
capacity (`mem_bytes`) is deliberately *not* measured: on the edge
devices LIME targets it's an enforced budget, not a throughput, so the
analytic base's value is kept.

All timing goes through ``timeit_median`` — also the single timing
helper `benchmarks/bench_kernels.py` and `repro.tune.sweep` use, so
every number in the repo is produced by the same clock discipline
(warmup, block_until_ready, median-of-reps).
"""
from __future__ import annotations

import datetime
import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.profiles import DeviceProfile
from repro.tune.profiles import MEASURED_FIELDS, MeasuredProfile


def _stats(ts) -> Tuple[float, float]:
    """(median, coefficient-of-variation) of a list of seconds."""
    a = np.asarray(ts, dtype=np.float64)
    med = float(np.median(a))
    cov = float(a.std() / a.mean()) if a.mean() > 0 else float("nan")
    return med, cov


def timeit_median(fn: Callable[[], object], *, reps: int = 5,
                  warmup: int = 2) -> Tuple[float, float]:
    """Time ``fn()`` (which must block until its work is done — call
    ``jax.block_until_ready`` inside) and return (median_s, cov)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return _stats(ts)


# -- primitives ----------------------------------------------------------------

def measure_flops(*, n: int = 1024, reps: int = 5) -> Tuple[float, float]:
    """Dense-compute throughput: timed (n x n) bf16 matmul; returns
    (flops_per_s, cov)."""
    import jax
    import jax.numpy as jnp

    x = jnp.ones((n, n), jnp.bfloat16)

    @jax.jit
    def mm(a):
        return a @ a

    med, cov = timeit_median(lambda: jax.block_until_ready(mm(x)), reps=reps)
    return 2.0 * n * n * n / med, cov


def measure_mem_bw(*, mb: int = 64, reps: int = 5) -> Tuple[float, float]:
    """On-device memory bandwidth: timed triad ``y = a*x + b`` over an
    ``mb``-MiB fp32 buffer (one read + one write stream); returns
    (bytes_per_s, cov)."""
    import jax
    import jax.numpy as jnp

    n = mb * (1 << 20) // 4
    x = jnp.ones((n,), jnp.float32)

    @jax.jit
    def triad(a):
        return a * 1.0001 + 0.5

    med, cov = timeit_median(lambda: jax.block_until_ready(triad(x)),
                             reps=reps)
    return 2.0 * n * 4 / med, cov


def measure_stream_bw(*, mb: int = 32,
                      reps: int = 5) -> Dict[str, Tuple[float, float]]:
    """Weight-streaming bandwidth both ways, the quantity the LIME
    pipeline lives or dies on. ``h2d``: host buffer -> device
    (``jax.device_put``), prices `load_bw`/`host_bw`; ``d2h``: device ->
    host (``np.asarray``), prices `load_write_bw`. Returns
    {dir: (bytes_per_s, cov)}."""
    import jax

    nbytes = mb * (1 << 20)
    host = np.ones((nbytes // 4,), np.float32)
    dev = jax.block_until_ready(jax.device_put(host))

    h2d_med, h2d_cov = timeit_median(
        lambda: jax.block_until_ready(jax.device_put(host)), reps=reps)
    # force a copy: on CPU backends np.asarray aliases the buffer and
    # would "measure" a no-op at absurd bandwidth
    d2h_med, d2h_cov = timeit_median(lambda: np.array(dev, copy=True),
                                     reps=reps)
    return {"h2d": (nbytes / h2d_med, h2d_cov),
            "d2h": (nbytes / d2h_med, d2h_cov)}


# -- end-to-end decode loop ----------------------------------------------------

def measure_decode_loop(arch: str = "gemma3-1b", *, batch: int = 1,
                        prompt: int = 32, gen: int = 8,
                        reps: int = 3) -> Dict[str, float]:
    """MaxText-style decode microbenchmark on a real (smoke-sized) model:
    timed prefill (prompt pass), insert (prefilled cache round-tripped
    through the device, the per-slot KV adoption copy), and generate
    (autoregressive ``decode_step`` loop). Returns raw observations for
    MeasuredProfile.extras — an end-to-end cross-check on the primitive
    measurements, not a pricing input."""
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_smoke_config
    import repro.models.model as M

    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    max_len = prompt + gen + 8
    tokens = jnp.ones((batch, prompt), jnp.int32)

    def do_prefill():
        cache = M.init_cache(cfg, batch, max_len)
        logits, cache = M.prefill(cfg, params, tokens, cache)
        return jax.block_until_ready(logits), cache

    prefill_s, prefill_cov = timeit_median(do_prefill, reps=reps, warmup=1)
    _, cache = do_prefill()

    leaves = jax.tree_util.tree_leaves(cache)
    cache_bytes = float(sum(x.size * x.dtype.itemsize for x in leaves
                            if hasattr(x, "dtype")))
    insert_s, _ = timeit_median(
        lambda: jax.block_until_ready(jax.device_put(cache)),
        reps=reps, warmup=1)

    tok = jnp.ones((batch, 1), jnp.int32)

    def do_generate():
        c, t = cache, tok
        for _ in range(gen):
            logits, c = M.decode_step(cfg, params, c, t)
            t = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return jax.block_until_ready(logits)

    gen_s, gen_cov = timeit_median(do_generate, reps=reps, warmup=1)
    per_tok = gen_s / gen
    return {
        "prefill_s": prefill_s,
        "prefill_cov": prefill_cov,
        "insert_s": insert_s,
        "insert_bytes": cache_bytes,
        "insert_bw": cache_bytes / insert_s if insert_s > 0 else float("nan"),
        "decode_tok_s": batch / per_tok if per_tok > 0 else float("nan"),
        "decode_cov": gen_cov,
        "prompt": float(prompt),
        "gen": float(gen),
    }


# -- assembly ------------------------------------------------------------------

def device_kind() -> str:
    import jax
    d = jax.devices()[0]
    return getattr(d, "device_kind", None) or d.platform


def measure_profile(name: str, base: DeviceProfile, *,
                    reps: int = 5, mb: int = 32,
                    decode_arch: Optional[str] = None) -> MeasuredProfile:
    """Run the harness and assemble a MeasuredProfile. `base` supplies
    the non-throughput knobs (mem_bytes stays an enforced budget) and
    the analytic comparison for `check_sane`. ``decode_arch`` optionally
    adds the end-to-end decode-loop observations to extras (slower, so
    off by default)."""
    flops, flops_cov = measure_flops(reps=reps)
    mem_bw, mem_cov = measure_mem_bw(mb=max(mb, 16), reps=reps)
    stream = measure_stream_bw(mb=mb, reps=reps)
    (h2d, h2d_cov), (d2h, d2h_cov) = stream["h2d"], stream["d2h"]

    extras: Dict[str, float] = {}
    if decode_arch:
        extras.update(measure_decode_loop(decode_arch))

    vals = dict(name=name, mem_bytes=base.mem_bytes, flops=flops,
                mem_bw=mem_bw, load_bw=h2d, load_write_bw=d2h, host_bw=h2d)
    conf = {"flops": flops_cov, "mem_bw": mem_cov, "load_bw": h2d_cov,
            "load_write_bw": d2h_cov, "host_bw": h2d_cov}
    prof = MeasuredProfile(
        device_kind=device_kind(), source="measured",
        measured_at=datetime.datetime.now(datetime.timezone.utc).isoformat(),
        n_trials=reps, confidence=conf, extras=extras, **vals)
    prof.check_sane(base)
    return prof


def measure_fields(base: DeviceProfile) -> Tuple[Dict[str, float],
                                                 Dict[str, float]]:
    """Primitive measurements only, as ({field: value}, {field: cov})
    over MEASURED_FIELDS — the pieces `measure_profile` assembles."""
    prof = measure_profile(base.name, base, reps=3, mb=16)
    return ({f: getattr(prof, f) for f in MEASURED_FIELDS},
            dict(prof.confidence))
