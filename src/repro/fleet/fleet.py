"""Fleet executor: co-steps N replica schedulers on one timeline
(DESIGN.md §16).

Each replica keeps its own backend clock (virtual time for sim replicas,
wall time for engine replicas). The executor merges three event streams —
request arrivals, scheduled drains, scheduled joins — into time order and,
before acting on an event at time t, steps every replica that still has
*actionable* work due by t, laggard first. A routing decision therefore
sees every replica's true state as of the arrival: queue depths, free KV
pages, and radix digests are live, not start-of-run snapshots.

Elastic membership:

  drain(name, at_s)   at t: the replica stops receiving admits (the
                      router skips draining members) but keeps stepping —
                      every request already routed to it finishes. When
                      its last request drains the replica retires
                      (live=False, retired_s stamped) and the router
                      forgets its sessions/digest.
  join(replica, at_s) at t: the replica's clock is advanced to t and it
                      enters the candidate set; load-based scoring pulls
                      traffic onto the empty newcomer within a few admits
                      (asserted in tests).

run() returns a FleetResult: pooled request records plus per-replica
partitions, from which report() builds the exact merged FleetReport.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.obs import trace as tr_ev
from repro.obs.trace import get_tracer
from repro.serving.scheduler import Request

from repro.fleet.replica import Replica
from repro.fleet.report import FleetResult
from repro.fleet.router import FleetRouter, RouterConfig


class Fleet:
    """N replicas + a router + a membership timeline."""

    def __init__(self, replicas: List[Replica],
                 router: Optional[FleetRouter] = None,
                 config: Optional[RouterConfig] = None):
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names: {names}")
        self.replicas: List[Replica] = list(replicas)
        self.router = router if router is not None \
            else FleetRouter(config or RouterConfig())
        self._events = []            # (at_s, seq, kind, payload)
        self._seq = 0
        self.shed: List[Request] = []

    def replica(self, name: str) -> Replica:
        for r in self.replicas:
            if r.name == name:
                return r
        raise KeyError(f"no replica {name!r}; "
                       f"have {[r.name for r in self.replicas]}")

    # -- elastic membership ------------------------------------------------------
    def drain(self, name: str, at_s: float = 0.0) -> None:
        """Schedule `name` to stop receiving admits at `at_s`; it retires
        once every request already routed to it has finished."""
        self.replica(name)                       # fail fast on a typo
        self._events.append((at_s, self._seq, "drain", name))
        self._seq += 1
        self._events.sort(key=lambda e: (e[0], e[1]))

    def join(self, replica: Replica, at_s: float = 0.0) -> None:
        """Schedule `replica` to enter the candidate set at `at_s`."""
        if any(r.name == replica.name for r in self.replicas):
            raise ValueError(f"replica {replica.name!r} already present")
        self._events.append((at_s, self._seq, "join", replica))
        self._seq += 1
        self._events.sort(key=lambda e: (e[0], e[1]))

    def _apply_membership(self, until: float) -> None:
        tr = get_tracer()
        while self._events and self._events[0][0] <= until:
            at_s, _, kind, payload = self._events.pop(0)
            if kind == "drain":
                rep = self.replica(payload)
                rep.draining = True
                if tr is not None:
                    tr.instant(tr_ev.FLEET_DRAIN, ts=at_s,
                               track=tr_ev.TRACK_ROUTER,
                               args={"replica": rep.name,
                                     "outstanding": rep.outstanding})
                self._maybe_retire(rep)          # idle drain: immediate
            else:                                # join
                rep: Replica = payload
                rep.backend.advance_to(at_s)
                rep.live = True
                rep.joined_s = at_s
                self.replicas.append(rep)
                if tr is not None:
                    tr.instant(tr_ev.FLEET_JOIN, ts=at_s,
                               track=tr_ev.TRACK_ROUTER,
                               args={"replica": rep.name})

    def _maybe_retire(self, rep: Replica) -> None:
        if rep.draining and rep.live and rep.outstanding == 0:
            rep.live = False
            rep.retired_s = rep.now()
            self.router.forget(rep.name)
            tr = get_tracer()
            if tr is not None:
                tr.instant(tr_ev.FLEET_DRAINED, ts=rep.retired_s,
                           track=tr_ev.TRACK_ROUTER,
                           args={"replica": rep.name})

    # -- co-stepping -------------------------------------------------------------
    def _advance(self, until: float) -> None:
        """Step every replica with actionable work due by `until` whose
        clock lags it, laggard first — replica states are current as of
        `until` when this returns."""
        while True:
            cands = [r for r in self.replicas
                     if r.live and r.now() < until and r.has_work(until)]
            if not cands:
                return
            rep = min(cands, key=lambda r: (r.now(), r.index))
            rep.step()
            self._maybe_retire(rep)

    def _drain_all(self) -> None:
        """Run every replica to completion (end of the arrival stream)."""
        while True:
            busy = [r for r in self.replicas if r.live and r.has_work()]
            if not busy:
                return
            rep = min(busy, key=lambda r: (r.now(), r.index))
            rep.step()
            self._maybe_retire(rep)

    # -- the run loop ------------------------------------------------------------
    def run(self, requests: List[Request]) -> FleetResult:
        """Route and serve `requests` (plus any scheduled drain/join
        events) to completion."""
        arrivals = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        for req in arrivals:
            t = req.arrival_s
            self._advance(t)
            self._apply_membership(t)
            target = self.router.route(req, self.replicas)
            if target is None:
                req.rejected = True
                self.shed.append(req)
                continue
            target.submit(req)
        # membership events past the last arrival still apply (a drain
        # scheduled late must retire its replica before reporting)
        self._apply_membership(math.inf)
        self._drain_all()
        per: Dict[str, List[Request]] = {}
        pooled: List[Request] = list(self.shed)
        for rep in self.replicas:
            recs = rep.finish()
            per[rep.name] = recs
            pooled.extend(recs)
        return FleetResult(requests=pooled, per_replica=per,
                           replicas=list(self.replicas),
                           router=self.router, shed=list(self.shed))
