"""Jit'd public wrapper for the RWKV6 WKV kernel.

Model layout in: r/k/v/w (B, S, H, dh), u (H, dh), state (B, H, dh, dh).
Pads time to the block multiple with identity steps (w = 1, k = 0: the state
passes through unchanged and padded outputs are sliced off) and dh to the
128-lane width (padded lanes carry zero k/v so they never contaminate S).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.rwkv6_scan.kernel import wkv_kernel


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv(r, k, v, w, u, state, *, block_t=None, interpret=None):
    """r/k/v/w: (B, S, H, dh); u: (H, dh); state: (B, H, dh, dh) fp32.
    Returns (out (B, S, H, dh) fp32, new_state fp32). block_t=None
    consults the tuned table (repro.kernels.tuning); 256 with none
    installed."""
    if interpret is None:
        interpret = _auto_interpret()
    B, S, H, dh = r.shape
    block_t = tuning.resolve("rwkv6_scan", S, dh, "block_t", block_t)
    bt = min(block_t, max(S, 8))
    pad_t = (-S) % bt
    pad_d = (-dh) % 128 if not interpret else 0

    def to_kernel(x, pad_value=0.0):
        x = jnp.moveaxis(x, 1, 2)                     # (B, H, S, dh)
        if pad_t or pad_d:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_t), (0, pad_d)),
                        constant_values=pad_value)
        return x

    rk = to_kernel(r.astype(jnp.float32))
    kk = to_kernel(k.astype(jnp.float32))
    vk = to_kernel(v.astype(jnp.float32))
    wk = to_kernel(w.astype(jnp.float32), pad_value=1.0)
    uk = jnp.pad(u.astype(jnp.float32), ((0, 0), (0, pad_d))) if pad_d else \
        u.astype(jnp.float32)
    sk = jnp.pad(state, ((0, 0), (0, 0), (0, pad_d), (0, pad_d))) if pad_d \
        else state

    out, s_final = wkv_kernel(rk, kk, vk, wk, uk, sk, block_t=bt,
                              interpret=interpret)
    out = jnp.moveaxis(out[:, :, :S, :dh], 1, 2)      # (B, S, H, dh)
    return out, s_final[:, :, :dh, :dh]
