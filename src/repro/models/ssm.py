"""State-space / linear-recurrence blocks.

* RWKV6 ("Finch") time-mix with **data-dependent decay** (the paper's headline
  feature) + channel-mix FFN. [arXiv:2404.05892]
* Mamba-style selective-SSM heads used by Hymba's hybrid blocks.
  [arXiv:2411.13676]

Projections are computed for the whole sequence in parallel (MXU-friendly);
only the O(dh^2)-per-step recurrence runs under ``lax.scan``. The Pallas kernel
(kernels/rwkv6_scan) keeps that recurrence's state in VMEM across the time loop.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.spec import ParamSpec
from repro.models.modules import rms_norm


# ----------------------------------------------------------------------------
# RWKV6
# ----------------------------------------------------------------------------
def rwkv_timemix_specs(d: int, n_heads: int, head_dim: int,
                       decay_lora: int = 64) -> dict:
    return {
        "mu_r": ParamSpec((d,), ("embed",), init="small"),
        "mu_k": ParamSpec((d,), ("embed",), init="small"),
        "mu_v": ParamSpec((d,), ("embed",), init="small"),
        "mu_g": ParamSpec((d,), ("embed",), init="small"),
        "mu_w": ParamSpec((d,), ("embed",), init="small"),
        "wr": ParamSpec((d, d), ("embed", "ffn")),
        "wk": ParamSpec((d, d), ("embed", "ffn")),
        "wv": ParamSpec((d, d), ("embed", "ffn")),
        "wg": ParamSpec((d, d), ("embed", "ffn")),
        "wo": ParamSpec((d, d), ("ffn", "embed")),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x_w A) B))
        "w0": ParamSpec((d,), ("embed",), init="small"),
        "wA": ParamSpec((d, decay_lora), ("embed", None), init="small"),
        "wB": ParamSpec((decay_lora, d), (None, "embed"), init="small"),
        "u": ParamSpec((n_heads, head_dim), (None, None), init="small"),
        "ln_out": ParamSpec((d,), ("embed",), init="zeros"),
    }


def rwkv_channelmix_specs(d: int, d_ff: int) -> dict:
    return {
        "mu_k": ParamSpec((d,), ("embed",), init="small"),
        "mu_r": ParamSpec((d,), ("embed",), init="small"),
        "wk": ParamSpec((d, d_ff), ("embed", "ffn")),
        "wv": ParamSpec((d_ff, d), ("ffn", "embed")),
        "wr": ParamSpec((d, d), ("embed", None)),
    }


def _token_shift(x, last):
    """x: (B,S,D); last: (B,D) token preceding x[:,0]. Returns shifted seq + new last."""
    shifted = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return shifted, x[:, -1, :]


def _rwkv_proj(p, x, xs):
    def mix(mu):
        return x + mu.astype(x.dtype) * (xs - x)
    r = mix(p["mu_r"]) @ p["wr"]
    k = mix(p["mu_k"]) @ p["wk"]
    v = mix(p["mu_v"]) @ p["wv"]
    g = jax.nn.silu(mix(p["mu_g"]) @ p["wg"])
    xw = mix(p["mu_w"]).astype(jnp.float32)
    logw = p["w0"].astype(jnp.float32) + jnp.tanh(xw @ p["wA"].astype(jnp.float32)) \
        @ p["wB"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw))                  # (B,S,D) in (0,1)
    return r, k, v, g, w


def wkv_scan_ref(r, k, v, w, u, state):
    """Sequential WKV recurrence (the pure-jnp oracle for the Pallas kernel).

    r,k,v,w: (B, S, H, dh) [w fp32]; u: (H, dh); state: (B, H, dh, dh) fp32.
    Returns (out (B,S,H,dh) fp32, new_state).
      a_t = k_t^T v_t;  o_t = r_t (S + u*a_t);  S' = w_t*S_rows + a_t
    (decay applies along the k-index of the state.)
    """
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    uf = u.astype(jnp.float32)

    if r.shape[1] == 1:
        # single decode token: unrolled. A length-1 scan is pure overhead,
        # and a nested lax.scan inside a partial-auto shard_map (the LIME
        # engine's slot loop) fatally asserts in old XLA's partitioner.
        r1, k1, v1, w1 = rf[:, 0], kf[:, 0], vf[:, 0], w[:, 0]
        a = k1[..., :, None] * v1[..., None, :]
        o = jnp.einsum("bhk,bhkd->bhd", r1,
                       state + uf[None, :, :, None] * a)
        return o[:, None], w1[..., :, None] * state + a

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                     # (B,H,dh)
        a = k_t[..., :, None] * v_t[..., None, :]    # (B,H,dh,dh)
        o = jnp.einsum("bhk,bhkd->bhd", r_t, S + uf[None, :, :, None] * a)
        S = w_t[..., :, None] * S + a
        return S, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, w))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1), state


def rwkv_timemix(p, x, last_x, state, *, n_heads: int, head_dim: int,
                 norm_eps: float, impl: str = "ref"):
    """x: (B,S,D). Returns (out, new_last_x, new_state)."""
    B, S, D = x.shape
    xs, new_last = _token_shift(x, last_x)
    r, k, v, g, w = _rwkv_proj(p, x, xs)
    hd = (B, S, n_heads, head_dim)
    r, k, v, w = (t.reshape(hd) for t in (r, k, v, w))
    if impl == "pallas":
        from repro.kernels.rwkv6_scan import ops as wkv_ops
        out, state = wkv_ops.wkv(r, k, v, w, p["u"], state)
    else:
        out, state = wkv_scan_ref(r, k, v, w, p["u"], state)
    out = rms_norm(out.reshape(B, S, D).astype(x.dtype), p["ln_out"], norm_eps)
    return (out * g) @ p["wo"], new_last, state


def rwkv_channelmix(p, x, last_x):
    xs, new_last = _token_shift(x, last_x)
    xk = x + p["mu_k"].astype(x.dtype) * (xs - x)
    xr = x + p["mu_r"].astype(x.dtype) * (xs - x)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), new_last


# ----------------------------------------------------------------------------
# Mamba-style selective SSM heads (Hymba)
# ----------------------------------------------------------------------------
def mamba_head_specs(d: int, n_heads: int, head_dim: int, state: int,
                     conv_k: int = 4) -> dict:
    d_inner = n_heads * head_dim
    return {
        "in_x": ParamSpec((d, d_inner), ("embed", "ffn")),
        "in_z": ParamSpec((d, d_inner), ("embed", "ffn")),
        "conv": ParamSpec((conv_k, d_inner), (None, "ffn"), init="small"),
        "w_dt": ParamSpec((d, n_heads), ("embed", None), init="small"),
        "dt_bias": ParamSpec((n_heads,), (None,), init="small"),
        "w_B": ParamSpec((d, state), ("embed", None), init="small"),
        "w_C": ParamSpec((d, state), ("embed", None), init="small"),
        "A_log": ParamSpec((n_heads,), (None,), init="small"),
        "D_skip": ParamSpec((n_heads,), (None,), init="small"),
        "ln": ParamSpec((d_inner,), ("ffn",), init="zeros"),
    }


def _causal_conv(x, kernel, conv_state):
    """Depthwise causal conv. x: (B,S,C), kernel: (K,C), conv_state: (B,K-1,C)."""
    K = kernel.shape[0]
    xp = jnp.concatenate([conv_state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * kernel[i] for i in range(K))
    return out, xp[:, -(K - 1):, :] if K > 1 else conv_state


def ssm_scan_ref(xh, dt, B_in, C_in, A, state):
    """Selective scan. xh: (B,S,H,dh); dt: (B,S,H); B_in/C_in: (B,S,N);
    A: (H,) negative; state: (B,H,N,dh) fp32."""
    decay = jnp.exp(A[None, None, :, None] * dt[..., None])        # (B,S,H,1)

    def step(h, inp):
        x_t, dt_t, b_t, c_t, dec_t = inp
        dbx = (dt_t[..., None, None] * b_t[:, None, :, None]
               * x_t[..., None, :].astype(jnp.float32))            # (B,H,N,dh)
        h = dec_t[..., None] * h + dbx
        y = jnp.einsum("bn,bhnd->bhd", c_t, h)
        return h, y

    xs = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B_in.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C_in.astype(jnp.float32), 1, 0),
          jnp.moveaxis(decay, 1, 0))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def mamba_forward(p, x, conv_state, ssm_state, *, n_heads: int, head_dim: int,
                  ssm_size: int, norm_eps: float, impl: str = "ref"):
    """x: (B,S,D) -> (out_heads (B,S,H*dh), new_conv_state, new_ssm_state)."""
    B, S, D = x.shape
    xi = x @ p["in_x"]
    z = x @ p["in_z"]
    xi, conv_state = _causal_conv(xi, p["conv"], conv_state)
    xi = jax.nn.silu(xi)
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    Bm = x @ p["w_B"]
    Cm = x @ p["w_C"]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xi.reshape(B, S, n_heads, head_dim)
    if impl == "pallas":
        from repro.kernels.ssm_scan import ops as ssm_ops
        y, ssm_state = ssm_ops.ssm_scan(xh, dt, Bm, Cm, A, ssm_state)
    else:
        y, ssm_state = ssm_scan_ref(xh, dt, Bm, Cm, A, ssm_state)
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(B, S, -1).astype(x.dtype)
    y = rms_norm(y, p["ln"], norm_eps) * jax.nn.silu(z)
    return y, conv_state, ssm_state
