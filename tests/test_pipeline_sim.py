"""Discrete-event simulator behaviour: schedule shape, overlap, and the
paper's qualitative claims (golden-trace style assertions)."""

from repro.configs.registry import get_config
from repro.core.baselines import BASELINES, simulate_pp_offload
from repro.core.cost_model import CostEnv, Workload
from repro.core.pipeline_sim import simulate_lime
from repro.core.profiles import (AGX_ORIN_32, AGX_ORIN_64, XAVIER_NX_16,
                                 env_E3, env_lowmem, mbps)

CFG70 = get_config("llama3.3-70b")
CFG13 = get_config("llama2-13b")


def test_lime_steady_state_latency_stable():
    env = CostEnv(env_E3(), mbps(200), Workload(CFG70, mb=1, ctx=1024))
    r = simulate_lime(env, CFG70.n_layers, 50, n_emp=1024, prompt=1024)
    lats = [t.latency for t in r.per_token]
    assert max(lats[5:]) / min(lats[5:]) < 1.5     # no drift without pressure


def test_interleave_covers_load_when_idle_sufficient():
    """With fast loaders + slow compute, offload hides completely."""
    fat = AGX_ORIN_64.scaled_mem(0.35)
    env = CostEnv([fat] * 4, mbps(200), Workload(CFG70, mb=1, ctx=512))
    r = simulate_lime(env, CFG70.n_layers, 20, n_emp=512, prompt=512)
    assert not r.oom
    base = CostEnv([AGX_ORIN_64] * 8, mbps(200),
                   Workload(CFG70, mb=1, ctx=512))
    rb = simulate_lime(base, CFG70.n_layers, 20, n_emp=512, prompt=512)
    # offloading ~58 GB/step over ~10 GB/s aggregate NVMe: the interleave
    # keeps the step under ~9x the all-resident fleet (raw serial load
    # alone would be ~6.5x the all-resident step before any compute)
    assert not r.oom
    assert r.ms_per_token < 9 * rb.ms_per_token


def test_bursty_throughput_exceeds_sporadic():
    env1 = CostEnv(env_E3(), mbps(200), Workload(CFG70, mb=1, ctx=1024))
    r1 = simulate_lime(env1, CFG70.n_layers, 30, n_micro=1, n_emp=1024,
                       prompt=1024)
    env4 = CostEnv(env_E3(), mbps(200),
                   Workload(CFG70, mb=1, ctx=1024, n_micro=4))
    r4 = simulate_lime(env4, CFG70.n_layers, 30, n_micro=4, n_emp=1024,
                       prompt=1024)
    # 4 streams per step: per-request-token latency must beat 4x sporadic
    assert r4.ms_per_token / 4 < r1.ms_per_token


def test_lime_beats_or_matches_all_baselines_under_pressure():
    env = CostEnv(env_lowmem(1), mbps(200),
                  Workload(CFG70, mb=1, ctx=2048, n_micro=1))
    lime = simulate_lime(env, CFG70.n_layers, 40, n_emp=2048, prompt=2048)
    assert not lime.oom
    for name, fn in BASELINES.items():
        b = fn(env, CFG70.n_layers, 40, n_micro=1, prompt=2048)
        if b.oom:
            continue
        assert b.ms_per_token >= 0.95 * lime.ms_per_token, name


def test_paper_oom_pattern_lowmem():
    """Figs 15-17: PP/EdgeShard/Galaxy OOM under Setting >= 2; LIME never."""
    env = CostEnv(env_lowmem(2), mbps(200),
                  Workload(CFG70, mb=1, ctx=2048, n_micro=1))
    lime = simulate_lime(env, CFG70.n_layers, 10, n_emp=2048, prompt=2048)
    assert not lime.oom
    assert BASELINES["pp"](env, CFG70.n_layers, 10, prompt=2048).oom
    assert BASELINES["edgeshard"](env, CFG70.n_layers, 10, prompt=2048).oom
    assert BASELINES["galaxy"](env, CFG70.n_layers, 10, prompt=2048).oom
    assert not BASELINES["tpi-llm"](env, CFG70.n_layers, 10,
                                    prompt=2048).oom


def test_naive_pp_offload_pays_uncovered_loads():
    """Fig 3a/4a: in-stage offloading leaves loading latency exposed;
    LIME's interleave covers it."""
    tight = [XAVIER_NX_16.scaled_mem(0.6), AGX_ORIN_32.scaled_mem(0.6),
             AGX_ORIN_64.scaled_mem(0.6), AGX_ORIN_64.scaled_mem(0.6),
             AGX_ORIN_64.scaled_mem(0.6)]
    env = CostEnv(tight, mbps(200), Workload(CFG70, mb=1, ctx=1024))
    lime = simulate_lime(env, CFG70.n_layers, 25, n_emp=1024, prompt=1024)
    naive = simulate_pp_offload(env, CFG70.n_layers, 25, prompt=1024)
    assert not lime.oom and not naive.oom
    assert naive.ms_per_token > 1.2 * lime.ms_per_token


def test_bandwidth_drop_does_not_stall():
    env = CostEnv(env_lowmem(1), mbps(200),
                  Workload(CFG70, mb=1, ctx=2048))

    def schedule(tok):
        return mbps(50 if 10 <= tok < 20 else 200)

    r = simulate_lime(env, CFG70.n_layers, 40, n_emp=2048, prompt=2048,
                      bandwidth_schedule=schedule)
    fixed = simulate_lime(env, CFG70.n_layers, 40, n_emp=2048, prompt=2048)
    assert r.ms_per_token < 3.0 * fixed.ms_per_token


def test_ablation_ordering_matches_paper():
    """Tab. V: full LIME <= no-KV-transfer <= no-planner (same ordering;
    magnitudes are regime-dependent, EXPERIMENTS.md §Repro)."""
    env = CostEnv(env_lowmem(1), mbps(200),
                  Workload(CFG70, mb=1, ctx=2048, n_micro=5))
    full = simulate_lime(env, CFG70.n_layers, 60, n_micro=5, n_emp=2048,
                         prompt=2048)
    no_pl = simulate_lime(env, CFG70.n_layers, 60, n_micro=5, n_emp=2048,
                          prompt=2048, planner_full_layer_fallback=True)
    assert full.ms_per_token <= no_pl.ms_per_token * 1.02
