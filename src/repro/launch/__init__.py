# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and
# must only be imported as the entry module (python -m repro.launch.dryrun).
from repro.launch.mesh import make_production_mesh, make_test_mesh  # noqa: F401
