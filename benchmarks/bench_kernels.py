"""Kernel micro-benchmarks: wall time of the jnp reference paths on CPU
(the Pallas kernels are TPU-target; interpret mode measures Python, not
hardware) + the analytic VMEM working-set / arithmetic-intensity numbers
the BlockSpec choices are based on.

Timing goes through the autotuner's shared clock discipline
(repro.tune.measure.timeit_median: warmup, block_until_ready,
median-of-reps) so these numbers are comparable with the sweep's."""
import jax
import jax.numpy as jnp

from repro.models.attention import chunked_attention, decode_attention_ref
from repro.models.ssm import wkv_scan_ref
from repro.tune.measure import timeit_median


def _time(fn, *args, reps=3):
    med, _ = timeit_median(lambda: jax.block_until_ready(fn(*args)),
                           reps=reps, warmup=1)
    return med * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    # prefill attention reference
    q = jax.random.normal(key, (1, 2048, 8, 128), jnp.bfloat16)
    kv = jax.random.normal(key, (1, 2048, 2, 128), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True,
                                                  window=None))
    us = _time(f, q, kv, kv)
    print(f"chunked_attention 2k x 8H/2KV x 128: {us:.0f} us/call (CPU ref)")
    rows.append(("flash_ref_2k", us))
    # decode attention
    q1 = jax.random.normal(key, (8, 1, 8, 128), jnp.bfloat16)
    c = jax.random.normal(key, (8, 4096, 2, 128), jnp.bfloat16)
    ids = jnp.arange(4096, dtype=jnp.int32)
    g = jax.jit(lambda q, k, v: decode_attention_ref(q, k, v, ids,
                                                     jnp.int32(4095),
                                                     window=None))
    us = _time(g, q1, c, c)
    print(f"decode_attention 4k cache x B8: {us:.0f} us/call (CPU ref)")
    rows.append(("decode_ref_4k", us))
    # wkv
    r = jax.random.normal(key, (2, 256, 4, 64))
    w = jax.nn.sigmoid(jax.random.normal(key, (2, 256, 4, 64)))
    u = jax.random.normal(key, (4, 64)) * 0.1
    s0 = jnp.zeros((2, 4, 64, 64))
    h = jax.jit(lambda r, k, v, w: wkv_scan_ref(r, k, v, w, u, s0))
    us = _time(h, r, r, r, w)
    print(f"wkv_scan 256 x 4H x 64: {us:.0f} us/call (CPU ref)")
    rows.append(("wkv_ref_256", us))

    # static kernel design numbers (TPU-target)
    bq, bk, dh = 128, 512, 128
    vmem = (2 * bq + 3 * bk) * dh * 2 + bq * dh * 4
    print(f"flash kernel VMEM working set @({bq},{bk},{dh}): "
          f"{vmem/1e6:.2f} MB of 16 MB")
    ai = (2 * bq * bk * dh * 2) / ((bq + 2 * bk) * dh * 2)
    print(f"flash kernel arithmetic intensity: {ai:.0f} flops/byte "
          f"(v5e ridge ~240)")
    return rows


if __name__ == "__main__":
    run()
