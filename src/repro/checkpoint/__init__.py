from repro.checkpoint.store import save, restore  # noqa: F401
