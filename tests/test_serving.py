"""LIME-Serve: traffic determinism, scheduler admission/queueing edge
cases, metrics, and backend parity (DESIGN.md §9)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config, get_smoke_config
from repro.core.cost_model import CostEnv, Workload
from repro.core.profiles import env_E3, mbps
from repro.serving import (ContinuousBatchingScheduler, Request,
                           SchedulerConfig, SimBackend, make_arrivals,
                           requests_from_arrivals, summarize)
from repro.serving.metrics import percentile
from repro.serving.traffic import bursty, poisson, sporadic


# ----------------------------------------------------------------------------
# traffic generators
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("pattern", ["sporadic", "bursty", "poisson"])
def test_traffic_deterministic_under_seed(pattern):
    a = make_arrivals(pattern, 16, seed=42, prompt_len=(32, 96),
                      max_new_tokens=(8, 64))
    b = make_arrivals(pattern, 16, seed=42, prompt_len=(32, 96),
                      max_new_tokens=(8, 64))
    c = make_arrivals(pattern, 16, seed=43, prompt_len=(32, 96),
                      max_new_tokens=(8, 64))
    assert a == b
    assert a != c                       # seed actually feeds the stream
    assert all(ev.time_s >= 0 and ev.max_new_tokens >= 1 for ev in a)
    times = [ev.time_s for ev in a]
    assert times == sorted(times)


def test_traffic_shapes():
    sp = sporadic(5, gap_s=2.0, jitter=0.0, seed=0)
    gaps = np.diff([e.time_s for e in sp])
    assert np.allclose(gaps, 2.0)
    bu = bursty(8, burst_size=4, gap_s=3.0, seed=0)
    assert [e.time_s for e in bu] == [0.0] * 4 + [3.0] * 4
    po = poisson(64, rate_rps=2.0, seed=1)
    mean_gap = np.mean(np.diff([e.time_s for e in po]))
    assert 0.2 < mean_gap < 1.2         # ~1/rate with sampling noise


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["sporadic", "bursty", "poisson"]),
       st.integers(0, 2 ** 31 - 1), st.integers(1, 40),
       st.integers(1, 256), st.integers(1, 128))
def test_traffic_seeded_determinism_property(pattern, seed, n, plen, mnew):
    """Any (pattern, seed, n, length ranges): identical seeds produce
    identical streams, times are sorted and non-negative, lengths land in
    the requested ranges."""
    kw = dict(seed=seed, prompt_len=(1, plen), max_new_tokens=(1, mnew))
    a = make_arrivals(pattern, n, **kw)
    b = make_arrivals(pattern, n, **kw)
    assert a == b
    assert len(a) == n
    times = [ev.time_s for ev in a]
    assert times == sorted(times) and all(t >= 0.0 for t in times)
    assert all(1 <= ev.prompt_len <= plen
               and 1 <= ev.max_new_tokens <= mnew for ev in a)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.floats(0.5, 8.0), st.floats(0.0, 0.9))
def test_sporadic_rate_property(seed, gap_s, jitter):
    """Sporadic gaps stay inside gap_s * (1 ± jitter)."""
    evs = sporadic(30, gap_s=gap_s, jitter=jitter, seed=seed)
    gaps = np.diff([ev.time_s for ev in evs])
    lo, hi = gap_s * (1.0 - jitter), gap_s * (1.0 + jitter)
    assert np.all(gaps >= lo - 1e-9) and np.all(gaps <= hi + 1e-9)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8), st.floats(0.5, 8.0))
def test_bursty_rate_property(seed, burst, gap_s):
    """Bursty arrivals come in exact groups of burst_size, gap_s apart."""
    evs = bursty(4 * burst, burst_size=burst, gap_s=gap_s, seed=seed)
    times = [ev.time_s for ev in evs]
    for i, t in enumerate(times):
        assert t == (i // burst) * gap_s


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.25, 8.0))
def test_poisson_rate_property(seed, rate):
    """Poisson mean inter-arrival ~ 1/rate (law of large numbers at
    n=400: within 35% of the nominal rate is a 5-sigma-ish band)."""
    evs = poisson(400, rate_rps=rate, seed=seed)
    mean_gap = np.mean(np.diff([ev.time_s for ev in evs]))
    assert 0.65 / rate < mean_gap < 1.35 / rate


def test_trace_replay_sorts_rows():
    rows = [(5.0, 16, 4), (0.0, 8, 2), (2.5, 32, 8)]
    evs = make_arrivals("trace", trace=rows)
    assert [e.time_s for e in evs] == [0.0, 2.5, 5.0]


# ----------------------------------------------------------------------------
# metrics
# ----------------------------------------------------------------------------
def test_percentile_nearest_rank():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 50) == 2.0
    assert percentile(xs, 99) == 4.0
    assert np.isnan(percentile([], 50))
    # exact-rank cases: ceil, not round-half-to-even
    assert percentile([1.0, 2.0], 50) == 1.0
    assert percentile(list(range(1, 101)), 99) == 99
    assert percentile(list(range(1, 101)), 50) == 50


# ----------------------------------------------------------------------------
# scheduler over the simulator backend
# ----------------------------------------------------------------------------
def _sim_backend(slots: int, arch: str = "llama2-13b", prompt: int = 64):
    cfg = get_config(arch)
    w = Workload(cfg, mb=1, ctx=prompt, n_micro=slots)
    env = CostEnv(env_E3(), mbps(200), w)
    return SimBackend(env, n_slots=slots, prompt_tokens=prompt)


def test_empty_queue_serves_nothing():
    sched = ContinuousBatchingScheduler(_sim_backend(2), SchedulerConfig())
    assert sched.serve([]) == []


def test_burst_larger_than_slots_drains_fully():
    """12 simultaneous arrivals through 4 micro-batch slots: everyone is
    served, later waves queue (TTFT ordering reflects it)."""
    arr = bursty(12, burst_size=12, gap_s=0.0, prompt_len=32,
                 max_new_tokens=8, seed=0)
    sched = ContinuousBatchingScheduler(_sim_backend(4), SchedulerConfig())
    done = sched.serve(requests_from_arrivals(arr))
    served = [r for r in done if not r.rejected]
    assert len(served) == 12
    assert all(r.done and r.generated == 8 for r in served)
    ttfts = sorted(r.ttft_s for r in served)
    assert ttfts[-1] > ttfts[0]         # the overflow wave actually waited


def test_queue_overflow_sheds():
    arr = bursty(6, burst_size=6, gap_s=0.0, prompt_len=16,
                 max_new_tokens=4, seed=0)
    sched = ContinuousBatchingScheduler(
        _sim_backend(1), SchedulerConfig(max_queue=2))
    done = sched.serve(requests_from_arrivals(arr))
    served = [r for r in done if not r.rejected]
    shed = [r for r in done if r.rejected]
    # simultaneous arrivals hit intake before batching: 2 queue, 4 shed
    assert len(shed) == 4 and len(served) == 2
    assert all(r.finish_s is None for r in shed)
    assert all(r.done for r in served)


def test_kv_budget_defers_admission():
    """With a budget of ~1.5 requests, co-residency never exceeds one."""
    arr = bursty(4, burst_size=4, gap_s=0.0, prompt_len=32,
                 max_new_tokens=8, seed=0)
    reqs = requests_from_arrivals(arr)
    per_req = reqs[0].kv_tokens
    sched = ContinuousBatchingScheduler(
        _sim_backend(4), SchedulerConfig(kv_budget_tokens=per_req * 3 // 2))
    done = sched.serve(reqs)
    served = sorted((r for r in done if not r.rejected),
                    key=lambda r: r.first_token_s)
    assert len(served) == 4
    # serialized by the KV gate: each starts only after the previous ends
    for a, b in zip(served, served[1:]):
        assert b.first_token_s >= a.finish_s - 1e-9


def test_oversized_request_rejected_not_deadlocked():
    r = Request(0, None, max_new_tokens=10_000, prompt_len=10_000)
    sched = ContinuousBatchingScheduler(
        _sim_backend(2), SchedulerConfig(kv_budget_tokens=100))
    done = sched.serve([r])
    assert done[0].rejected and done[0].finish_s is None


def test_engine_per_slot_cap_rejects_overlong_request():
    """Pooled slot capacity must not admit a request whose prompt+max_new
    exceeds the statically-shaped per-slot cache (max_len)."""
    import jax

    from repro.models import model as M
    from repro.serving import EngineBackend

    cfg = get_smoke_config("gemma3-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    be = EngineBackend(cfg, params, n_slots=4, max_len=32)
    reqs = [Request(0, None, max_new_tokens=8, prompt_len=40),   # > 32
            Request(1, None, max_new_tokens=4, prompt_len=8)]    # fits
    done = ContinuousBatchingScheduler(be, SchedulerConfig()).serve(reqs)
    by = {r.rid: r for r in done}
    assert by[0].rejected and by[0].finish_s is None
    assert by[1].done and by[1].generated == 4


def test_engine_heterogeneous_batch_respects_padded_positions():
    """Left-padding makes co-scheduled requests share position space:
    max(prompt in batch) + own max_new must fit max_len, so a long-prompt
    and a long-generation request must NOT ride the same epoch."""
    import jax

    from repro.models import model as M
    from repro.serving import EngineBackend

    cfg = get_smoke_config("gemma3-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    be = EngineBackend(cfg, params, n_slots=2, max_len=32)
    reqs = [Request(0, None, max_new_tokens=2, prompt_len=28,
                    arrival_s=0.0),
            Request(1, None, max_new_tokens=28, prompt_len=4,
                    arrival_s=0.0)]
    assert not be.fits_batch([reqs[0]], reqs[1])
    done = ContinuousBatchingScheduler(be, SchedulerConfig()).serve(reqs)
    by = {r.rid: r for r in done}
    assert by[0].done and by[0].generated == 2
    assert by[1].done and by[1].generated == 28
    # serialized into separate epochs, not co-scheduled
    assert by[1].first_token_s >= by[0].finish_s - 1e-9


def test_single_token_request_exact_count():
    arr = [Request(0, None, max_new_tokens=1, prompt_len=8)]
    done = ContinuousBatchingScheduler(
        _sim_backend(2), SchedulerConfig()).serve(arr)
    assert done[0].done and done[0].generated == 1
    assert done[0].finish_s == done[0].first_token_s


def test_idle_gap_jumps_virtual_clock():
    arr = [Request(0, None, max_new_tokens=2, prompt_len=8, arrival_s=0.0),
           Request(1, None, max_new_tokens=2, prompt_len=8,
                   arrival_s=500.0)]
    be = _sim_backend(1)
    done = ContinuousBatchingScheduler(be, SchedulerConfig()).serve(arr)
    r1 = next(r for r in done if r.rid == 1)
    assert r1.first_token_s >= 500.0    # clock jumped, no phantom work
    assert r1.ttft_s < 100.0            # and latency is from *arrival*


def test_planner_fired_by_serving_load():
    """Serving load past the allocation's reserved length walks the
    OnlinePlanner ladder (admission accounting -> Eq. 5 thresholds): the
    bench_ablation regime, driven through the scheduler instead of a
    fixed token loop."""
    from repro.core.offline_scheduler import allocate
    from repro.core.online_planner import OnlinePlanner
    from repro.core.profiles import env_lowmem

    cfg = get_config("llama3.3-70b")
    w = Workload(cfg, mb=1, ctx=1024, n_micro=1)
    env = CostEnv(env_lowmem(1), mbps(200), w)
    r = allocate(env, cfg.n_layers, n_emp=1024)
    assert r.feasible
    probe = OnlinePlanner(env, r.plan, horizon_tokens=2 ** 20)
    first_ts = min(l[0].threshold_tokens for l in probe.ladders if l)
    prompt = max(first_ts - 16, 64)     # generation crosses the threshold

    # kv-transfer off: delegation would defer exactly the thresholds this
    # test wants to see fire (that interplay is bench_ablation's subject)
    be = SimBackend(env, plan=r.plan, n_slots=1, prompt_tokens=prompt,
                    use_kv_transfer=False)
    arr = sporadic(1, gap_s=1.0, jitter=0.0, prompt_len=prompt,
                   max_new_tokens=64, seed=0)
    sched = ContinuousBatchingScheduler(be, SchedulerConfig())
    done = sched.serve(requests_from_arrivals(arr))
    assert all(r_.done for r_ in done)
    assert any(st.plan_idx > 0 for st in be.sim.planner.states)


def test_bursty_throughput_at_least_sporadic():
    """The acceptance invariant behind bench_serving --pattern all."""
    results = {}
    for pattern, slots in (("sporadic", 1), ("bursty", 4)):
        arr = make_arrivals(pattern, 8, seed=0, prompt_len=64,
                            max_new_tokens=16, gap_s=4.0,
                            **({"burst_size": 4} if pattern == "bursty"
                               else {}))
        sched = ContinuousBatchingScheduler(_sim_backend(slots),
                                            SchedulerConfig())
        done = sched.serve(requests_from_arrivals(arr))
        results[pattern] = summarize(done, pattern=pattern, backend="sim")
    assert results["bursty"].throughput_tok_s >= \
        results["sporadic"].throughput_tok_s


# ----------------------------------------------------------------------------
# backend parity: simulator vs engine-substrate (single-device fallback)
# ----------------------------------------------------------------------------
def test_backend_parity_token_counts():
    """Same arrival stream through both substrates: every request gets
    exactly its requested token count on each, and completion sets the
    same bookkeeping."""
    import jax

    from repro.models import model as M
    from repro.serving import EngineBackend

    arr = make_arrivals("poisson", 6, seed=5, rate_rps=4.0,
                        prompt_len=(4, 8), max_new_tokens=(1, 7))

    sim_done = ContinuousBatchingScheduler(
        _sim_backend(2, prompt=8), SchedulerConfig()).serve(
            requests_from_arrivals(arr))

    cfg = get_smoke_config("gemma3-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng_be = EngineBackend(cfg, params, n_slots=2, max_len=32)
    eng_done = ContinuousBatchingScheduler(
        eng_be, SchedulerConfig()).serve(requests_from_arrivals(arr))

    sim_counts = {r.rid: r.generated for r in sim_done}
    eng_counts = {r.rid: r.generated for r in eng_done}
    want = {i: ev.max_new_tokens for i, ev in enumerate(arr)}
    assert sim_counts == want
    assert eng_counts == want
    # engine emits real token ids, one per generated step
    assert all(len(r.output) == r.generated for r in eng_done)
    for done in (sim_done, eng_done):
        assert all(r.done and r.finish_s >= r.first_token_s >= r.arrival_s
                   for r in done)


def test_engine_backend_paged_decode_serves_tokens():
    """The paged single-device decode path (block-table pools +
    paged attention, kvcache/paged_decode) behind EngineBackend: same
    request counts, real token ids, pages freed after the run."""
    import jax

    from repro.models import model as M
    from repro.serving import EngineBackend

    arr = make_arrivals("bursty", 4, seed=3, burst_size=2, gap_s=0.5,
                        prompt_len=(4, 8), max_new_tokens=(2, 6))
    cfg = get_smoke_config("gemma3-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    be = EngineBackend(cfg, params, n_slots=2, max_len=32, paged=True,
                       page_size=8)
    done = ContinuousBatchingScheduler(be, SchedulerConfig()).serve(
        requests_from_arrivals(arr))
    want = {i: ev.max_new_tokens for i, ev in enumerate(arr)}
    assert {r.rid: r.generated for r in done} == want
    assert all(len(r.output) == r.generated for r in done)
    assert be._paged_cache is not None
    assert be._paged_cache.pages_in_use > 0   # epoch pools live until next


# ----------------------------------------------------------------------------
# sampling: nucleus (top_p) + top_k filtering math
# ----------------------------------------------------------------------------
def test_filter_logits_top_p_keeps_minimal_nucleus():
    import jax.numpy as jnp

    from repro.serving.sampling import NEG_INF, SamplerConfig, filter_logits

    # probs (descending): 0.4, 0.3, 0.2, 0.1 -> top_p=0.6 keeps the first
    # two (mass before token 0 is 0.0 < 0.6, before token 1 is 0.4 < 0.6,
    # before token 2 is 0.7 >= 0.6)
    p = np.array([0.4, 0.3, 0.2, 0.1])
    logits = jnp.asarray(np.log(p))[None, :]
    out = np.asarray(filter_logits(logits,
                                   SamplerConfig(temperature=1.0, top_p=0.6),
                                   4))[0]
    kept = out > NEG_INF / 2
    assert kept.tolist() == [True, True, False, False]
    # renormalized distribution over the nucleus
    probs = np.exp(out - out.max())
    probs /= probs.sum()
    assert np.allclose(probs[:2], [0.4 / 0.7, 0.3 / 0.7], atol=1e-6)


def test_filter_logits_top_p_always_keeps_head():
    import jax.numpy as jnp

    from repro.serving.sampling import NEG_INF, SamplerConfig, filter_logits

    p = np.array([0.99, 0.005, 0.005])
    out = np.asarray(filter_logits(jnp.asarray(np.log(p))[None, :],
                                   SamplerConfig(temperature=1.0,
                                                 top_p=0.01), 3))[0]
    kept = out > NEG_INF / 2
    assert kept.tolist() == [True, False, False]


def test_filter_logits_top_k_then_top_p_compose():
    import jax.numpy as jnp

    from repro.serving.sampling import NEG_INF, SamplerConfig, filter_logits

    lv = jnp.asarray([[5.0, 4.0, 3.0, 2.0, 1.0]])
    out = np.asarray(filter_logits(
        lv, SamplerConfig(temperature=1.0, top_k=3, top_p=0.99), 5))[0]
    kept = (out > NEG_INF / 2).tolist()
    assert kept == [True, True, True, False, False]
    # temperature rescales surviving logits
    out2 = np.asarray(filter_logits(
        lv, SamplerConfig(temperature=2.0), 5))[0]
    assert np.allclose(out2, np.asarray(lv)[0] / 2.0)


def test_sample_top_p_respects_nucleus():
    import jax
    import jax.numpy as jnp

    from repro.serving.sampling import SamplerConfig, sample

    p = np.array([0.5, 0.3, 0.1, 0.1])
    logits = jnp.tile(jnp.asarray(np.log(p)), (64, 1))
    toks = np.asarray(sample(logits,
                             SamplerConfig(temperature=1.0, top_p=0.7,
                                           seed=0),
                             jax.random.PRNGKey(0), 4))
    assert set(toks.tolist()) <= {0, 1}   # outside the nucleus never drawn


# ----------------------------------------------------------------------------
# server front door: RequestQueue + LimeServer end-to-end
# ----------------------------------------------------------------------------
def test_request_queue_fifo_rids_and_drain():
    from repro.serving import RequestQueue

    q = RequestQueue()
    a = q.submit([1, 2, 3], max_new_tokens=4)
    b = q.submit([4], max_new_tokens=2, now=1.5)
    c = q.submit([5, 6], max_new_tokens=1)
    assert (a.rid, b.rid, c.rid) == (0, 1, 2)
    assert len(q) == 3
    assert b.arrival_s == 1.5 and b.prompt_len == 1
    first = q.pop_up_to(2)
    assert [r.rid for r in first] == [0, 1]
    assert len(q) == 1
    rest = q.drain()
    assert [r.rid for r in rest] == [2]
    assert len(q) == 0 and q.drain() == []
    # rid assignment continues after a drain
    d = q.submit([7], max_new_tokens=1)
    assert d.rid == 3


def test_request_queue_pop_up_to_zero_and_overshoot():
    from repro.serving import RequestQueue

    q = RequestQueue()
    q.submit([1], max_new_tokens=1)
    assert q.pop_up_to(0) == []
    assert len(q.pop_up_to(10)) == 1


def test_lime_server_end_to_end_over_engine_backend():
    """LimeServer smoke: queue -> scheduler -> EngineBackend fallback,
    real token ids, latency bookkeeping, repeat serve_all() calls."""
    import jax

    from repro.models import model as M
    from repro.serving import LimeServer

    cfg = get_smoke_config("gemma3-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = LimeServer(cfg, params, max_len=32, pattern="bursty")
    assert srv.serve_all() == []          # empty queue: no work
    r0 = srv.queue.submit(np.array([3, 1, 4], np.int32), max_new_tokens=5)
    r1 = srv.queue.submit(np.array([1, 5], np.int32), max_new_tokens=3)
    done = srv.serve_all()
    assert {r.rid for r in done} == {r0.rid, r1.rid}
    assert len(srv.queue) == 0
    by = {r.rid: r for r in done}
    assert by[r0.rid].generated == 5 and len(by[r0.rid].output) == 5
    assert by[r1.rid].generated == 3 and len(by[r1.rid].output) == 3
    assert all(0 <= t < cfg.vocab_size
               for r in done for t in r.output)
    assert all(r.done and r.finish_s >= r.first_token_s >= r.arrival_s
               for r in done)
    # second batch reuses the cached backend; arrivals re-base onto its
    # clock so queueing latency is not inflated by the first batch
    r2 = srv.queue.submit(np.array([2, 7, 1, 8], np.int32),
                          max_new_tokens=2)
    done2 = srv.serve_all()
    assert len(done2) == 1 and done2[0].rid == r2.rid
    assert done2[0].done and done2[0].ttft_s < 60.0


def test_lime_server_sporadic_single_slot():
    import jax

    from repro.models import model as M
    from repro.serving import LimeServer

    cfg = get_smoke_config("gemma3-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = LimeServer(cfg, params, max_len=32, pattern="sporadic")
    assert srv.slots == 1
    srv.queue.submit(np.array([2, 3], np.int32), max_new_tokens=2)
    srv.queue.submit(np.array([4], np.int32), max_new_tokens=2)
    done = srv.serve_all()
    served = sorted((r for r in done if not r.rejected),
                    key=lambda r: r.first_token_s)
    assert len(served) == 2
    # one slot: strictly serialized epochs
    assert served[1].first_token_s >= served[0].finish_s - 1e-9


# ----------------------------------------------------------------------------
# metrics: per-request decode pace percentiles
# ----------------------------------------------------------------------------
def test_summarize_decode_tok_s_percentiles():
    reqs = []
    # 11 tokens in 1s after TTFT -> 10 tok/s; 5 tokens in 2s -> 2 tok/s
    for rid, (t_first, t_fin, gen) in enumerate(
            ((1.0, 2.0, 11), (1.0, 3.0, 5))):
        r = Request(rid, None, max_new_tokens=gen, prompt_len=4,
                    arrival_s=0.0)
        r.generated = gen
        r.first_token_s = t_first
        r.finish_s = t_fin
        r.done = True
        reqs.append(r)
    rep = summarize(reqs, pattern="x", backend="y")
    assert rep.decode_tok_s_p50 == pytest.approx(2.0)
    assert rep.decode_tok_s_p99 == pytest.approx(10.0)
    # single-token requests contribute no decode-pace sample
    one = Request(9, None, max_new_tokens=1, prompt_len=1)
    one.generated, one.first_token_s, one.finish_s, one.done = \
        1, 0.5, 0.5, True
    rep2 = summarize([one], pattern="x", backend="y")
    assert np.isnan(rep2.decode_tok_s_p50)


# ----------------------------------------------------------------------------
# online memory adaptation (DESIGN.md §13)
# ----------------------------------------------------------------------------
def _serve_adapt(adapt: bool):
    from repro.serving import cli_arrivals
    cfg = get_config("llama2-13b")
    slots = 8
    env = CostEnv(env_E3(), mbps(200),
                  Workload(cfg, mb=1, ctx=64, n_micro=slots))
    backend = SimBackend(env, n_slots=slots, prompt_tokens=64, adapt=adapt)
    arrivals = cli_arrivals("bursty", 16, seed=0, prompt_len=64,
                            max_new_tokens=96, gap_s=8.0, burst_size=slots)
    budget = int(2.0 * (64 + 96))
    sched = ContinuousBatchingScheduler(backend, SchedulerConfig(
        kv_budget_tokens=budget, kv_policy="paged", page_size=16,
        preempt="recompute"))
    done = sched.serve(requests_from_arrivals(arrivals))
    rep = summarize(done, stats=sched.stats)
    return done, rep


def test_adaptive_backend_reclaims_instead_of_preempting():
    """Retier headroom absorbs KV pressure: the scheduler demotes weight
    blocks (growing the page pool) BEFORE preempting, so the adaptive run
    completes every request with fewer preemptions and no worse p50
    latency than the static plan — the bench_adaptation invariant at
    tier-1 scale."""
    done_s, rep_s = _serve_adapt(False)
    done_a, rep_a = _serve_adapt(True)
    for done in (done_s, done_a):
        assert all(r.done and not r.rejected for r in done)
    assert rep_s.n_preempted > 0          # pressure is real
    assert rep_s.retier_events == 0       # static plan never retiers
    assert rep_a.n_preempted <= rep_s.n_preempted
    assert rep_a.retier_events > 0
    assert rep_a.retier_reclaimed_pages > 0
    assert rep_a.hbm_returned_bytes > 0
    assert rep_a.latency_p50_s <= rep_s.latency_p50_s + 1e-9
