from repro.data.pipeline import (SyntheticCorpus,  # noqa: F401
                                 PackedBatches, make_batches)  # noqa: F401
