"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (dryrun.py sets XLA_FLAGS before any jax init).

Mesh semantics (DESIGN.md §5):
  single-pod: (data=16, model=16)        — 256 chips (one v5e pod)
  multi-pod:  (pod=2, data=16, model=16) — 512 chips

'data'  — batch parallel for train/prefill; doubles as the LIME pipeline
          *stage* axis in the serving engine.
'model' — tensor parallel (heads / ffn / experts / vocab).
'pod'   — batch/replica parallel across pods (bursty request replicas).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_stage: int = 4, n_model: int = 2):
    """Small mesh for CPU tests (requires forced host device count)."""
    return jax.make_mesh((n_stage, n_model), ("data", "model"))
