"""Host-side page-layout movement (DESIGN.md §10).

One pair of loops owns the dense <-> paged byte movement so the engine's
seed_cache adoption (core/engine._through_pages) and the single-device
paged decode's seeding (kvcache/paged_decode.PagedDecodeCache.seed) can
never diverge on partial-last-page arithmetic:

  dense  (L, B, S, *rest)          per-slot contiguous token rows
  pool   (L, P, page_size, *rest)  physical pages, any owner

Both operate in place on numpy buffers and copy only the first `ctx`
tokens of each slot — the tail past ctx holds no tokens (its pages are
unallocated), garbage there is masked positionally by every consumer.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.kvcache.allocator import BlockTable


def scatter_to_pages(pool_buf: np.ndarray, dense: np.ndarray,
                     tables: Sequence[BlockTable], ctx: int) -> np.ndarray:
    """dense[:, b, :ctx] -> pool_buf pages named by tables[b]."""
    ps = pool_buf.shape[2]
    for b, t in enumerate(tables):
        for j, pid in enumerate(t.pages):
            fill = min(ctx - j * ps, ps)
            if fill > 0:
                pool_buf[:, pid, :fill] = dense[:, b, j * ps:j * ps + fill]
    return pool_buf


def gather_from_pages(dense_out: np.ndarray, pool_buf: np.ndarray,
                      tables: Sequence[BlockTable], ctx: int) -> np.ndarray:
    """Inverse of scatter_to_pages: pool pages -> dense_out[:, b, :ctx]."""
    ps = pool_buf.shape[2]
    for b, t in enumerate(tables):
        for j, pid in enumerate(t.pages):
            fill = min(ctx - j * ps, ps)
            if fill > 0:
                dense_out[:, b, j * ps:j * ps + fill] = \
                    pool_buf[:, pid, :fill]
    return dense_out
