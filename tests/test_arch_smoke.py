"""Per-architecture smoke tests (assignment deliverable f).

For every assigned arch: instantiate the REDUCED config (2 layers,
d_model<=512, <=4 experts), run one forward and one train step on CPU,
assert output shapes and no NaNs; plus a prefill+decode round trip.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import Family
from repro.configs.registry import (ASSIGNED_ARCHS, PAPER_MODELS,
                                    get_smoke_config)
from repro.models import model as M
from repro.optim.adamw import AdamW, constant_schedule
from repro.training.trainer import make_train_step

ALL = ASSIGNED_ARCHS + PAPER_MODELS


def _inputs(cfg, key, B=2, S=16):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = None
    enc_out = None
    params = M.init_params(cfg, key)
    if cfg.family == Family.VLM:
        fe = jnp.ones((B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == Family.ENCDEC:
        enc_out = M.encode(cfg, params,
                           jnp.ones((B, cfg.frontend_tokens, cfg.d_model),
                                    jnp.bfloat16))
    return params, tokens, fe, enc_out


@pytest.mark.parametrize("arch", ALL)
def test_forward_shapes_finite(arch, rng):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    params, tokens, fe, enc_out = _inputs(cfg, rng)
    logits, aux = M.forward(cfg, params, tokens, frontend_embeds=fe,
                            enc_out=enc_out)
    B, S = tokens.shape
    S_out = S + (fe.shape[1] if fe is not None else 0)
    pv = M.round_up(cfg.vocab_size, 256)
    assert logits.shape == (B, S_out, pv)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step(arch, rng):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, rng)
    opt = AdamW(lr=constant_schedule(1e-3))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, None))
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend_tokens:
        batch["frontend_embeds"] = jnp.ones(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    new_params, new_state, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # parameters actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
        > 0 for a, b in zip(jax.tree.leaves(params),
                            jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_roundtrip(arch, rng):
    cfg = get_smoke_config(arch)
    params, tokens, fe, enc_out = _inputs(cfg, rng, B=2, S=8)
    cache = M.init_cache(cfg, 2, 32, enc_out=enc_out)
    if cfg.family == Family.ENCDEC:
        cache = M.seed_cross_kv(cfg, params, cache, enc_out)
    logits, cache = M.prefill(cfg, params, tokens, cache,
                              frontend_embeds=fe, enc_out=enc_out)
    assert logits.shape[1] == 1
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    enc_len = 0 if enc_out is None else enc_out.shape[1]
    for _ in range(3):
        logits, cache = M.decode_step(cfg, params, cache, tok,
                                      enc_len=enc_len)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
    assert int(cache["pos"]) == tokens.shape[1] + (fe.shape[1] if fe is not None else 0) + 3


@pytest.mark.parametrize("arch", ["gemma3-1b", "hymba-1.5b", "rwkv6-3b"])
def test_long_context_ring_decode(arch, rng):
    """Sub-quadratic archs decode past the window with a ring/state cache."""
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, rng)
    max_len = 32
    cache = M.init_cache(cfg, 1, max_len, long_mode=True)
    tok = jnp.ones((1, 1), jnp.int32)
    for i in range(max_len + 8):          # run PAST the cache length
        logits, cache = M.decode_step(cfg, params, cache, tok,
                                      long_mode=True)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), i
    if "k" in cache:
        assert cache["k"].shape[2] <= max_len   # ring, not grown


def test_decode_matches_forward_last_token(rng):
    """Losslessness at model level: decode path == forward path logits."""
    cfg = get_smoke_config("internlm2-1.8b")
    params = M.init_params(cfg, rng)
    tokens = jax.random.randint(rng, (2, 12), 0, cfg.vocab_size)
    full, _ = M.forward(cfg, params, tokens)
    cache = M.init_cache(cfg, 2, 32)
    pre, cache = M.prefill(cfg, params, tokens[:, :-1], cache)
    step, _ = M.decode_step(cfg, params, cache, tokens[:, -1:])
    err = float(jnp.abs(full[:, -1].astype(jnp.float32)
                        - step[:, 0].astype(jnp.float32)).max())
    assert err < 0.15, err     # bf16 accumulation-order tolerance
