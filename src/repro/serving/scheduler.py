"""Continuous-batching scheduler for LIME-Serve (DESIGN.md §9).

One scheduler in front of both execution substrates (engine and simulator,
behind the InferenceBackend protocol in `serving/backend.py`):

  admission   a request is admitted only when the fleet's KV budget can
              hold its worst case (prompt + max_new tokens) alongside every
              co-resident request — the same per-request accounting whose
              token totals drive the OnlinePlanner's TS thresholds inside
              the simulator backend (paper Eq. 5).
  queueing    FIFO past the admission gate; arrivals beyond `max_queue`
              are rejected (shed) rather than queued forever.
  batching    up to `backend.n_slots` requests ride the pipeline's
              micro-batch slots. Backends that support it
              (`can_join_running`) refill freed slots mid-flight —
              continuous batching; epoch backends (the real engine, whose
              batch membership is fixed at cache-seed time) drain a batch,
              then form the next.

The loop is clock-agnostic: `backend.now()` is wall time for the engine
and virtual time for the simulator, so the same scheduler produces both
real measurements and discrete-event predictions.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request, from arrival to completion."""
    rid: int
    prompt: Optional[np.ndarray]    # (S,) int32 token ids; None -> length-only
    max_new_tokens: int
    arrival_s: float = 0.0
    prompt_len: int = 0
    output: List[int] = dataclasses.field(default_factory=list)
    generated: int = 0              # tokens emitted (simulated backends
                                    # emit steps without real token ids)
    done: bool = False
    rejected: bool = False
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None

    def __post_init__(self):
        if self.prompt is not None:
            self.prompt = np.asarray(self.prompt, np.int32)
            self.prompt_len = len(self.prompt)
        self.max_new_tokens = max(int(self.max_new_tokens), 1)

    @property
    def kv_tokens(self) -> int:
        """Worst-case KV footprint in tokens (admission currency)."""
        return self.prompt_len + self.max_new_tokens

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.first_token_s is None \
            else self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.finish_s is None \
            else self.finish_s - self.arrival_s


def requests_from_arrivals(arrivals, *, start_rid: int = 0) -> List[Request]:
    """ArrivalEvents (traffic.py) -> length-only Requests."""
    return [Request(start_rid + i, None, ev.max_new_tokens,
                    arrival_s=ev.time_s, prompt_len=ev.prompt_len)
            for i, ev in enumerate(arrivals)]


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_queue: int = 4096                    # beyond this: shed (rejected)
    kv_budget_tokens: Optional[int] = None   # None -> ask the backend


class ContinuousBatchingScheduler:
    """Drives an InferenceBackend through an arrival stream."""

    def __init__(self, backend, config: SchedulerConfig = SchedulerConfig()):
        self.backend = backend
        self.config = config
        self._kv_in_use = 0
        budget = config.kv_budget_tokens
        if budget is None:
            budget = backend.kv_budget_tokens()
        self.kv_budget = budget               # None -> unbounded
        # per-request ceiling (e.g. the engine's statically-shaped per-slot
        # cache): pooled headroom must not admit an over-long request
        cap_fn = getattr(backend, "max_request_tokens", None)
        self.max_request = cap_fn() if cap_fn else None
        # optional batch-composition constraint (engine: left-padding
        # makes co-scheduled requests share position space)
        self._fits_batch = getattr(backend, "fits_batch", None)

    # -- admission -------------------------------------------------------------
    def _admits(self, req: Request) -> bool:
        if self.kv_budget is None:
            return True
        return self._kv_in_use + req.kv_tokens <= self.kv_budget

    def _oversized(self, req: Request) -> bool:
        """Can never be served, even on an idle fleet."""
        if self.max_request is not None and req.kv_tokens > self.max_request:
            return True
        return self.kv_budget is not None and req.kv_tokens > self.kv_budget

    # -- main loop ---------------------------------------------------------------
    def serve(self, requests: List[Request]) -> List[Request]:
        """Run every request to completion (or rejection); returns them all,
        completion order first, then rejected."""
        pending: Deque[Request] = deque(
            sorted(requests, key=lambda r: r.arrival_s))
        queue: Deque[Request] = deque()
        active: Dict[int, Request] = {}       # slot -> request
        done: List[Request] = []
        shed: List[Request] = []

        def intake(now: float):
            while pending and pending[0].arrival_s <= now:
                r = pending.popleft()
                if self._oversized(r) or len(queue) >= self.config.max_queue:
                    r.rejected = True
                    shed.append(r)
                else:
                    queue.append(r)

        while pending or queue or active:
            intake(self.backend.now())

            if not active:
                if not queue:
                    if not pending:   # intake shed the last arrivals
                        break
                    # idle: jump to the next arrival
                    self.backend.advance_to(pending[0].arrival_s)
                    intake(self.backend.now())
                    continue
                batch, slots = [], list(range(self.backend.n_slots))
                while queue and len(batch) < len(slots) \
                        and self._admits(queue[0]) \
                        and (self._fits_batch is None or not batch
                             or self._fits_batch(batch, queue[0])):
                    r = queue.popleft()
                    self._kv_in_use += r.kv_tokens
                    batch.append(r)
                if not batch:
                    # head-of-line blocked on KV budget with nothing in
                    # flight: impossible unless budget < kv_tokens, which
                    # _oversized() already shed — defensive guard
                    r = queue.popleft()
                    r.rejected = True
                    shed.append(r)
                    continue
                first = self.backend.start_batch(batch)
                t = self.backend.now()
                for slot, (r, tok) in enumerate(zip(batch, first)):
                    active[slot] = r
                    r.first_token_s = t
                    r.generated += 1
                    if tok is not None:
                        r.output.append(tok)
                    if r.generated >= r.max_new_tokens:  # max_new == 1
                        self._finish(r, slot, active, done, t)
                continue

            # one decode step for every live slot
            emitted = self.backend.decode_active(sorted(active))
            t = self.backend.now()
            for slot, tok in emitted.items():
                r = active[slot]
                r.generated += 1
                if tok is not None:
                    r.output.append(tok)
                if r.generated >= r.max_new_tokens:
                    self._finish(r, slot, active, done, t)

            # continuous batching: refill freed slots mid-flight
            if self.backend.can_join_running and active:
                intake(self.backend.now())
                free = [s for s in range(self.backend.n_slots)
                        if s not in active]
                for slot in free:
                    if not queue or not self._admits(queue[0]):
                        break
                    if self._fits_batch is not None and not \
                            self._fits_batch(list(active.values()),
                                             queue[0]):
                        break
                    r = queue.popleft()
                    self._kv_in_use += r.kv_tokens
                    active[slot] = r
                    tok = self.backend.join(slot, r)
                    r.first_token_s = self.backend.now()
                    r.generated += 1
                    if tok is not None:
                        r.output.append(tok)
                    if r.generated >= r.max_new_tokens:  # max_new == 1
                        self._finish(r, slot, active, done,
                                     self.backend.now())

        return done + shed

    def _finish(self, r: Request, slot: int, active: Dict[int, Request],
                done: List[Request], t: float) -> None:
        r.done = True
        r.finish_s = t
        self._kv_in_use -= r.kv_tokens
        done.append(r)
        del active[slot]
        self.backend.release(slot)
