"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig2a,e1e2e3,...]

Prints per-scenario results and writes benchmarks/results.csv. Roofline
terms for the (arch x shape x mesh) grid come from the dry-run
(`python -m repro.launch.dryrun --all`), not from here — this harness runs
the paper-reproduction simulator (EXPERIMENTS.md §Repro).
"""
import argparse
import glob
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

SUITES = {
    "fig2a": ("benchmarks.bench_motivation", "Fig 2a motivation"),
    "e1e2e3": ("benchmarks.bench_paper_e1e2e3", "Figs 12-14 E1/E2/E3"),
    "lowmem": ("benchmarks.bench_lowmem", "Figs 15-17 low-memory"),
    "varbw": ("benchmarks.bench_bandwidth", "Fig 18 varying bandwidth"),
    "ablation": ("benchmarks.bench_ablation", "Tab V ablation"),
    "kernels": ("benchmarks.bench_kernels", "kernel microbench"),
    "specdec": ("benchmarks.bench_specdec", "speculative vs AR decode"),
    "selfspec": ("benchmarks.bench_selfspec", "resident self-draft vs n-gram "
                                              "across retier rungs"),
    "prefix": ("benchmarks.bench_prefix", "radix prefix cache + chunked "
                                          "prefill"),
    "adaptation": ("benchmarks.bench_adaptation", "online memory adaptation "
                                                  "vs static plan"),
    "fleet": ("benchmarks.bench_fleet", "multi-replica router vs single "
                                        "pipeline"),
    "slo": ("benchmarks.bench_slo", "SLO engine: sketches, burn-rate "
                                    "shed, critical path"),
    "autotune": ("benchmarks.bench_autotune", "measured-profile plan vs "
                                              "analytic + kernel sweep + "
                                              "online re-fit"),
}

HISTORY_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_HISTORY.jsonl")


def append_history(suite, rows, elapsed_s, path=HISTORY_PATH):
    """One summary line per suite run, appended to BENCH_HISTORY.jsonl so
    drift is visible across commits without digging through CI logs.
    Timestamps/revisions come from the environment (BENCH_DATE,
    BENCH_GIT_REV or the checkout itself) so replays are deterministic."""
    rev = os.environ.get("BENCH_GIT_REV")
    if rev is None:
        try:
            import subprocess
            rev = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                capture_output=True, text=True, timeout=10,
                cwd=os.path.dirname(os.path.abspath(__file__)),
            ).stdout.strip() or None
        except (OSError, subprocess.SubprocessError):
            rev = None
    entry = {"suite": suite, "date": os.environ.get("BENCH_DATE"),
             "git_rev": rev, "elapsed_s": round(elapsed_s, 2),
             "rows": [r.csv() if hasattr(r, "csv") else list(r)
                      for r in rows]}
    with open(path, "a") as f:
        f.write(json.dumps(entry) + "\n")
    return entry


def read_history(path=HISTORY_PATH):
    """Parse BENCH_HISTORY.jsonl, skipping corrupt lines (appends from a
    killed run can truncate the tail)."""
    out = []
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(d, dict) and "suite" in d:
                out.append(d)
    return out


def check_baselines(baseline_dir=None):
    """Schema sanity over benchmarks/baselines/*.json: a baseline written
    by an older repo version carries an older (or no) schema_version —
    warn and keep going instead of KeyError-ing deep inside a comparison
    (serving/metrics.py SCHEMA_VERSION is the authority; report_from_dict
    fills fields the old schema lacked)."""
    from repro.obs.log import get_logger
    from repro.serving.metrics import SCHEMA_VERSION
    log = get_logger("benchmarks.run")
    if baseline_dir is None:
        baseline_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    "baselines")
    stale = []
    for path in sorted(glob.glob(os.path.join(baseline_dir, "*.json"))):
        try:
            with open(path) as f:
                d = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            log.warning(f"baseline {os.path.basename(path)}: unreadable "
                        f"({e}) — skipping")
            stale.append(path)
            continue
        # list-shaped baselines stamp each report; dict-shaped ones carry
        # one top-level version
        heads = d if isinstance(d, list) else [d]
        vers = {h.get("schema_version") for h in heads if isinstance(h, dict)}
        if vers != {SCHEMA_VERSION}:
            log.warning(
                f"baseline {os.path.basename(path)}: schema_version="
                f"{sorted(vers, key=str)} != current {SCHEMA_VERSION} — "
                f"comparisons may miss newer fields; regenerate with the "
                f"suite's --out flag")
            stale.append(path)
    hist = read_history()
    if hist:
        last = {}
        for h in hist:
            last[h["suite"]] = h
        log.info(f"bench history: {len(hist)} runs on record, latest per "
                 f"suite: "
                 + ", ".join(f"{s}@{h.get('git_rev') or '?'}"
                             for s, h in sorted(last.items())))
    return stale


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--csv", default="benchmarks/results.csv")
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(SUITES)
    check_baselines()

    all_rows = []
    for name in names:
        mod_name, title = SUITES[name]
        print(f"\n=== {title} ({name}) " + "=" * max(40 - len(title), 3))
        t0 = time.time()
        mod = __import__(mod_name, fromlist=["run"])
        rows = mod.run() or []
        elapsed = time.time() - t0
        print(f"--- {name} done in {elapsed:.1f}s")
        append_history(name, rows, elapsed)
        for r in rows:
            if hasattr(r, "csv"):
                all_rows.append(r.csv())
            else:
                all_rows.append(f"{name},{r[0]},{r[1]:.1f},ok")
    if args.csv and all_rows:
        with open(args.csv, "w") as f:
            f.write("scenario,method,ms_per_token,status\n")
            f.write("\n".join(all_rows) + "\n")
        print(f"\nwrote {len(all_rows)} rows to {args.csv}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
