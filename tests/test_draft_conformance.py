"""DraftProvider conformance + sampler losslessness properties
(DESIGN.md §11/§14).

Every provider — n-gram, small-model, resident-tier — must satisfy one
contract: propose(k) returns exactly k in-vocab tokens WITHOUT mutating
committed state (propose is a snapshot), observe() is the only way to
advance, and reset(h1 + h2) is indistinguishable from reset(h1) +
observe(h2). A rejected proposal must leave no trace (snapshot/advance
with rollback). ResidentDraft additionally survives retier() — the live
tier boundary moving under it — by replaying its committed history, and
spec rollback over paged KV must hold exactly the pages a non-spec decode
of the same committed tokens holds (no page leaks).

The sampler half: hypothesis properties pinning greedy_verify to the
argmax-chain prefix and rejection_verify to the accepted-prefix shape.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.specdec import (DepthController, NgramDraft, ResidentDraft,
                           SmallModelDraft, default_resident_ids,
                           greedy_verify, rejection_verify)
from repro.specdec.resident_draft import truncate_stack

HIST = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5]
KINDS = ("ngram", "model", "resident")


@pytest.fixture(params=KINDS)
def provider_factory(request, smoke_model):
    """Fresh-provider factory for one kind, with .kind/.vocab attached."""
    cfg, params = smoke_model
    kind = request.param

    def make(temperature=0.0):
        if kind == "ngram":
            return NgramDraft(max_ngram=3)
        if kind == "model":
            return SmallModelDraft(cfg, params, max_len=64,
                                   temperature=temperature)
        return ResidentDraft(cfg, params, default_resident_ids(cfg),
                             max_len=64, temperature=temperature)

    make.kind = kind
    make.vocab = cfg.vocab_size
    return make


# ----------------------------------------------------------------------------
# the shared provider contract
# ----------------------------------------------------------------------------
def test_propose_exact_length_and_vocab(provider_factory):
    d = provider_factory()
    d.reset(HIST)
    for k in (1, 3, 5):
        toks, probs = d.propose(k)
        toks = np.asarray(toks)
        assert toks.shape == (k,)
        assert toks.dtype == np.int32
        assert bool(((toks >= 0) & (toks < provider_factory.vocab)).all())
        assert probs is None            # temperature 0: point-mass draft


def test_stochastic_propose_probs_are_distributions(provider_factory):
    if provider_factory.kind == "ngram":
        pytest.skip("n-gram drafts are always point-mass")
    d = provider_factory(temperature=0.8)
    d.reset(HIST)
    toks, probs = d.propose(4)
    assert probs.shape == (4, provider_factory.vocab)
    assert bool((probs >= 0).all())
    assert np.allclose(probs.sum(-1), 1.0, atol=1e-6)
    # each proposed token must be drawable under its own row of q
    assert all(probs[i, int(t)] > 0 for i, t in enumerate(toks))


def test_propose_is_snapshot(provider_factory):
    d = provider_factory()
    d.reset(HIST)
    a, _ = d.propose(4)
    b, _ = d.propose(4)
    assert list(a) == list(b)


def test_rejected_proposal_never_pollutes(provider_factory):
    """propose(), then commit something the draft did NOT predict: the
    provider must behave exactly like a twin that never proposed."""
    a, b = provider_factory(), provider_factory()
    a.reset(HIST)
    b.reset(HIST)
    drafted, _ = a.propose(4)
    committed = [(int(drafted[0]) + 1) % provider_factory.vocab,
                 (int(drafted[0]) + 2) % provider_factory.vocab]
    a.observe(committed)
    b.observe(committed)
    pa, _ = a.propose(4)
    pb, _ = b.propose(4)
    assert list(pa) == list(pb)


def test_reset_equals_reset_plus_observe(provider_factory):
    a, b = provider_factory(), provider_factory()
    a.reset(HIST)
    b.reset(HIST[:5])
    b.observe(HIST[5:])
    pa, _ = a.propose(4)
    pb, _ = b.propose(4)
    assert list(pa) == list(pb)


# ----------------------------------------------------------------------------
# ResidentDraft specifics: truncation + retier replay
# ----------------------------------------------------------------------------
def test_truncate_stack_validates_and_shares_head(smoke_model):
    cfg, params = smoke_model
    with pytest.raises(ValueError):
        truncate_stack(cfg, params, [])
    with pytest.raises(ValueError):
        truncate_stack(cfg, params, [cfg.n_layers])
    sub_cfg, sub = truncate_stack(cfg, params, [0])
    assert sub_cfg.n_layers == 1
    # embeddings / final norm / LM head are SHARED (early-exit head), not
    # copied: every non-layer leaf must be the same object
    for k, v in params.items():
        if k != "layers":
            assert sub[k] is v, k


def test_default_resident_ids_bottom_of_stack(smoke_model):
    cfg, _ = smoke_model
    assert default_resident_ids(cfg) == \
        list(range(max(1, cfg.n_layers // 2)))
    assert default_resident_ids(cfg, 1) == [0]
    assert default_resident_ids(cfg, 10 ** 6) == list(range(cfg.n_layers))


def test_resident_retier_replays_history(smoke_model):
    """A retier event mid-sequence rebuilds the truncated stack and
    replays the committed history: afterwards the provider is
    indistinguishable from one built with the new tier from scratch."""
    cfg, params = smoke_model
    a = ResidentDraft(cfg, params, [0], max_len=64)
    a.reset(HIST)
    a.observe([7, 7])
    a.propose(3)                        # a pending (soon stale) snapshot
    a.retier(range(cfg.n_layers))       # promotion: full stack resident
    fresh = ResidentDraft(cfg, params, range(cfg.n_layers), max_len=64)
    fresh.reset(HIST + [7, 7])
    pa, _ = a.propose(4)
    pf, _ = fresh.propose(4)
    assert list(pa) == list(pf)
    # no-op retier must not re-jit the decode callables
    dec = a._decode
    a.retier(range(cfg.n_layers))
    assert a._decode is dec


# ----------------------------------------------------------------------------
# paged KV: spec rollback leaks no pages, and stays lossless
# ----------------------------------------------------------------------------
def test_resident_spec_paged_rollback_no_page_leak(smoke_model):
    """Greedy spec decode with ResidentDraft proposals over paged KV: the
    committed stream equals plain autoregressive decode, and after every
    partial-commit rollback the cache holds exactly the pages a non-spec
    twin decoding the same committed tokens holds."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.kvcache.paged_decode import PagedDecodeCache
    from repro.models import model as M
    cfg, params = smoke_model
    toks = jnp.asarray([HIST], jnp.int32)
    cache = M.init_cache(cfg, 1, 64)
    logits, cache = jax.jit(functools.partial(M.prefill, cfg))(
        params, toks, cache)
    first = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))

    # dense autoregressive reference
    c1 = dict(cache)
    cur = jnp.asarray([[first]], jnp.int32)
    want = [first]
    for _ in range(8):
        lg, c1 = M.decode_step(cfg, params, c1, cur)
        cur = jnp.argmax(lg[:, 0, :cfg.vocab_size], -1)[:, None] \
            .astype(jnp.int32)
        want.append(int(cur[0, 0]))

    draft = ResidentDraft(cfg, params, [0], max_len=64)
    draft.reset(HIST + [first])
    spec_pc = PagedDecodeCache(cfg, 1, 64, page_size=4)
    spec_pc.seed(cache)
    twin_pc = PagedDecodeCache(cfg, 1, 64, page_size=4)
    twin_pc.seed(cache)

    got, cur = [first], first
    while len(got) < 9:
        d, _ = draft.propose(3)
        mat = np.concatenate([np.array([[cur]], np.int32),
                              np.asarray(d)[None, :]], 1)
        lg = np.asarray(spec_pc.verify(params, mat), np.float32)
        committed = greedy_verify(lg[0], d, cfg.vocab_size)
        spec_pc.commit(len(committed))
        tcur = cur
        for t in committed:             # twin: plain decode, same tokens
            twin_pc.step(params, np.array([[tcur]], np.int32))
            tcur = t
        assert spec_pc.pages_in_use == twin_pc.pages_in_use
        draft.observe(committed)
        got.extend(committed)
        cur = committed[-1]
    assert got[:9] == want, (got, want)
    spec_pc.release()
    twin_pc.release()
    assert spec_pc.pool.alloc.used_pages == 0
    assert twin_pc.pool.alloc.used_pages == 0


# ----------------------------------------------------------------------------
# DepthController: retier-adaptive draft depth
# ----------------------------------------------------------------------------
def test_depth_controller_maps_acceptance_to_depth():
    d = DepthController(k_max=6, k_min=1)
    d.note_rung(0, prior=0.9)
    assert d.k() == 6                   # 0.9/0.1 = 9, clipped to k_max
    d.note_rung(1, prior=0.5)
    assert d.k() == 1                   # expected run of geometric(0.5)
    d.note_rung(2, prior=0.05)
    assert d.k() == 1                   # never below k_min


def test_depth_controller_remembers_revisited_rungs():
    d = DepthController(k_max=8, decay=0.5, prior=0.5)
    d.note_rung(0, prior=0.95)
    assert d.k() == 8
    for _ in range(8):
        d.note_round(8, 1)              # rung 0 turns out terrible
    shrunk = d.k()
    assert shrunk < 8
    d.note_rung(3, prior=0.9)           # demotion: unseen rung seeds high
    assert d.k() > shrunk
    d.note_rung(0)                      # revisit: EMA kept, prior ignored
    assert d.k() == shrunk
    d.note_round(0, 0)                  # empty round: no-op
    assert d.k() == shrunk


# ----------------------------------------------------------------------------
# sampler properties (hypothesis; deterministic stub when not installed)
# ----------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6), st.integers(2, 33))
def test_greedy_verify_commits_exact_argmax_chain(seed, k, V):
    """Greedy rejection commits precisely the argmax chain: accepted
    drafts up to the first mismatch, then the correction (or the bonus
    after full acceptance) — never more, never fewer, never a padded-
    vocab token."""
    r = np.random.default_rng(seed)
    lg = r.normal(size=(k + 1, V + 2))
    lg[:, V:] = 99.0                    # poisoned padding must be cut
    draft = r.integers(0, V, k)
    got = greedy_verify(lg, draft, V)
    am = lg[:, :V].argmax(-1)
    want = []
    for i in range(k):
        want.append(int(am[i]))
        if int(draft[i]) != int(am[i]):
            break
    else:
        want.append(int(am[k]))
    assert got == want
    assert 1 <= len(got) <= k + 1


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6), st.integers(2, 17),
       st.sampled_from([True, False]))
def test_rejection_verify_commit_shape(seed, k, V, point_mass):
    """Stochastic rejection commits 1..k+1 in-vocab tokens whose prefix
    (all but the last) is exactly the accepted draft prefix."""
    r = np.random.default_rng(seed)
    p = r.random((k + 1, V)) + 1e-3
    p /= p.sum(-1, keepdims=True)
    draft = r.integers(0, V, k)
    q = None
    if not point_mass:
        q = r.random((k, V)) + 1e-3
        q /= q.sum(-1, keepdims=True)
    got = rejection_verify(np.random.default_rng(seed + 1), p, draft, q)
    assert 1 <= len(got) <= k + 1
    assert all(0 <= t < V for t in got)
    assert got[:-1] == [int(d) for d in draft[:len(got) - 1]]
