"""LIME engine losslessness: pipelined output == single-device decode.

The engine needs >= 4 devices; this module re-execs its worker in a
subprocess with a forced host device count (the only sanctioned way to get
multiple CPU devices without polluting the whole test session's jax state).
"""
import os
import subprocess
import sys

import pytest

WORKER = r"""
import jax, jax.numpy as jnp, functools, sys
jnp.bfloat16 = jnp.float32   # fp32 => losslessness must be (near-)exact
import repro.core.engine as E
from repro.configs.base import ModelConfig, Family, AttnKind
from repro.models import model as M

CASES = {
 "dense": ModelConfig(name="d", family=Family.DENSE, n_layers=8, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      head_dim=16),
 "moe": ModelConfig(name="m", family=Family.MOE, n_layers=8, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                    head_dim=16, n_experts=4, top_k=2, n_shared_experts=1,
                    moe_d_ff=64),
 "ssm": ModelConfig(name="s", family=Family.SSM, n_layers=8, d_model=64,
                    n_heads=4, n_kv_heads=0, d_ff=128, vocab_size=256,
                    head_dim=16, attn_kind=AttnKind.NONE, ssm_state_size=16),
 "hybrid": ModelConfig(name="h", family=Family.HYBRID, n_layers=8,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab_size=256, head_dim=16,
                       attn_kind=AttnKind.SLIDING, window_size=16,
                       ssm_state_size=8, ssm_heads=4),
 "local_global": ModelConfig(name="lg", family=Family.DENSE, n_layers=8,
                             d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
                             vocab_size=256, head_dim=16,
                             attn_kind=AttnKind.LOCAL_GLOBAL, window_size=8,
                             tie_embeddings=True),
}
key = jax.random.PRNGKey(0)
mesh = jax.make_mesh((4, 2), ("data", "model"))
fails = []
for name, cfg in CASES.items():
    params = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        M.init_params(cfg, key))
    ref_step = jax.jit(functools.partial(M.decode_step, cfg))
    for fm in ("slot", "step"):
        for n_mb, mb, plan in ((4, 2, E.UniformPlan(4, 2, 0, 1)),
                               (1, 2, E.UniformPlan(4, 2, 1, 1))):
            eng = E.InterleavedEngine(cfg, mesh, plan, n_mb=n_mb, mb=mb,
                                      max_len=32, fetch_mode=fm)
            state = eng.init_state(params)
            caches = [jax.tree.map(
                lambda a: a.astype(jnp.float32)
                if a.dtype == jnp.bfloat16 else a,
                M.init_cache(cfg, mb, 32)) for _ in range(n_mb)]
            tok = jax.random.randint(key, (n_mb * mb, 1), 0, cfg.vocab_size)
            worst = 0.0
            for step in range(3):
                rls = []
                for m in range(n_mb):
                    rl, caches[m] = ref_step(params, caches[m],
                                             tok[m*mb:(m+1)*mb])
                    rls.append(rl[:, 0].astype(jnp.float32))
                rl = jnp.concatenate(rls, 0)
                lg, state = eng.decode_step(state, tok)
                worst = max(worst, float(jnp.abs(lg - rl).max()))
                tok = jnp.argmax(rl, -1)[:, None].astype(jnp.int32)
            ok = worst < 5e-4
            print(f"{name} fetch={fm} n_mb={n_mb} plan={plan}: "
                  f"worst={worst:.2e} {'OK' if ok else 'FAIL'}")
            if not ok:
                fails.append((name, fm, n_mb, worst))
sys.exit(1 if fails else 0)
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_engine_lossless_all_families():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", WORKER], env=env,
                       capture_output=True, text=True, timeout=900)
    sys.stdout.write(r.stdout)
    sys.stderr.write(r.stderr[-2000:])
    assert r.returncode == 0


def test_uniform_plan_arithmetic():
    from repro.core.engine import UniformPlan
    p = UniformPlan(n_stage=16, n_seg=2, k_res=1, k_off=1)
    assert p.k == 2 and p.n_chunks == 32 and p.n_layers == 64


def test_stage_shard_dim_prefers_largest_divisible():
    from repro.core.engine import stage_shard_dim
    assert stage_shard_dim((384, 7168, 2048), 16) == 1
    assert stage_shard_dim((25,), 16) is None
    assert stage_shard_dim((64, 64), 4) == 0


MULTIPOD_WORKER = r"""
import jax, jax.numpy as jnp, functools, sys
jnp.bfloat16 = jnp.float32
import repro.core.engine as E
from repro.configs.base import ModelConfig, Family
from repro.models import model as M

cfg = ModelConfig(name="t", family=Family.DENSE, n_layers=8, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16)
key = jax.random.PRNGKey(0)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
n_mb, mb = 2, 4       # mb=4 shards over pod=2 (bursty replicas per pod)
params = jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
    M.init_params(cfg, key))
eng = E.InterleavedEngine(cfg, mesh, E.UniformPlan(2, 2, 1, 1),
                          n_mb=n_mb, mb=mb, max_len=32)
state = eng.init_state(params)
ref_step = jax.jit(functools.partial(M.decode_step, cfg))
caches = [jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
    M.init_cache(cfg, mb, 32)) for _ in range(n_mb)]
tok = jax.random.randint(key, (n_mb * mb, 1), 0, cfg.vocab_size)
worst = 0.0
for step in range(3):
    rls = []
    for m in range(n_mb):
        rl, caches[m] = ref_step(params, caches[m], tok[m*mb:(m+1)*mb])
        rls.append(rl[:, 0].astype(jnp.float32))
    rl = jnp.concatenate(rls, 0)
    lg, state = eng.decode_step(state, tok)
    worst = max(worst, float(jnp.abs(lg - rl).max()))
    tok = jnp.argmax(rl, -1)[:, None].astype(jnp.int32)
print(f"multipod worst={worst:.2e}")
sys.exit(0 if worst < 5e-4 else 1)
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_engine_lossless_multipod():
    """Decode through the 3-axis production mesh shape (pod, data, model):
    pod shards the bursty replicas, data is the pipeline, model is TP."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", MULTIPOD_WORKER], env=env,
                       capture_output=True, text=True, timeout=900)
    sys.stdout.write(r.stdout)
    sys.stderr.write(r.stderr[-2000:])
    assert r.returncode == 0


LONGMODE_WORKER = r"""
import jax, jax.numpy as jnp, functools, sys
jnp.bfloat16 = jnp.float32
import repro.core.engine as E
from repro.configs.base import ModelConfig, Family, AttnKind
from repro.models import model as M

# sliding-window arch decoding PAST the ring-buffer length (the long_500k
# serving mode: cache is window-capped, slots wrap via pos_ids)
cfg = ModelConfig(name="sw", family=Family.DENSE, n_layers=8, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, attn_kind=AttnKind.SLIDING, window_size=8)
key = jax.random.PRNGKey(0)
mesh = jax.make_mesh((4, 2), ("data", "model"))
n_mb, mb, max_len = 4, 1, 16
params = jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
    M.init_params(cfg, key))
eng = E.InterleavedEngine(cfg, mesh, E.UniformPlan(4, 2, 1, 1), n_mb=n_mb,
                          mb=mb, max_len=max_len, long_mode=True)
state = eng.init_state(params)
caches = [jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
    M.init_cache(cfg, mb, max_len, long_mode=True)) for _ in range(n_mb)]
tok = jax.random.randint(key, (n_mb * mb, 1), 0, cfg.vocab_size)
worst = 0.0
for step in range(14):        # window S_c = 8: wraps around
    rls = []
    for m in range(n_mb):
        rl, caches[m] = M.decode_step(cfg, params, caches[m],
                                      tok[m*mb:(m+1)*mb], long_mode=True)
        rls.append(rl[:, 0].astype(jnp.float32))
    rl = jnp.concatenate(rls, 0)
    lg, state = eng.decode_step(state, tok)
    worst = max(worst, float(jnp.abs(lg - rl).max()))
    tok = jnp.argmax(rl, -1)[:, None].astype(jnp.int32)
print(f"ring worst={worst:.2e}")
sys.exit(0 if worst < 5e-4 else 1)
"""


PAGED_WORKER = r"""
import jax, jax.numpy as jnp, functools, sys
import numpy as np
jnp.bfloat16 = jnp.float32
import repro.core.engine as E
from repro.configs.base import ModelConfig, Family
from repro.models import model as M

# paged KV accounting (DESIGN.md §10): seed_cache adoption routed through
# block-table pages must stay lossless, and slot occupancy must be
# page-granular (alloc on seed, extend per decode step, free on release)
cfg = ModelConfig(name="d", family=Family.DENSE, n_layers=8, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16)
key = jax.random.PRNGKey(0)
mesh = jax.make_mesh((4, 2), ("data", "model"))
params = jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
    M.init_params(cfg, key))
ref_step = jax.jit(functools.partial(M.decode_step, cfg))
n_mb, mb, max_len, ps = 4, 2, 32, 8
eng = E.InterleavedEngine(cfg, mesh, E.UniformPlan(4, 2, 0, 1), n_mb=n_mb,
                          mb=mb, max_len=max_len, paged=True, page_size=ps)
state = eng.init_state(params)
B = n_mb * mb
toks = jax.random.randint(key, (B, 10), 1, cfg.vocab_size)
cache = jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
    M.init_cache(cfg, B, max_len))
logits, cache = jax.jit(functools.partial(M.prefill, cfg))(params, toks,
                                                           cache)
state = eng.seed_cache(state, cache)
st = eng.paged_stats()
assert st["slot_tokens"] == [10] * B, st              # prompt adopted
assert st["pages_in_use"] == B * 2, st                # ceil(10/8) pages
tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
worst = 0.0
active = np.ones(B, bool)
for step in range(6):
    rl, cache = ref_step(params, cache, tok)
    lg, state = eng.decode_requests(state, tok, active)
    worst = max(worst, float(jnp.abs(lg - rl[:, 0].astype(jnp.float32))
                             .max()))
    tok = jnp.argmax(rl[:, 0].astype(jnp.float32), -1)[:, None] \
        .astype(jnp.int32)
st = eng.paged_stats()
assert st["slot_tokens"] == [16] * B, st              # extended per step
assert st["pages_in_use"] == B * 2, st                # 16 tok = 2 pages
eng.free_slot(0)
assert eng.paged_stats()["pages_in_use"] == B * 2 - 2
print(f"paged worst={worst:.2e}")
sys.exit(0 if worst < 5e-4 else 1)
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_engine_paged_kv_lossless_and_accounted():
    """Paged engine contract: block-table adoption is lossless and slot
    page counts track seed / extend / free exactly."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", PAGED_WORKER], env=env,
                       capture_output=True, text=True, timeout=900)
    sys.stdout.write(r.stdout)
    sys.stderr.write(r.stderr[-2000:])
    assert r.returncode == 0


@pytest.mark.slow
@pytest.mark.subprocess
def test_engine_lossless_ring_buffer_long_mode():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", LONGMODE_WORKER], env=env,
                       capture_output=True, text=True, timeout=900)
    sys.stdout.write(r.stdout)
    sys.stderr.write(r.stderr[-2000:])
    assert r.returncode == 0
