"""repro.obs: flight-recorder tracing, metrics registry, structured
logging (DESIGN.md §15).

  trace      ring-buffered Tracer + the stable event vocabulary; zero
             cost when no tracer is installed (get_tracer() -> None)
  exporters  Chrome trace-event JSON (Perfetto) + JSONL round-trip +
             schema validation
  metrics    MetricsRegistry (counters/gauges/histograms) behind the
             scheduler's stats — ServingReport is a derived view
  log        level-gated structured logger (quiet under pytest)
"""
from repro.obs.exporters import (export_chrome, export_jsonl,  # noqa: F401
                                 read_jsonl, to_chrome, validate_chrome,
                                 validate_chrome_file)
from repro.obs.log import get_logger  # noqa: F401
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry)
from repro.obs.trace import (Tracer, get_tracer,  # noqa: F401
                             set_tracer, tracing)
