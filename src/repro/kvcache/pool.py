"""Two-tier page pool: device tier + host/"delegated" tier (DESIGN.md §10).

LIME's KV-transfer protocol (paper §IV-D, Eq. 8) sizes a token volume each
low-threshold device delegates to a high-threshold target; its online
planner (Eq. 5) fires offload plans on KV *occupancy*. Both are statements
about where KV bytes live. The PagePool makes that concrete: every page is
resident in exactly one tier —

  DEVICE   counts against the accelerator KV budget (admission currency)
  HOST     delegated / swapped out: off the device, still owned by its
           request, a fetch away from being attended again

Migrations move pages between tiers and return the byte volume moved, so
the discrete-event simulator can price the wire time (Eq. 8's transfer)
and benchmarks can report spill/fetch traffic. Capacity is enforced per
tier; page identity (and the owning BlockTable's entries) never changes
across a migration — only the residency bit does.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List

from repro.kvcache.allocator import BlockTable, OutOfPages, PageAllocator
from repro.obs import trace as tr_ev
from repro.obs.trace import get_tracer

DEVICE = "device"
HOST = "host"


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    """Geometry of a paged KV pool.

    page_size:        token slots per page (64 keeps the Pallas kernel's
                      kv-block sublane-aligned for f32/bf16 tiles)
    device_pages:     device-tier capacity
    host_pages:       host/delegated-tier capacity (0 = no spill target)
    page_bytes:       bytes per page across all layers (for pricing
                      migrations; 0 = unpriced)
    """
    page_size: int = 64
    device_pages: int = 0
    host_pages: int = 0
    page_bytes: float = 0.0

    @staticmethod
    def for_budget(budget_tokens: int, *, page_size: int = 64,
                   host_frac: float = 1.0,
                   bytes_per_token: float = 0.0) -> "PagedKVConfig":
        """Size the device tier to a token budget (floor — a page is only
        usable if *all* its slots fit the budget) and the host tier to
        `host_frac` of it."""
        dev = max(budget_tokens, 0) // page_size
        return PagedKVConfig(page_size=page_size, device_pages=dev,
                             host_pages=int(dev * host_frac),
                             page_bytes=bytes_per_token * page_size)


class PagePool:
    """Allocator + tier residency + migration accounting."""

    def __init__(self, cfg: PagedKVConfig):
        self.cfg = cfg
        total = cfg.device_pages + cfg.host_pages
        self.alloc = PageAllocator(total, cfg.page_size)
        self._tier: Dict[int, str] = {}
        self._count = {DEVICE: 0, HOST: 0}
        self._cap = {DEVICE: cfg.device_pages, HOST: cfg.host_pages}
        # cumulative migration traffic (benchmark / metrics counters)
        self.spilled_pages = 0
        self.fetched_pages = 0
        self.migrated_bytes = 0.0
        self._spare = 0       # capacity withdrawn by shrink(), ids parked

    # -- capacity ----------------------------------------------------------------
    @property
    def page_size(self) -> int:
        return self.cfg.page_size

    def pages_in_use(self, tier: str = DEVICE) -> int:
        return self._count[tier]

    def free_pages(self, tier: str = DEVICE) -> int:
        return self._cap[tier] - self._count[tier]

    def pages_for(self, n_tokens: int) -> int:
        return self.alloc.pages_for(n_tokens)

    def can_alloc(self, n_pages: int, tier: str = DEVICE) -> bool:
        return self.free_pages(tier) >= n_pages \
            and self.alloc.can_alloc(n_pages)

    def grow(self, n_pages: int, tier: str = DEVICE) -> int:
        """Raise a tier's capacity by `n_pages` pages (online memory
        adaptation: retiered weights return their HBM as KV pages —
        DESIGN.md §13). Capacity previously withdrawn by shrink() is
        reused before minting fresh allocator ids, so grow/shrink
        oscillation is bounded by the high-water mark. Returns the pages
        added."""
        if n_pages <= 0:
            return 0
        reuse = min(self._spare, n_pages)
        self._spare -= reuse
        fresh = n_pages - reuse
        if fresh:
            self.alloc.add_pages(fresh)
        self._cap[tier] += n_pages
        tr = get_tracer()
        if tr is not None:
            tr.instant(tr_ev.KV_GROW, track=tr_ev.TRACK_KV,
                       args={"pages": n_pages, "tier": tier})
        return n_pages

    def shrink(self, n_pages: int, tier: str = DEVICE) -> int:
        """Lower a tier's capacity (promotion reclaims its HBM). Only free
        capacity can be withdrawn — pages in use stay until released; the
        orphaned allocator ids are parked for the next grow() (capacity,
        not identity, gates usage). Returns the pages withdrawn."""
        take = max(min(n_pages, self.free_pages(tier)), 0)
        self._cap[tier] -= take
        self._spare += take
        if take:
            tr = get_tracer()
            if tr is not None:
                tr.instant(tr_ev.KV_SHRINK, track=tr_ev.TRACK_KV,
                           args={"pages": take, "tier": tier})
        return take

    # -- allocation --------------------------------------------------------------
    def alloc_pages(self, n: int, tier: str = DEVICE) -> List[int]:
        if self.free_pages(tier) < n:
            raise OutOfPages(f"{tier} tier full "
                             f"({self._count[tier]}/{self._cap[tier]})")
        pids = self.alloc.alloc_many(n)
        for pid in pids:
            self._tier[pid] = tier
        self._count[tier] += n
        return pids

    def extend_table(self, table: BlockTable, n_tokens: int,
                     tier: str = DEVICE) -> List[int]:
        """Grow a block table within a tier's capacity (all-or-nothing)."""
        need = self.alloc.pages_for(n_tokens) - len(table.pages)
        if need > 0 and self.free_pages(tier) < need:
            raise OutOfPages(f"{tier} tier full "
                             f"({self._count[tier]}/{self._cap[tier]})")
        new = table.extend_to(n_tokens, self.alloc)
        for pid in new:
            self._tier[pid] = tier
        self._count[tier] += len(new)
        return new

    def truncate_table(self, table: BlockTable, n_tokens: int) -> int:
        """Shrink a table to `n_tokens` (speculative rollback): pages past
        the kept prefix leave their tier when this table was the last
        owner. Returns the number of pages dropped."""
        if not 0 <= n_tokens <= table.tokens:   # validate BEFORE touching
            raise ValueError(                   # tier accounting
                f"truncate_table to {n_tokens} outside [0, {table.tokens}]")
        keep = self.alloc.pages_for(n_tokens)
        for pid in table.pages[keep:]:
            if self.alloc.refcount(pid) == 1:   # last owner frees the slot
                self._count[self._tier.pop(pid)] -= 1
        return len(table.truncate_to(n_tokens, self.alloc))

    def release_table(self, table: BlockTable) -> None:
        for pid in table.pages:
            if self.alloc.refcount(pid) == 1:   # last owner frees the slot
                self._count[self._tier.pop(pid)] -= 1
        table.release(self.alloc)

    # -- page-level sharing (radix prefix cache, DESIGN.md §12) ------------------
    def incref_page(self, pid: int) -> None:
        """Add an owner to an allocated page (tier unchanged)."""
        self.alloc.incref(pid)

    def decref_page(self, pid: int) -> None:
        """Drop one owner; the last owner's decref frees the tier slot
        (the page-level twin of release_table's per-page bookkeeping)."""
        if self.alloc.refcount(pid) == 1:
            self._count[self._tier.pop(pid)] -= 1
        self.alloc.decref(pid)

    # -- migration ---------------------------------------------------------------
    def tier_of(self, pid: int) -> str:
        return self._tier[pid]

    def migrate(self, pids: Iterable[int], dst: str) -> float:
        """Move pages to tier `dst`; returns bytes moved (0 for pages
        already there). All-or-nothing on destination capacity."""
        moving = [p for p in pids if self._tier[p] != dst]
        if self.free_pages(dst) < len(moving):
            raise OutOfPages(f"{dst} tier full "
                             f"({self._count[dst]}/{self._cap[dst]})")
        for pid in moving:
            src = self._tier[pid]
            self._tier[pid] = dst
            self._count[src] -= 1
            self._count[dst] += 1
        nbytes = len(moving) * self.cfg.page_bytes
        if dst == HOST:
            self.spilled_pages += len(moving)
        else:
            self.fetched_pages += len(moving)
        self.migrated_bytes += nbytes
        if moving:
            tr = get_tracer()
            if tr is not None:
                tr.instant(tr_ev.KV_SPILL if dst == HOST else tr_ev.KV_FETCH,
                           track=tr_ev.TRACK_KV,
                           args={"pages": len(moving), "bytes": nbytes})
        return nbytes

    def migrate_any(self, n: int, dst: str) -> float:
        """Move up to `n` in-use pages (caller doesn't care which —
        volume-level Eq. 8 accounting) into tier `dst`, clamped to source
        supply and destination capacity. Returns bytes moved."""
        src = HOST if dst == DEVICE else DEVICE
        n = min(n, self._count[src], self.free_pages(dst))
        if n <= 0:
            return 0.0
        pids = [p for p, t in self._tier.items() if t == src][:n]
        return self.migrate(pids, dst)

    def spill_table(self, table: BlockTable) -> float:
        """Whole-table spill to the host tier (preempt-and-swap). Pages
        the table shares with another owner (the radix tree or a
        co-resident COW fork, refcount > 1) stay put: migrating them
        would pull KV out from under a resident request that still
        attends it and overstate free device capacity. resume() is
        tier-aware (fetch_table moves only what left), so a partially
        spilled table round-trips correctly."""
        return self.migrate([p for p in table.pages
                             if self.alloc.refcount(p) == 1], HOST)

    def fetch_table(self, table: BlockTable) -> float:
        """Bring every page of a table back to the device tier."""
        return self.migrate(table.pages, DEVICE)

    def device_pages_of(self, table: BlockTable) -> int:
        return sum(1 for p in table.pages if self._tier[p] == DEVICE)
