"""RWKV6 (Finch) 3B — attention-free RNN with data-dependent decay.
wkv head size 64 -> 40 heads at d_model=2560. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="rwkv6-3b", family=Family.SSM,
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=0,
    d_ff=8960, vocab_size=65536, head_dim=64,
    attn_kind=AttnKind.NONE, ssm_state_size=64,
    source="RWKV6 Finch [arXiv:2404.05892]",
)
