"""Paper Figs 15-17: extremely low-memory settings 1-3 (llama3.3-70b on 5
devices, memory progressively restricted); baselines OOM/OOT, LIME holds."""
from benchmarks.common import run_scenario, speedup_table
from repro.configs.registry import get_config
from repro.core.profiles import env_lowmem


def run():
    cfg = get_config("llama3.3-70b")
    rows = []
    for setting in (1, 2, 3):
        devices = env_lowmem(setting)
        for bw in (100, 200):
            for pattern, nm in (("sporadic", 1), ("bursty", 5)):
                sc = f"S{setting}/{bw}Mbps/{pattern}"
                rows.extend(run_scenario(sc, devices, cfg, bw_mbps=bw,
                                         pattern=pattern, n_micro=nm,
                                         n_tokens=150))
    for sc, t in speedup_table(rows).items():
        lime = next(r for r in rows
                    if r.scenario == sc and r.method == "LIME")
        status = lime.status if lime.status != "ok" else \
            f"{lime.ms_per_token:.0f} ms/tok"
        print(f"{sc}: LIME {status} | "
              + " ".join(f"{m}={v}" for m, v in t.items() if m != "LIME"))
    return rows


if __name__ == "__main__":
    run()
