from repro.optim.adamw import (AdamW, AdamWState,  # noqa: F401
                               cosine_schedule,  # noqa: F401
                               constant_schedule, global_norm)  # noqa: F401
