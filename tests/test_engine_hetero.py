"""Heterogeneous ExecutionPlan execution + online retier (DESIGN.md §13).

The unification contract: the engine running an ExecutionPlan with unequal
per-stage splits — including one retiered mid-stream — must be
token-identical to the uniform path at bf16, on both the ref and Pallas
attention impls. Distributed cases re-exec in a subprocess with a forced
host device count (the test_engine.py convention).
"""
import numpy as np
import pytest

WORKER = r"""
import jax, jax.numpy as jnp, numpy as np, sys
import repro.core.engine as E
from repro.core.cost_model import ExecutionPlan, StageAlloc
from repro.configs.base import ModelConfig, Family
from repro.models import model as M

cfg = ModelConfig(name="d", family=Family.DENSE, n_layers=8, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16)
key = jax.random.PRNGKey(0)
# unequal per-stage splits (chunks of 3/1/1/1 layers over the same 8-layer
# model the uniform plan runs as 4 x 2-layer chunks; both grids pad)
HET = ExecutionPlan(n_seg=2, stages=[StageAlloc(2, 1), StageAlloc(0, 1),
                                     StageAlloc(2, 0), StageAlloc(0, 1)])
UNI = E.UniformPlan(4, 2, 1, 1)


def decode_tokens(mesh, plan, impl, steps=8, retier=None, headroom=0,
                  pre_demote=0):
    params = M.init_params(cfg, key)
    eng = E.InterleavedEngine(cfg, mesh, plan, n_mb=1, mb=2, max_len=32,
                              impl=impl, retier_headroom=headroom)
    if pre_demote:
        # counter-only retier before any state exists: init_state must
        # build the demoted layout directly
        none_state, freed = eng.retier(None, 0, pre_demote)
        assert none_state is None and freed > 0, freed
    state = eng.init_state(params)
    tok = jax.random.randint(key, (2, 1), 0, cfg.vocab_size)
    out = []
    for t in range(steps):
        lg, state = eng.decode_step(state, tok)
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok)[:, 0].copy())
        if retier and t in retier:
            stage, delta = retier[t]
            state, freed = eng.retier(state, stage, delta)
            assert (freed > 0) == (delta > 0), (delta, freed)
    return np.stack(out)


fails = []
for impl, shape, axes in (("ref", (4, 2), ("data", "model")),
                          ("pallas", (4,), ("data",))):
    # ref on the partial-auto (stage x model) mesh; pallas on the
    # stage-only mesh (old XLA's partitioner rejects Pallas calls in
    # partial-auto regions — the pre-existing engine limitation)
    mesh = jax.make_mesh(shape, axes)
    base = decode_tokens(mesh, UNI, impl)
    cases = {
        "hetero": decode_tokens(mesh, HET, impl),
        # demote stage 0's resident slot after step 2, promote after 5 —
        # a mid-stream retier event must change no emitted token
        "retier": decode_tokens(mesh, HET, impl, headroom=1,
                                retier={2: (0, +1), 5: (0, -1)}),
        # demote BEFORE init_state (between-epoch counter-only path)
        "pre_demoted": decode_tokens(mesh, HET, impl, headroom=1,
                                     pre_demote=1),
    }
    for name, got in cases.items():
        ok = (got == base).all()
        print(f"{impl} {name}: tokens {'identical' if ok else 'MISMATCH'}")
        if not ok:
            fails.append((impl, name))
print("HETERO_OK" if not fails else f"FAILS {fails}")
sys.exit(1 if fails else 0)
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_engine_hetero_and_retier_token_identical(run_worker):
    """Heterogeneous ExecutionPlan (unequal per-stage k_res/k_off) and
    mid-stream retier events are token-identical to the uniform path at
    bf16, ref + Pallas."""
    r = run_worker(WORKER)
    assert r.returncode == 0 and "HETERO_OK" in r.stdout


# ----------------------------------------------------------------------------
# retier DURING speculative decoding (DESIGN.md §14): a demotion between
# spec rounds must not disturb losslessness — the resident self-draft
# thins, the verify pass still corrects everything
# ----------------------------------------------------------------------------
SPEC_RETIER_WORKER = r"""
import jax, jax.numpy as jnp, numpy as np, sys
import repro.core.engine as E
from repro.core.cost_model import ExecutionPlan, StageAlloc
from repro.configs.base import ModelConfig, Family
from repro.models import model as M
from repro.specdec import greedy_verify

cfg = ModelConfig(name="d", family=Family.DENSE, n_layers=8, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16)
key = jax.random.PRNGKey(0)
HET = ExecutionPlan(n_seg=2, stages=[StageAlloc(2, 1), StageAlloc(0, 1),
                                     StageAlloc(2, 0), StageAlloc(0, 1)])
STEPS = 12


def make(mesh, impl):
    params = M.init_params(cfg, key)
    eng = E.InterleavedEngine(cfg, mesh, HET, n_mb=1, mb=2, max_len=48,
                              impl=impl, retier_headroom=1)
    return eng, eng.init_state(params)


def greedy(lg):
    return jnp.argmax(lg[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)


fails = []
for impl, shape, axes in (("ref", (4, 2), ("data", "model")),
                          ("pallas", (4,), ("data",))):
    mesh = jax.make_mesh(shape, axes)
    tok0 = jax.random.randint(key, (2, 1), 0, cfg.vocab_size)

    # plain autoregressive greedy reference on the SAME hetero plan
    eng, st = make(mesh, impl)
    t, ref = tok0, []
    for _ in range(STEPS):
        lg, st = eng.decode_step(st, t)
        t = greedy(lg)
        ref.append(np.asarray(t)[:, 0].copy())
    ref = np.stack(ref)

    # resident self-spec loop, retiering stage 0 BETWEEN spec rounds:
    # demote after round 2 (the draft loses a resident layer mid-stream),
    # promote it back after round 4
    eng, st = make(mesh, impl)
    t = np.array(tok0, np.int32)
    out = [[], []]
    pos, rounds = 0, 0
    while min(len(o) for o in out) < STEPS:
        cur = jnp.asarray(t)
        drafts = np.zeros((2, 3), np.int32)
        for i in range(3):
            lg, st = eng.draft_step(st, cur)
            cur = greedy(lg)
            drafts[:, i] = np.asarray(cur)[:, 0]
        st = eng.rollback(st, pos)
        lg, st = eng.verify_step(st, jnp.asarray(
            np.concatenate([t, drafts], 1)))
        lgn = np.asarray(lg, np.float32)
        committed = [greedy_verify(lgn[b], drafts[b], cfg.vocab_size)
                     for b in range(2)]
        c = min(len(x) for x in committed)
        pos += c
        st = eng.rollback(st, pos)
        for b in range(2):
            out[b].extend(committed[b][:c])
            t[b, 0] = committed[b][c - 1]
        rounds += 1
        if rounds == 2:
            st, freed = eng.retier(st, 0, +1)
            assert freed > 0, freed
        if rounds == 4:
            st, freed = eng.retier(st, 0, -1)
            assert freed < 0, freed
    got = np.stack([np.asarray(o[:STEPS]) for o in out], 1)
    ok = (got == ref).all()
    print(f"{impl}: retier x spec tokens "
          f"{'identical' if ok else 'MISMATCH'} ({rounds} rounds)")
    if not ok:
        fails.append(impl)
print("SPEC_RETIER_OK" if not fails else f"FAILS {fails}")
sys.exit(1 if fails else 0)
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_engine_retier_during_spec_token_identical(run_worker):
    """Mid-stream demotion AND promotion between resident-draft spec
    rounds leave the committed stream token-identical to plain greedy
    decode on the same heterogeneous plan, ref + Pallas."""
    r = run_worker(SPEC_RETIER_WORKER)
    assert r.returncode == 0 and "SPEC_RETIER_OK" in r.stdout


# ----------------------------------------------------------------------------
# plan geometry (no mesh needed)
# ----------------------------------------------------------------------------
def _hetero_plan():
    from repro.core.cost_model import ExecutionPlan, StageAlloc
    return ExecutionPlan(n_seg=2, stages=[StageAlloc(4, 1), StageAlloc(2, 2),
                                          StageAlloc(6, 0),
                                          StageAlloc(0, 3)])


def test_execution_plan_geometry():
    p = _hetero_plan()
    assert p.n_stage == 4 and p.n_chunks == 8
    assert p.k_res_list == (2, 1, 3, 0)
    assert p.k_off_list == (1, 2, 0, 3)
    assert p.k_max == 3
    assert p.n_layers == 2 * (3 + 3 + 3 + 3)
    assert p.layers_total() == 24
    assert not p.is_uniform
    with pytest.raises(AssertionError):
        p.k_res                                        # noqa: B018


def test_uniform_plan_delegates_to_execution_plan():
    from repro.core.cost_model import ExecutionPlan
    from repro.core.engine import UniformPlan
    p = UniformPlan(4, 2, 1, 1)
    assert isinstance(p, ExecutionPlan)
    assert p.is_uniform
    assert (p.k_res, p.k_off, p.k) == (1, 1, 2)
    assert p.n_layers == p.n_chunks * p.k == 16


def test_plan_layout_hetero_and_demoted():
    from repro.core.engine import plan_layout
    p = _hetero_plan()
    res, off = plan_layout(p, headroom=2)
    dead = p.n_layers
    # chunk 0 (seg 0, stage 0): layers 0,1 resident + 2 streamed
    assert list(res[0, 0]) == [0, 1, dead]
    assert list(off[0, 0]) == [dead, dead, 2, dead, dead]
    # chunk 3 (stage 3): all streamed
    assert list(res[0, 3]) == [dead] * 3
    assert list(off[0, 3]) == [dead, dead, 9, 10, 11]
    # demote stage 0's last resident slot: its layer id moves into the
    # LAST headroom slot (order-preserving: right before the streamed tail)
    res_d, off_d = plan_layout(p, headroom=2, k_res_live=[1, 1, 3, 0])
    assert list(res_d[0, 0]) == [0, dead, dead]
    assert list(off_d[0, 0]) == [dead, 1, 2, dead, dead]


def test_split_layer_stack_hetero_roundtrip():
    import jax.numpy as jnp
    from repro.core.engine import split_layer_stack
    p = _hetero_plan()
    L = p.layers_total()
    stacked = {"w": jnp.arange(L * 3.0).reshape(L, 3)}
    res, off = split_layer_stack(stacked, p, headroom=1)
    H = 1
    flat = 0
    for c in range(p.n_chunks):
        s, d = c // p.n_stage, c % p.n_stage
        kr, ko = p.k_res_list[d], p.k_off_list[d]
        chunk = np.concatenate([np.asarray(res["w"][s, d, :kr]),
                                np.asarray(off["w"][s, d, H:H + ko])], 0)
        want = np.arange(flat * 3.0, (flat + kr + ko) * 3.0).reshape(-1, 3)
        np.testing.assert_array_equal(chunk, want)
        # padding slots are zero (identity layers)
        np.testing.assert_array_equal(np.asarray(res["w"][s, d, kr:]), 0.0)
        np.testing.assert_array_equal(np.asarray(off["w"][s, d, :H]), 0.0)
        np.testing.assert_array_equal(
            np.asarray(off["w"][s, d, H + ko:]), 0.0)
        flat += kr + ko


# ----------------------------------------------------------------------------
# plan_for regression (ISSUE 5 S1): layer counts that don't factor cleanly
# ----------------------------------------------------------------------------
def test_plan_for_covers_and_fits_budget():
    """The 2-segment fallback used to size k_res from floor-divided
    off_layers, claiming up to ~170x more resident bytes than the stage
    budget holds. Every emitted plan must cover cfg.n_layers AND keep
    n_seg * k_res resident layers inside the per-stage weight budget."""
    from repro.configs.base import Family, ModelConfig
    from repro.core.engine import plan_for
    for n_layers in range(1, 41):
        cfg = ModelConfig(name="t", family=Family.DENSE, n_layers=n_layers,
                          d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
                          vocab_size=1024, head_dim=64)
        l_bytes = cfg.layer_params() * 2
        for n_stage in (2, 3, 4, 5, 8, 16):
            for frac, hbm in ((0.002, 5e7), (0.01, 2e8), (0.05, 1e9),
                              (0.3, 1e9), (0.6, 16e9)):
                plan = plan_for(cfg, n_stage, hbm_frac_for_weights=frac,
                                hbm_bytes=hbm)
                ctx = (n_layers, n_stage, frac, hbm, plan)
                assert plan.n_layers >= n_layers, ctx
                assert plan.k_res + plan.k_off == plan.k, ctx
                if plan.k_off:                # offloading: budget binds
                    assert plan.n_seg * plan.k_res * l_bytes \
                        <= hbm * frac + 1e-6, ctx
