"""Hymba-1.5B — hybrid-head: parallel attention + Mamba heads in each block,
sliding-window attention on most layers, ssm_state=16. [arXiv:2411.13676]"""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="hymba-1.5b", family=Family.HYBRID,
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    attn_kind=AttnKind.SLIDING, window_size=1024,
    ssm_state_size=16, ssm_heads=25,
    source="Hymba [arXiv:2411.13676]",
)
