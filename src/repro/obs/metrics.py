"""MetricsRegistry: counters / gauges / histograms for the serving path
(DESIGN.md §15).

Replaces the ad-hoc `stats` dicts that used to flow scheduler ->
`serving.metrics.summarize()`: the scheduler now increments typed
instruments and `ServingReport` is a *derived view* over the flattened
registry (`to_stats_dict()` keeps the exact key vocabulary the legacy
dicts used, so the report is field-identical either way — asserted in
tests/test_obs.py).

Instrument semantics:

  Counter    monotonic; `inc(n)` adds, `set(v)` adopts an externally
             accumulated total (the pool's spilled_pages etc. — counters
             owned by a subsystem the scheduler reads at drain time).
  Gauge      last-written value + high-water mark (`peak`): occupancy
             style quantities where the report wants the max.
  Histogram  raw observations + nearest-rank percentiles (small request
             counts; same convention as serving.metrics.percentile).
"""
from __future__ import annotations

import math
from typing import Dict, List


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def set(self, v: float) -> None:
        self.value = v


class Gauge:
    __slots__ = ("name", "value", "peak")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self.peak = 0.0

    def set(self, v: float) -> None:
        self.value = v
        if v > self.peak:
            self.peak = v


class Histogram:
    __slots__ = ("name", "values")

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(v)

    @property
    def count(self) -> int:
        return len(self.values)

    def percentile(self, p: float) -> float:
        """Nearest-rank (serving.metrics convention); NaN when empty."""
        if not self.values:
            return float("nan")
        xs = sorted(self.values)
        k = max(math.ceil(p / 100.0 * len(xs)) - 1, 0)
        return xs[min(k, len(xs) - 1)]


class MetricsRegistry:
    """Get-or-create instrument registry with a flat dict view."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    # -- instruments -------------------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(name)
        return h

    # -- shorthands (the scheduler's hot-path calls) -----------------------------
    def inc(self, name: str, n: float = 1.0) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v: float) -> None:
        self.counter(name).set(v)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def update(self, stats: Dict[str, float]) -> None:
        """Adopt a subsystem's counter dict (spec stats, adapt stats,
        engine prefix stats — totals owned elsewhere, merged at drain)."""
        for k, v in stats.items():
            self.counter(k).set(v)

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold another registry into this one — the fleet aggregation
        primitive (DESIGN.md §16). Counters sum (totals across replicas),
        gauges take the max (a fleet's peak occupancy is the max of the
        replicas' peaks, not their sum — each replica's pool is its own),
        histograms concatenate raw samples so merged percentiles equal
        percentiles over the pooled observations *exactly* (asserted in
        tests; merging precomputed percentiles would not be). Returns self
        so merges chain."""
        for name, c in other._counters.items():
            self.counter(name).inc(c.value)
        for name, g in other._gauges.items():
            mine = self.gauge(name)
            mine.value = max(mine.value, g.value)
            mine.peak = max(mine.peak, g.peak)
        for name, h in other._hists.items():
            self.histogram(name).values.extend(h.values)
        return self

    # -- views -------------------------------------------------------------------
    def to_stats_dict(self) -> Dict[str, float]:
        """The legacy flat `stats` vocabulary: counters under their own
        name, gauges under their *peak* when the name says so ("peak_*")
        else current value, histograms as "<name>_p50"/"<name>_p99"."""
        out: Dict[str, float] = {}
        for name, c in self._counters.items():
            out[name] = c.value
        for name, g in self._gauges.items():
            out[name] = g.peak if name.startswith("peak_") else g.value
        for name, h in self._hists.items():
            out[f"{name}_p50"] = h.percentile(50)
            out[f"{name}_p99"] = h.percentile(99)
            out[f"{name}_count"] = h.count
        return out

    def get(self, name: str, default: float = 0.0) -> float:
        if name in self._counters:
            return self._counters[name].value
        if name in self._gauges:
            g = self._gauges[name]
            return g.peak if name.startswith("peak_") else g.value
        return default
