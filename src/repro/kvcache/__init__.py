"""Paged KV-cache subsystem (DESIGN.md §10).

Layers, bottom to top: allocator (free-list pages + block tables) ->
pool (two-tier residency: device / host-"delegated", migration byte
accounting) -> manager (per-request admission, extension, preemption
spill/recompute, Eq. 8 delegation as page movement). The paged decode
path (gather through block tables) lives in kernels/decode_attention/
paged.py and kvcache/paged_decode.py.
"""
from repro.kvcache.allocator import (BlockTable, OutOfPages,  # noqa: F401
                                     PageAllocator)
from repro.kvcache.manager import (RECOMPUTE, SPILL,  # noqa: F401
                                   PagedKVManager)
from repro.kvcache.pool import (DEVICE, HOST, PagedKVConfig,  # noqa: F401
                                PagePool)
