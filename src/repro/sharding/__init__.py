from repro.sharding.rules import RULES, spec_for, shardings, \
    partition_specs, activation_sharding  # noqa: F401
