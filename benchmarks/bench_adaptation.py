"""Online memory adaptation vs. a static plan under KV pressure
(EXPERIMENTS.md §Adaptation, DESIGN.md §13).

Same fleet, same offline ExecutionPlan, same bursty arrival stream, same
tight paged KV budget — two serving configurations through the
continuous-batching scheduler over the discrete-event substrate:

  static    the plan never changes at runtime. When the page pool runs
            dry mid-generation the scheduler preempts (recompute or
            spill) — the pre-adaptation behaviour.
  adaptive  the backend exposes retier headroom: before preempting, the
            scheduler reclaims pages by demoting resident weight blocks
            into the streamed tier (the OnlinePlanner's TS ladder,
            force-advanced ahead of its occupancy thresholds). The freed
            HBM grows the device page tier; the simulator prices the
            added per-segment weight load on every subsequent step.

The headline claim: under bursty traffic that overruns the KV budget,
the adaptive plan beats the static plan on p50 request latency WITHOUT
preempting more requests — trading a bounded steady-state load increase
for the preemption churn (re-prefill or page swaps) the static plan
pays. The run exits non-zero if either half of that invariant fails.

  python benchmarks/bench_adaptation.py
  python benchmarks/bench_adaptation.py --preempt spill \
      --budget-factor 1.8 --out /tmp/adaptation.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))


def build_backend(args, slots: int, adapt: bool):
    from repro.configs.registry import get_config
    from repro.core.cost_model import CostEnv, Workload
    from repro.core.profiles import env_E1, env_E2, env_E3, mbps
    from repro.serving import SimBackend

    fleets = {"E1": env_E1, "E2": env_E2, "E3": env_E3}
    cfg = get_config(args.arch)
    w = Workload(cfg, mb=1, ctx=args.prompt_len, n_micro=slots)
    env = CostEnv(fleets[args.fleet](), mbps(args.bw_mbps), w)
    return SimBackend(env, n_slots=slots, prompt_tokens=args.prompt_len,
                      adapt=adapt)


def run_one(args, adapt: bool) -> dict:
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               cli_arrivals, requests_from_arrivals,
                               summarize)

    arrivals = cli_arrivals("bursty", args.n_requests, seed=args.seed,
                            prompt_len=args.prompt_len,
                            max_new_tokens=args.max_new, gap_s=args.gap_s,
                            burst_size=args.slots)
    budget = int(args.budget_factor * (args.prompt_len + args.max_new))
    backend = build_backend(args, args.slots, adapt)
    sched = ContinuousBatchingScheduler(backend, SchedulerConfig(
        kv_budget_tokens=budget, kv_policy="paged",
        page_size=args.page_size, preempt=args.preempt))
    served = sched.serve(requests_from_arrivals(arrivals))
    rep = summarize(served, pattern="bursty",
                    backend=f"sim/{'adaptive' if adapt else 'static'}",
                    stats=sched.stats)
    out = rep.to_dict()
    out["adaptive"] = adapt
    out["kv_budget_tokens"] = budget
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="llama2-13b")
    ap.add_argument("--fleet", default="E3", choices=("E1", "E2", "E3"))
    ap.add_argument("--bw-mbps", type=float, default=200.0)
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=96)
    ap.add_argument("--gap-s", type=float, default=8.0)
    ap.add_argument("--budget-factor", type=float, default=2.0,
                    help="device KV budget as a multiple of one worst-case "
                         "request — small enough that a bursty batch "
                         "overruns it mid-generation")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--preempt", choices=("spill", "recompute"),
                    default="recompute",
                    help="what the STATIC plan pays when the pool runs dry")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    static = run_one(args, adapt=False)
    adaptive = run_one(args, adapt=True)
    comparison = {
        "latency_p50_static_s": static["latency_p50_s"],
        "latency_p50_adaptive_s": adaptive["latency_p50_s"],
        "latency_gain": (static["latency_p50_s"]
                         / max(adaptive["latency_p50_s"], 1e-12)),
        "preempted_static": static["n_preempted"],
        "preempted_adaptive": adaptive["n_preempted"],
        "retier_events": adaptive["retier_events"],
        "layers_demoted": adaptive["layers_demoted"],
        "hbm_returned_bytes": adaptive["hbm_returned_bytes"],
        "retier_reclaimed_pages": adaptive["retier_reclaimed_pages"],
    }
    payload = {"config": vars(args), "results": [static, adaptive],
               "comparison": comparison}
    text = json.dumps(payload, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")

    c = comparison
    print(f"# p50 latency: adaptive {c['latency_p50_adaptive_s']:.2f}s vs "
          f"static {c['latency_p50_static_s']:.2f}s "
          f"({c['latency_gain']:.2f}x); preemptions "
          f"{c['preempted_adaptive']} vs {c['preempted_static']}; "
          f"{c['retier_events']} retier events", file=sys.stderr)
    rc = 0
    if c["preempted_static"] == 0:
        print("# WARNING: static plan never preempted — budget not "
              "constraining at this load, invariant vacuous", file=sys.stderr)
        rc = 1
    if c["latency_p50_adaptive_s"] > c["latency_p50_static_s"]:
        print("# FAIL: adaptive plan lost on p50 latency", file=sys.stderr)
        rc = 1
    if c["preempted_adaptive"] > c["preempted_static"]:
        print("# FAIL: adaptive plan preempted more requests",
              file=sys.stderr)
        rc = 1
    if c["retier_events"] == 0:
        print("# FAIL: adaptation never fired", file=sys.stderr)
        rc = 1
    return rc


def run():
    """benchmarks.run harness hook: the exit-enforced default scenario."""
    class _Row:
        def __init__(self, name, ms):
            self.name, self.ms = name, ms

        def csv(self):
            return f"adaptation,{self.name},{self.ms:.1f},ok"

    rc = main([])
    if rc:
        raise SystemExit("bench_adaptation smoke failed")
    return [_Row("bursty_adaptive_vs_static", 0.0)]


if __name__ == "__main__":
    raise SystemExit(main())
