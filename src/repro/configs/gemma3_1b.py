"""Gemma3-1B — dense GQA, 5:1 local(sliding-1024):global, 128k ctx, kv=1.
[hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="gemma3-1b", family=Family.DENSE,
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab_size=262144, head_dim=256,
    attn_kind=AttnKind.LOCAL_GLOBAL, window_size=1024, local_global_ratio=5,
    rope_theta=1_000_000.0, tie_embeddings=True,
    source="Gemma 3 model card [hf:google/gemma-3-1b-pt]",
)
