"""MoE layer: sort-based grouped compute vs the dense per-token oracle,
plus hypothesis sweeps on routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import Family, ModelConfig
from repro.models.moe import (_group_tokens, _route, moe_forward,
                              moe_forward_naive)


def make_cfg(E, K, shared=0):
    return ModelConfig(name="t", family=Family.MOE, n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=128,
                       head_dim=16, n_experts=E, top_k=K,
                       n_shared_experts=shared, moe_d_ff=48)


def make_params(cfg, key):
    from repro.models.moe import moe_specs
    from repro.models import spec as pspec
    return pspec.init(key, moe_specs(cfg.d_model, cfg.n_experts,
                                     cfg.moe_d_ff, cfg.n_shared_experts))


@pytest.mark.parametrize("E,K,shared", [(4, 2, 0), (8, 2, 1), (4, 1, 2),
                                        (16, 6, 2)])
def test_grouped_matches_naive(E, K, shared):
    cfg = make_cfg(E, K, shared)
    key = jax.random.PRNGKey(0)
    params = jax.tree.map(lambda a: a.astype(jnp.float32),
                          make_params(cfg, key))
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32)
    # ample capacity => no drops => must equal the dense oracle
    out, aux = moe_forward(params, x, cfg=cfg, capacity_factor=8.0)
    ref = moe_forward_naive(params, x, cfg=cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)
    assert float(aux) > 0.0


@given(st.integers(1, 64), st.integers(2, 16), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_group_tokens_invariants(T, E, K):
    K = min(K, E)
    key = jax.random.PRNGKey(T * 131 + E)
    ids = jax.random.randint(key, (T, K), 0, E)
    cap = max(1, (T * K) // E)
    order, buf_idx, keep = _group_tokens(ids, cap, E)
    order = np.asarray(order)
    buf_idx = np.asarray(buf_idx)
    keep = np.asarray(keep)
    # order is a permutation of T*K slots
    assert sorted(order.tolist()) == list(range(T * K))
    # kept slots land inside their expert's row, never the dump row
    e_sorted = np.asarray(ids).reshape(-1)[order]
    for j in range(T * K):
        if keep[j]:
            assert e_sorted[j] * cap <= buf_idx[j] < (e_sorted[j] + 1) * cap
        else:
            assert buf_idx[j] == E * cap
    # per-expert occupancy never exceeds capacity
    kept = buf_idx[keep]
    _, counts = np.unique(kept, return_counts=True)
    assert (counts <= 1).all()          # each buffer slot used once


def test_router_normalized_topk():
    key = jax.random.PRNGKey(1)
    router = jax.random.normal(key, (16, 8), jnp.float32)
    x = jax.random.normal(key, (5, 16), jnp.float32)
    w, ids, probs = _route(router, x, 3)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert int(ids.max()) < 8
