"""SpecDecodeController: the propose → verify → commit/rollback loop
(DESIGN.md §11).

The controller owns everything host-side about a speculative round for a
batch of slots: per-slot draft providers, the acceptance-rejection walk
over the target's multi-position logits, and the drafted/accepted
counters the serving metrics report. It never touches device state — the
backend runs the multi-token verify pass (engine.verify_requests,
model.verify_step, or PagedDecodeCache.verify) and applies the commit the
controller returns (pos rollback / block-table truncation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.sampling import SamplerConfig
from repro.specdec.draft import make_draft_provider
from repro.specdec.sampler import (greedy_verify, rejection_verify,
                                   target_probs)


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Knobs for speculative decoding, shared by both backends.

    k                 drafted tokens per round (verify scores k+1); with
                      adapt_k this is the CAP the depth controller adapts
                      under (the scheduler reserves k+1 tokens per round)
    draft             "ngram" (prompt-lookup self-draft, no weights),
                      "model" (small-model draft from a registered config)
                      or "resident" (truncated forward through the target's
                      own resident tier — DESIGN.md §14)
    max_ngram         longest tail n-gram the lookup draft matches
    draft_arch        registry arch for draft="model" (smoke-reduced)
    draft_temperature sampling temperature of the model draft (0 = greedy
                      point-mass proposals)
    acceptance        per-draft-token acceptance probability of the
                      SimBackend's acceptance-rate model (the simulator
                      has no real tokens to verify); for draft="resident"
                      it is the FULL-residency acceptance, scaled by the
                      live resident fraction (sim) / used as the depth
                      controller's rung prior (engine)
    resident_layers   draft="resident" without an engine: how many bottom
                      layers form the draft (default n_layers // 2); the
                      engine path reads the live tier boundary instead
    adapt_k           draft="resident": adapt depth per retier rung via
                      DepthController (k stays the cap)
    seed              host-side rng (rejection sampling + sim model)
    """
    k: int = 4
    draft: str = "ngram"
    max_ngram: int = 3
    draft_arch: Optional[str] = None
    draft_temperature: float = 0.0
    acceptance: float = 0.8
    resident_layers: Optional[int] = None
    adapt_k: bool = True
    seed: int = 0


@dataclasses.dataclass
class SpecStats:
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0          # drafted tokens that survived verification

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.drafted if self.drafted else 0.0

    def to_dict(self) -> Dict[str, float]:
        # raw counters only: the acceptance RATE is derived downstream
        # (serving.metrics.summarize) — one source of truth, no stale
        # pre-computed copy riding the stats dict
        return {"spec_rounds": self.rounds, "spec_drafted": self.drafted,
                "spec_accepted": self.accepted}


class SpecDecodeController:
    """Per-slot drafting + lossless acceptance for one serving batch."""

    def __init__(self, spec: SpecConfig, sampler: SamplerConfig,
                 target_cfg, n_slots: int, *, target_params=None,
                 resident_ids=None, external_drafts: bool = False):
        """external_drafts: the backend proposes tokens itself (the
        engine's on-device resident draft) and uses the controller only
        for verification + stats; no host providers are built and
        begin/observe are no-ops."""
        self.spec = spec
        self.sampler = sampler
        self.cfg = target_cfg
        if external_drafts:
            self.drafts = None
        else:
            self.drafts = [
                make_draft_provider(spec, target_cfg,
                                    target_params=target_params,
                                    resident_ids=resident_ids)
                for _ in range(n_slots)]
        self._rng = np.random.default_rng(spec.seed)
        self.stats = SpecStats()

    # -- sequence lifecycle ------------------------------------------------------
    def begin(self, slot: int, tokens) -> None:
        """Start a sequence on `slot`: prompt + the first sampled token."""
        if self.drafts is not None:
            self.drafts[slot].reset(tokens)

    def observe(self, slot: int, tokens) -> None:
        """Feed the round's committed tokens back to the draft."""
        if self.drafts is not None:
            self.drafts[slot].observe(tokens)

    # -- one round ---------------------------------------------------------------
    def propose(self, slot: int,
                k: Optional[int] = None) -> Tuple[np.ndarray,
                                                  Optional[np.ndarray]]:
        """k: round cap from the backend (near the cache end it shrinks
        below spec.k — drafting past it would be discarded work)."""
        assert self.drafts is not None, \
            "external_drafts controller: the backend proposes"
        return self.drafts[slot].propose(self.spec.k if k is None else k)

    def verify(self, logits: np.ndarray, draft: np.ndarray,
               draft_probs: Optional[np.ndarray] = None) -> List[int]:
        """logits: (k+1, PV) target logits for one slot; returns the
        committed tokens (1..k+1). Greedy for temperature=0, stochastic
        rejection sampling otherwise — both exactly the serving sampler's
        distribution (sampler.py). Counters are NOT updated here — the
        backend may truncate the result (lockstep commit); it reports
        what was actually committed via note_round()."""
        if self.sampler.temperature <= 0.0:
            return greedy_verify(logits, draft, self.cfg.vocab_size)
        p = target_probs(logits, self.sampler, self.cfg.vocab_size)
        return rejection_verify(self._rng, p, draft, draft_probs)

    def note_round(self, drafted: int, accepted_committed: int) -> None:
        """Per-slot round accounting AFTER the commit: `accepted_committed`
        counts drafted tokens that both survived verification and made it
        into the committed prefix (lockstep truncation drops the rest —
        they are re-drafted and must not be counted twice)."""
        self.stats.rounds += 1
        self.stats.drafted += drafted
        self.stats.accepted += accepted_committed
