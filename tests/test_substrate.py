"""Substrate layers: optimizer, data pipeline, trainer, checkpoint, serving."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import restore, save
from repro.configs.registry import get_smoke_config
from repro.data import SyntheticCorpus, make_batches
from repro.models import model as M
from repro.optim.adamw import AdamW, constant_schedule, cosine_schedule, \
    global_norm
from repro.serving import LimeServer, SamplerConfig, sample
from repro.training import Trainer


# ----------------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------------
def test_adamw_converges_quadratic():
    opt = AdamW(lr=constant_schedule(0.1), weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw w^2
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_bf16_params_master_weights():
    """Tiny updates must not be lost to bf16 rounding (master weights)."""
    opt = AdamW(lr=constant_schedule(1e-5), weight_decay=0.0)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    for _ in range(100):
        params, state = opt.update({"w": jnp.ones((4,))}, state, params)
    # 100 steps x ~1e-5 => ~1e-3 drift, invisible per-step in bf16 but
    # accumulated in the fp32 master
    assert float(state.master["w"][0]) < 1.0 - 5e-4


def test_grad_clip():
    opt = AdamW(lr=constant_schedule(1.0), grad_clip=1.0)
    g = {"w": jnp.full((100,), 100.0)}
    assert float(global_norm(g)) > 1.0
    params = {"w": jnp.zeros((100,))}
    state = opt.init(params)
    p2, _ = opt.update(g, state, params)
    assert float(jnp.abs(p2["w"]).max()) < 1.1   # step bounded by lr


def test_cosine_schedule_shape():
    f = cosine_schedule(1.0, warmup=10, total=100)
    assert float(f(jnp.int32(0))) == 0.0
    assert float(f(jnp.int32(10))) == pytest.approx(1.0)
    assert float(f(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


# ----------------------------------------------------------------------------
# data
# ----------------------------------------------------------------------------
def test_packing_label_alignment():
    b = next(make_batches(512, batch=2, seq_len=32))
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    assert b["mask"].shape == b["tokens"].shape


def test_corpus_deterministic():
    c1 = SyntheticCorpus(256, seed=7)
    c2 = SyntheticCorpus(256, seed=7)
    s1 = [next(iter_) for iter_ in [c1.stream(0)] for _ in range(50)]
    s2 = [next(iter_) for iter_ in [c2.stream(0)] for _ in range(50)]
    assert s1 == s2


@given(st.integers(64, 2048), st.integers(1, 4), st.integers(8, 64))
@settings(max_examples=10, deadline=None)
def test_packing_token_range(vocab, batch, seq):
    b = next(make_batches(vocab, batch=batch, seq_len=seq))
    assert b["tokens"].min() >= 0 and b["tokens"].max() < vocab
    assert b["tokens"].shape == (batch, seq)


# ----------------------------------------------------------------------------
# trainer end-to-end (loss decreases)
# ----------------------------------------------------------------------------
@pytest.mark.slow
def test_trainer_learns():
    cfg = get_smoke_config("internlm2-1.8b")
    tr = Trainer(cfg, mesh=None, total_steps=80, warmup=8, peak_lr=1e-3)
    params, opt_state = tr.init()
    batches = make_batches(cfg.vocab_size, batch=8, seq_len=64)
    params, opt_state, hist = tr.fit(params, opt_state, batches, 60,
                                     log_every=59, log_fn=lambda s: None)
    assert hist[-1][1]["loss"] < hist[0][1]["loss"] - 0.5


# ----------------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------------
def test_checkpoint_roundtrip_bf16():
    tree = {"a": {"b": jnp.ones((3, 4), jnp.bfloat16) * 1.5},
            "c": jnp.arange(5, dtype=jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        save(d, tree, step=7)
        back, step = restore(d)
        assert step == 7
        assert str(jnp.asarray(back["a"]["b"]).dtype) == "bfloat16"
        np.testing.assert_array_equal(np.asarray(back["c"]),
                                      np.arange(5, dtype=np.int32))


def test_checkpoint_shard_named_by_process_and_multi_shard_restore():
    """save() writes shard<process_index>.npz (shard0 single-host);
    restore() globs and merges every shard — simulate a 2-host checkpoint
    by splitting one save across two shard files."""
    import os

    tree = {"a": jnp.ones((2, 2), jnp.float32), "b": jnp.arange(3)}
    with tempfile.TemporaryDirectory() as d:
        save(d, tree, step=3)
        assert os.path.exists(os.path.join(d, "shard0.npz"))
        # split: move key "b" into a second host's shard
        data = dict(np.load(os.path.join(d, "shard0.npz")))
        np.savez(os.path.join(d, "shard0.npz"), a=data["a"])
        np.savez(os.path.join(d, "shard1.npz"), b=data["b"])
        back, step = restore(d)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.ones((2, 2), np.float32))
        np.testing.assert_array_equal(np.asarray(back["b"]), np.arange(3))
        # a key in no shard is an error, not a silent hole
        np.savez(os.path.join(d, "shard1.npz"), unrelated=data["b"])
        with pytest.raises(KeyError):
            restore(d)


# ----------------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------------
def test_sampler_greedy_and_temperature():
    logits = jnp.array([[0.0, 5.0, 1.0, -2.0]])
    key = jax.random.PRNGKey(0)
    assert int(sample(logits, SamplerConfig(0.0), key, 4)[0]) == 1
    t = sample(jnp.tile(logits, (256, 1)), SamplerConfig(1.5, top_k=3),
               key, 4)
    assert set(np.asarray(t).tolist()) <= {0, 1, 2}   # top-k excludes idx 3


def test_server_patterns_and_metrics():
    cfg = get_smoke_config("gemma3-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = LimeServer(cfg, params, engine=None, max_len=48, pattern="bursty")
    for i in range(3):
        srv.queue.submit(np.arange(4) + 1, max_new_tokens=6)
    done = srv.serve_all()
    assert len(done) == 3
    for r in done:
        assert len(r.output) == 6 and r.done
        assert r.first_token_s is not None and r.finish_s >= r.first_token_s
