"""End-to-end training driver: a ~100M-parameter gemma3-family model for a
few hundred steps on the synthetic corpus, with checkpointing and eval-loss
reporting. This is the train_4k shape's code path at laptop scale.

  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.checkpoint import restore, save
from repro.configs.registry import get_config
from repro.data import make_batches
from repro.models import model as M
from repro.training import Trainer


def make_100m():
    base = get_config("gemma3-1b")
    return dataclasses.replace(
        base, name="gemma3-100m", n_layers=8, d_model=512, n_heads=4,
        n_kv_heads=1, d_ff=2048, vocab_size=8192, head_dim=128,
        window_size=256)


def eval_loss(cfg, params, batches, n=4):
    tot = 0.0
    for _ in range(n):
        b = {k: jnp.asarray(v) for k, v in next(batches).items()}
        loss, _ = M.loss_fn(cfg, params, b, remat=False)
        tot += float(loss)
    return tot / n


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args(argv)

    cfg = make_100m()
    n_params = cfg.total_params()
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M")
    tr = Trainer(cfg, mesh=None, peak_lr=6e-4, warmup=args.steps // 10,
                 total_steps=args.steps)
    params, opt_state = tr.init()
    train_b = make_batches(cfg.vocab_size, args.batch, args.seq, seed=0)
    val_b = make_batches(cfg.vocab_size, args.batch, args.seq, seed=1)

    print(f"eval loss (init): {eval_loss(cfg, params, val_b):.4f}")
    params, opt_state, hist = tr.fit(params, opt_state, train_b,
                                     args.steps, log_every=25)
    final = eval_loss(cfg, params, val_b)
    print(f"eval loss (final): {final:.4f}")
    save(args.ckpt, params, step=args.steps)
    back, step = restore(args.ckpt)
    print(f"checkpoint roundtrip ok (step {step}); saved to {args.ckpt}")
    assert final < hist[0][1]["loss"], "training did not improve eval loss"


if __name__ == "__main__":
    main()
