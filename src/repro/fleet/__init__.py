"""Fleet layer: multi-replica serving behind a prefix-affine router
(DESIGN.md §16).

One LIME pipeline serves one model on one device subset; the fleet layer
runs N of them — each a full Scheduler + InferenceBackend + ExecutionPlan
stack (`Replica`) — behind a `FleetRouter` that places each request by
prefix overlap (against per-replica radix digests), session stickiness,
and load, with spillover and hysteresis. `Fleet` co-steps the replica
clocks on one timeline and supports elastic drain/join; `FleetReport`
merges the per-replica results exactly (pooled records + registry merge).
"""
from repro.fleet.fleet import Fleet  # noqa: F401
from repro.fleet.replica import Replica  # noqa: F401
from repro.fleet.report import FleetReport, FleetResult  # noqa: F401
from repro.fleet.router import (POLICIES, FleetRouter,  # noqa: F401
                                RouterConfig)
