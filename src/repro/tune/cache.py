"""TuneCache: one JSON file holding everything the autotuner learned.

Two sections, both keyed by device kind (``jax.devices()[0].device_kind``
— measurements from a different device must never be replayed):

  - ``profiles``: device_kind -> MeasuredProfile dict (measure.py)
  - ``kernels``:  device_kind -> kernel -> shape-bucket -> config
                  (sweep.py winners; int block params plus ``_``-prefixed
                  meta like ``_speedup`` / ``_us`` that `kernel_table`
                  strips before installing)

``install`` bridges to the kernels package: it builds the plain
``{kernel: {bucket: {param: int}}}`` table and hands it to
``repro.kernels.tuning.set_tuning_table`` — remember the
install-before-trace caveat documented there.

File format is versioned and written atomically (tmp + rename) with the
repo's NaN->null JSON convention.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, Mapping, Optional

from repro.kernels import tuning
from repro.obs.log import get_logger
from repro.tune.profiles import MeasuredProfile

VERSION = 1


class TuneCache:
    """In-memory view of the tune cache; load/save are explicit."""

    def __init__(self) -> None:
        self.profiles: Dict[str, MeasuredProfile] = {}
        self.kernels: Dict[str, Dict[str, Dict[str, Dict]]] = {}

    # -- profiles --------------------------------------------------------------
    def put_profile(self, prof: MeasuredProfile) -> None:
        if not prof.device_kind:
            raise ValueError("MeasuredProfile.device_kind is required as "
                             "the cache key")
        self.profiles[prof.device_kind] = prof

    def get_profile(self, device_kind: str) -> Optional[MeasuredProfile]:
        return self.profiles.get(device_kind)

    # -- kernel configs --------------------------------------------------------
    def put_kernel(self, device_kind: str, kernel: str, bucket: str,
                   cfg: Mapping[str, int], **meta) -> None:
        """Record a sweep winner. `cfg` holds the block params exactly as
        the wrapper takes them; `meta` kwargs are stored ``_``-prefixed."""
        row = {k: int(v) for k, v in cfg.items()}
        row.update({"_" + k: v for k, v in meta.items()})
        self.kernels.setdefault(device_kind, {}) \
                    .setdefault(kernel, {})[bucket] = row

    def get_kernel(self, device_kind: str, kernel: str,
                   bucket: str) -> Optional[Dict]:
        return self.kernels.get(device_kind, {}).get(kernel, {}).get(bucket)

    def kernel_table(self, device_kind: str) -> Dict[str, Dict[str, Dict]]:
        """The ``{kernel: {bucket: {param: int}}}`` shape
        `repro.kernels.tuning` consumes — meta keys stripped."""
        out: Dict[str, Dict[str, Dict]] = {}
        for kernel, buckets in self.kernels.get(device_kind, {}).items():
            for bucket, row in buckets.items():
                cfg = {k: v for k, v in row.items()
                       if not k.startswith("_")}
                if cfg:
                    out.setdefault(kernel, {})[bucket] = cfg
        return out

    def install(self, device_kind: str) -> int:
        """Install this cache's tuned kernel configs for `device_kind`
        as the process-wide table; returns the number of (kernel,
        bucket) entries installed (0 clears nothing — an empty table is
        not installed, so defaults stay untouched)."""
        table = self.kernel_table(device_kind)
        n = sum(len(b) for b in table.values())
        if n:
            tuning.set_tuning_table(table)
        return n

    # -- persistence -----------------------------------------------------------
    def to_dict(self) -> Dict:
        return {
            "version": VERSION,
            "profiles": {k: p.to_dict() for k, p in self.profiles.items()},
            "kernels": self.kernels,
        }

    def save(self, path: str) -> None:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_dict(), f, indent=2, sort_keys=True,
                          allow_nan=False)
                f.write("\n")
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    @classmethod
    def from_dict(cls, d: Mapping) -> "TuneCache":
        ver = d.get("version", 0)
        if ver != VERSION:
            get_logger("repro.tune").warning(
                "tune cache version mismatch; ignoring contents",
                found=ver, expected=VERSION)
            return cls()
        c = cls()
        for k, pd in (d.get("profiles") or {}).items():
            c.profiles[k] = MeasuredProfile.from_dict(pd)
        for dk, kernels in (d.get("kernels") or {}).items():
            for kernel, buckets in kernels.items():
                c.kernels.setdefault(dk, {})[kernel] = {
                    b: dict(row) for b, row in buckets.items()}
        return c

    @classmethod
    def load(cls, path: str) -> "TuneCache":
        """Load, tolerating a missing or corrupt file (returns an empty
        cache with a warning — a bad cache must never block serving)."""
        if not os.path.exists(path):
            return cls()
        try:
            with open(path) as f:
                return cls.from_dict(json.load(f))
        except (json.JSONDecodeError, OSError, ValueError) as e:
            get_logger("repro.tune").warning(
                "tune cache unreadable; starting empty", path=path,
                error=str(e))
            return cls()


def default_cache_path() -> str:
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "tune_cache.json")
