"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Each assigned architecture lives in its own ``configs/<id>.py`` exposing CONFIG.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, InputShape, INPUT_SHAPES, reduced

ASSIGNED_ARCHS: List[str] = [
    "internlm2-1.8b",
    "codeqwen1.5-7b",
    "pixtral-12b",
    "stablelm-12b",
    "kimi-k2-1t-a32b",
    "gemma3-1b",
    "rwkv6-3b",
    "seamless-m4t-medium",
    "deepseek-moe-16b",
    "hymba-1.5b",
]

PAPER_MODELS: List[str] = [
    "llama2-13b",      # paper Tab. III row 1
    "qwen3-32b",       # paper Tab. III row 2
    "llama3.3-70b",    # paper Tab. III row 3
]

_MOD = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
        for a in ASSIGNED_ARCHS + PAPER_MODELS}

_cache: Dict[str, ModelConfig] = {}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _cache:
        if arch_id not in _MOD:
            raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_MOD)}")
        _cache[arch_id] = importlib.import_module(_MOD[arch_id]).CONFIG
    return _cache[arch_id]


def get_smoke_config(arch_id: str) -> ModelConfig:
    return reduced(get_config(arch_id))


def list_archs() -> List[str]:
    return list(ASSIGNED_ARCHS)


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def dryrun_pairs() -> List[tuple]:
    """The 10x4 assigned grid; (arch, shape, runnable, skip_reason)."""
    out = []
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        for s in INPUT_SHAPES.values():
            skip = None
            if s.name == "long_500k" and not cfg.supports_long_context():
                skip = ("full-attention arch: 524k dense KV cache is the memory "
                        "blow-up LIME bounds; no sub-quadratic variant defined "
                        "(DESIGN.md §4)")
            out.append((a, s.name, skip is None, skip))
    return out
