"""Engine plan/layout math — property tests (no mesh needed).

The chunking invariant behind losslessness: splitting the layer stack into
(resident, offloaded) per the UniformPlan and reassembling chunk-by-chunk
in pipeline order must reproduce the original layers exactly.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.engine import UniformPlan, split_layer_stack, stage_shard_dim
from repro.configs.registry import ASSIGNED_ARCHS, get_config


@st.composite
def plans(draw):
    n_stage = draw(st.sampled_from([2, 4, 8, 16]))
    n_seg = draw(st.integers(1, 4))
    k_res = draw(st.integers(0, 3))
    k_off = draw(st.integers(0, 2))
    if k_res + k_off == 0:
        k_res = 1
    return UniformPlan(n_stage, n_seg, k_res, k_off)


@given(plans(), st.integers(1, 3))
@settings(max_examples=40, deadline=None)
def test_split_reassemble_roundtrip(plan, extra_dims):
    """res[s, d, :k_res] ++ off[s, d, :k_off] == layers of chunk (s, d)."""
    L = plan.n_layers
    shape = (L,) + tuple(range(3, 3 + extra_dims))
    stacked = {"w": jnp.arange(int(np.prod(shape)),
                               dtype=jnp.float32).reshape(shape)}
    res, off = split_layer_stack(stacked, plan)
    k = plan.k
    for s in range(plan.n_seg):
        for d in range(plan.n_stage):
            c = s * plan.n_stage + d
            orig = stacked["w"][c * k:(c + 1) * k]
            got = jnp.concatenate([res["w"][s, d], off["w"][s, d]], axis=0)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(orig))


@given(plans())
@settings(max_examples=30, deadline=None)
def test_split_pads_short_stacks_with_identity_zeros(plan):
    """A stack shorter than the plan's grid is zero-padded — zero projections
    are identity layers through the residual stream (DESIGN.md §2)."""
    L_real = max(plan.n_layers - plan.k, 1)
    stacked = {"w": jnp.ones((L_real, 4))}
    res, off = split_layer_stack(stacked, plan)
    total = (res["w"].size + off["w"].size) // 4
    assert total == plan.n_layers
    # padded tail is zeros
    flat = jnp.concatenate(
        [jnp.concatenate([res["w"][s, d], off["w"][s, d]], 0)
         for s in range(plan.n_seg) for d in range(plan.n_stage)], 0)
    np.testing.assert_array_equal(np.asarray(flat[L_real:]), 0.0)


@given(st.lists(st.sampled_from([16, 25, 64, 128, 384, 2048, 7168]),
                min_size=1, max_size=4),
       st.sampled_from([4, 8, 16]))
@settings(max_examples=40, deadline=None)
def test_stage_shard_dim_properties(shape, n_stage):
    d = stage_shard_dim(tuple(shape), n_stage)
    if d is None:
        assert all(x % n_stage for x in shape)
    else:
        assert shape[d] % n_stage == 0
        assert shape[d] == max(x for x in shape if x % n_stage == 0)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_plan_fits_hbm_budget(arch):
    """The dry-run's serving plan keeps resident weights inside the
    per-chip budget for every assigned architecture (the memory proof's
    precondition)."""
    import importlib
    import os
    prev = os.environ.get("XLA_FLAGS")
    dr = importlib.import_module("repro.launch.dryrun")   # sets XLA_FLAGS
    # jax is already initialized with 1 device (flag is a no-op in-process),
    # but restore the env so later subprocess-spawning tests see the truth
    if prev is None:
        os.environ.pop("XLA_FLAGS", None)
    else:
        os.environ["XLA_FLAGS"] = prev
    cfg = get_config(arch)
    plan = dr.decode_plan(cfg, 16)
    assert plan.n_layers >= cfg.n_layers
    l_bytes = cfg.layer_params() * 2
    res_per_chip = plan.k_res * plan.n_seg * l_bytes / 16    # /model_par
    assert res_per_chip <= 16e9 * 0.55, (arch, res_per_chip / 1e9)
    if plan.k_off:
        assert plan.n_seg >= 2 or plan.k_res == 0 or True
