"""Jit'd public wrapper: layout/padding glue around the flash kernel.

Model code calls ``flash_attention(q, k, v, causal=..., window=...)`` with the
model-native (B, S, H, dh) layout; this wrapper transposes to the kernel's
(B, H, S, dh) layout, pads S to block multiples and dh to 128 lanes (zero-pad
keys leave scores untouched because padded q·k terms are 0; padded kv *rows*
are masked via skv_real), and slices the result back.

On CPU (this container) the kernel runs in interpret mode; on TPU it compiles
to Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.flash_attention.kernel import flash_attention_kernel

GLOBAL_WINDOW = 2 ** 30


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "q_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q=None, block_k=None,
                    q_offset: int = 0, interpret=None):
    """q: (B, Sq, H, dh); k, v: (B, Skv, KV, dh) -> (B, Sq, H, dh).
    block_q/block_k=None consult the tuned table (repro.kernels.tuning)
    at trace time; (128, 512) with none installed."""
    if interpret is None:
        interpret = _auto_interpret()
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    if window is None:
        window = GLOBAL_WINDOW
    block_q = tuning.resolve("flash_attention", Skv, dh, "block_q", block_q)
    block_k = tuning.resolve("flash_attention", Skv, dh, "block_k", block_k)
    ws = jnp.asarray(window, jnp.int32).reshape(1)

    qt = _pad_to(_pad_to(jnp.moveaxis(q, 2, 1), 2, block_q), 3, 128)
    kt = _pad_to(_pad_to(jnp.moveaxis(k, 2, 1), 2, block_k), 3, 128)
    vt = _pad_to(_pad_to(jnp.moveaxis(v, 2, 1), 2, block_k), 3, 128)

    out = flash_attention_kernel(qt, kt, vt, ws, causal=causal,
                                 sq_real=Sq, skv_real=Skv, dh_real=dh,
                                 block_q=block_q, block_k=block_k,
                                 q_offset=q_offset, interpret=interpret)
    return jnp.moveaxis(out[:, :, :Sq, :dh], 1, 2)
