"""Parameter specification trees.

A model is declared once as a pytree of :class:`ParamSpec` (shape, dtype,
logical axes). From that single source of truth we derive:

* ``init(key, specs)``       — materialized parameters (smoke tests, examples)
* ``shapes(specs)``          — ``jax.ShapeDtypeStruct`` tree (dry-run lowering)
* ``shardings(specs, mesh)`` — ``NamedSharding`` tree via the logical-axis rules
  in :mod:`repro.sharding.rules`
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]        # logical axis name per dim (or None)
    dtype: jnp.dtype = jnp.bfloat16
    init: str = "normal"                   # normal | zeros | ones | small
    scale: Optional[float] = None          # override fan-in scaling

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(key, s: ParamSpec):
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "small":
        return (0.01 * jax.random.normal(key, s.shape, jnp.float32)).astype(s.dtype)
    fan_in = s.shape[0] if len(s.shape) > 1 else max(s.shape[0], 1)
    scale = s.scale if s.scale is not None else 1.0 / np.sqrt(fan_in)
    return (scale * jax.random.normal(key, s.shape, jnp.float32)).astype(s.dtype)


def init(key, specs):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_one(k, s) for k, s in zip(keys, leaves)])


def shapes(specs):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        specs, is_leaf=is_spec)


def stack(spec_tree, n: int, axis_name: Optional[str] = None):
    """Prepend a stacking dim of size n (for scan-over-layers parameter stacks)."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.dtype,
                            s.init, s.scale),
        spec_tree, is_leaf=is_spec)


def count_params(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))
