from repro.training.trainer import (Trainer,  # noqa: F401
                                    make_train_step,  # noqa: F401
                                    zero1_sharding)  # noqa: F401
