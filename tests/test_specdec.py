"""Speculative decoding (DESIGN.md §11): acceptance-rejection losslessness,
draft providers, multi-query kernels, verify/rollback through every decode
path, and serving integration."""
import numpy as np
import pytest

from repro.serving.sampling import SamplerConfig
from repro.specdec import (NgramDraft, SpecConfig, greedy_verify,
                           rejection_verify, target_probs)


# ----------------------------------------------------------------------------
# acceptance-rejection sampler
# ----------------------------------------------------------------------------
def test_greedy_verify_prefix_correction_bonus():
    V = 8
    lg = np.full((4, V), -10.0)
    lg[0, 3] = lg[1, 5] = lg[2, 1] = lg[3, 7] = 0.0   # argmax per position
    # full acceptance -> bonus appended
    assert greedy_verify(lg, [3, 5, 1], V) == [3, 5, 1, 7]
    # first mismatch commits the correction and stops
    assert greedy_verify(lg, [3, 2, 1], V) == [3, 5]
    assert greedy_verify(lg, [0, 5, 1], V) == [3]


def test_greedy_verify_ignores_padded_vocab():
    lg = np.zeros((2, 8))
    lg[:, 6] = 5.0        # real-vocab argmax
    lg[:, 7] = 99.0       # padding column must not win
    assert greedy_verify(lg, [6], real_vocab=7) == [6, 6]


def _hist(tokens, V):
    h = np.zeros(V)
    for t in tokens:
        h[t] += 1
    return h / len(tokens)


@pytest.mark.parametrize("point_mass", [True, False])
def test_rejection_verify_matches_target_distribution(point_mass):
    """The first committed token of a 1-draft round is exactly
    p-distributed, whatever the proposal: the statistical half of the
    losslessness contract."""
    rng = np.random.default_rng(0)
    V = 6
    p = np.array([[0.35, 0.05, 0.2, 0.1, 0.25, 0.05],
                  [1 / V] * V])            # bonus row (unused on reject)
    q = np.array([[0.1, 0.4, 0.1, 0.2, 0.1, 0.1]])
    n = 40_000
    out = []
    for _ in range(n):
        d = rng.choice(V, p=q[0])
        committed = rejection_verify(
            rng, p, [d] if not point_mass else [int(np.argmax(q[0]))],
            None if point_mass else q)
        out.append(committed[0])
    emp = _hist(out, V)
    # 3-sigma-ish band for n=40k multinomial cells
    assert np.abs(emp - p[0]).max() < 0.01, (emp, p[0])


def test_rejection_verify_full_acceptance_bonus_distribution():
    """Proposal == target: every draft accepted, the bonus token is drawn
    from the last row."""
    rng = np.random.default_rng(1)
    V = 4
    p = np.array([[0.25, 0.25, 0.25, 0.25],
                  [0.7, 0.1, 0.1, 0.1]])
    out = []
    for _ in range(20_000):
        d = rng.choice(V, p=p[0])
        committed = rejection_verify(rng, p, [d], p[:1])
        assert committed[0] == d          # q == p: acceptance is certain
        assert len(committed) == 2
        out.append(committed[1])
    emp = _hist(out, V)
    assert np.abs(emp - p[1]).max() < 0.015, emp


def test_target_probs_is_filtered_softmax():
    import jax.numpy as jnp
    lg = jnp.asarray([[1.0, 2.0, 3.0, 0.5, -1.0, 99.0]])
    # padding column (index 5) is cut by real_vocab
    p = target_probs(lg, SamplerConfig(temperature=1.0), 5)
    ref = np.exp([1.0, 2.0, 3.0, 0.5, -1.0])
    ref /= ref.sum()
    assert np.allclose(p[0], ref, atol=1e-6)
    assert abs(p[0].sum() - 1.0) < 1e-9
    # top_k=2 keeps exactly the two largest
    p2 = target_probs(lg, SamplerConfig(temperature=1.0, top_k=2), 5)
    assert (p2[0] > 0).sum() == 2 and p2[0, 2] > p2[0, 1] > 0


# ----------------------------------------------------------------------------
# draft providers
# ----------------------------------------------------------------------------
def test_ngram_draft_continues_repeated_pattern():
    d = NgramDraft(max_ngram=3)
    d.reset([1, 2, 3, 4, 9, 9, 1, 2, 3])
    toks, probs = d.propose(3)
    assert probs is None                  # point-mass draft
    assert list(toks[:2]) == [4, 9]       # continuation of the earlier match
    d.observe([4])
    toks, _ = d.propose(2)
    assert list(toks[:1]) == [9]          # match shifted by the new token


def test_ngram_draft_fallback_repeats_last():
    d = NgramDraft()
    d.reset([7])
    toks, _ = d.propose(4)
    assert list(toks) == [7, 7, 7, 7]


def test_small_model_draft_propose_is_snapshot(smoke_model):
    """propose() must not advance the committed cache: two proposals from
    the same state are identical, and observe() actually moves it.
    (The full cross-provider contract lives in test_draft_conformance.py;
    this pins the shift-by-one behaviour of the greedy model draft.)"""
    from repro.specdec import SmallModelDraft

    cfg, params = smoke_model
    d = SmallModelDraft(cfg, params, max_len=32)
    d.reset([3, 1, 4, 1, 5])
    a, _ = d.propose(3)
    b, _ = d.propose(3)
    assert list(a) == list(b)
    d.observe([int(a[0])])
    c, _ = d.propose(3)
    # after observing the first proposed token, the remaining proposal
    # shifts by one (greedy draft is deterministic)
    assert list(c[:2]) == list(a[1:])


# ----------------------------------------------------------------------------
# multi-query kernels (bit-wise contracts)
# ----------------------------------------------------------------------------
def _paged_case(key, B=2, Q=3, KV=2, G=2, dh=16, ps=8, P=12, dtype=None):
    import jax
    import jax.numpy as jnp
    dtype = dtype or jnp.bfloat16
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, Q, KV * G, dh), dtype)
    kp = jax.random.normal(k2, (P, ps, KV, dh), dtype)
    vp = jax.random.normal(k3, (P, ps, KV, dh), dtype)
    bt = jnp.array([[5, 2, -1], [7, 0, 3]], jnp.int32)
    ctx = jnp.array([14, 19], jnp.int32)      # incl. the Q new positions
    return q, kp, vp, bt, ctx


@pytest.mark.parametrize("window", [None, 6])
def test_mq_paged_kernel_bitwise_vs_blocked_ref_bf16(window):
    import jax
    import jax.numpy as jnp

    from repro.kernels.decode_attention import multiquery as mq
    q, kp, vp, bt, ctx = _paged_case(jax.random.PRNGKey(0))
    out_k = mq.mq_paged_decode_attention(q, kp, vp, bt, ctx, window=window)
    out_r = mq.mq_paged_decode_attention_ref(q, kp, vp, bt, ctx,
                                             window=window)
    assert out_k.dtype == jnp.bfloat16
    assert bool((out_k.view(jnp.uint16) == out_r.view(jnp.uint16)).all())


@pytest.mark.parametrize("dtype_name", ["bfloat16", "float32"])
def test_mq_paged_qlen1_reduces_to_paged_kernel(dtype_name):
    import jax
    import jax.numpy as jnp

    from repro.kernels.decode_attention import multiquery as mq
    from repro.kernels.decode_attention import paged as pg
    dtype = getattr(jnp, dtype_name)
    q, kp, vp, bt, ctx = _paged_case(jax.random.PRNGKey(1), Q=1,
                                     dtype=dtype)
    a = mq.mq_paged_decode_attention(q, kp, vp, bt, ctx)
    b = pg.paged_decode_attention(q, kp, vp, bt, ctx)
    bits = jnp.uint16 if dtype == jnp.bfloat16 else jnp.uint32
    assert bool((a.view(bits) == b.view(bits)).all())


def test_mq_contiguous_qlen1_reduces_to_decode_kernel():
    import jax
    import jax.numpy as jnp

    from repro.kernels.decode_attention import multiquery as mq
    from repro.kernels.decode_attention import ops as da_ops
    key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    B, KV, G, dh, S_c = 2, 2, 2, 16, 24
    q = jax.random.normal(k1, (B, 1, KV * G, dh), jnp.bfloat16)
    kc = jax.random.normal(k2, (B, S_c, KV, dh), jnp.bfloat16)
    vc = jax.random.normal(k3, (B, S_c, KV, dh), jnp.bfloat16)
    pos_ids = jnp.where(jnp.arange(S_c) < 14, jnp.arange(S_c), -1)
    a = mq.mq_decode_attention(q, kc, vc, pos_ids, jnp.int32(13))
    b = da_ops.decode_attention(q, kc, vc, pos_ids, jnp.int32(13))
    assert bool((a.view(jnp.uint16) == b.view(jnp.uint16)).all())


def test_mq_contiguous_matches_einsum_ref():
    import jax
    import jax.numpy as jnp

    from repro.kernels.decode_attention import multiquery as mq
    from repro.models.attention import mq_decode_attention_ref
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    B, Q, KV, G, dh, S_c = 2, 3, 2, 2, 16, 24
    q = jax.random.normal(k1, (B, Q, KV * G, dh), jnp.float32)
    kc = jax.random.normal(k2, (B, S_c, KV, dh), jnp.float32)
    vc = jax.random.normal(k3, (B, S_c, KV, dh), jnp.float32)
    pos_ids = jnp.where(jnp.arange(S_c) < 14, jnp.arange(S_c), -1)
    a = mq.mq_decode_attention(q, kc, vc, pos_ids, jnp.int32(11))
    b = mq_decode_attention_ref(q, kc, vc, pos_ids, jnp.int32(11),
                                window=None)
    assert float(jnp.abs(a - b).max()) < 1e-5


# ----------------------------------------------------------------------------
# model.verify_step: multi-token scoring == sequential decode + rollback
# ----------------------------------------------------------------------------
def test_verify_step_equals_sequential_decode_and_rolls_back(tiny_dense_cfg):
    import functools

    import jax
    import jax.numpy as jnp

    from repro.models import model as M
    cfg = tiny_dense_cfg
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 5), 0, cfg.vocab_size)
    cache = M.init_cache(cfg, 2, 24)
    logits, cache = jax.jit(functools.partial(M.prefill, cfg))(
        params, toks, cache)

    seq_logits, fed = [], []
    c1 = dict(cache)
    cur = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None] \
        .astype(jnp.int32)
    fed.append(cur)
    for _ in range(3):
        lg, c1 = M.decode_step(cfg, params, c1, cur)
        seq_logits.append(lg[:, 0])
        cur = jnp.argmax(lg[:, 0, :cfg.vocab_size], -1)[:, None] \
            .astype(jnp.int32)
        fed.append(cur)

    vt = jnp.concatenate(fed[:3], axis=1)
    vl, c2 = M.verify_step(cfg, params, dict(cache), vt)
    sl = jnp.stack(seq_logits, 1)
    assert float(jnp.abs(vl.astype(jnp.float32)
                         - sl.astype(jnp.float32)).max()) < 1e-5
    assert int(c2["pos"]) == int(cache["pos"]) + 3

    # rollback: commit 1 of 3 by resetting pos; the next sequential step
    # must exactly reproduce the sequential path (stale future entries
    # are masked by pos_ids > pos)
    c2 = dict(c2)
    c2["pos"] = cache["pos"] + 1
    lg_a, _ = M.decode_step(cfg, params, c2, fed[1])
    assert float(jnp.abs(lg_a[:, 0].astype(jnp.float32)
                         - seq_logits[1].astype(jnp.float32)).max()) < 1e-6


def test_verify_step_rejects_recurrent_families():
    import jax

    from repro.configs.base import AttnKind, Family, ModelConfig
    from repro.models import model as M
    cfg = ModelConfig(name="s", family=Family.SSM, n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=0, d_ff=64, vocab_size=64,
                      head_dim=8, attn_kind=AttnKind.NONE,
                      ssm_state_size=16)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, 1, 16)
    with pytest.raises(NotImplementedError):
        M.verify_step(cfg, params, cache,
                      np.zeros((1, 3), np.int32))


# ----------------------------------------------------------------------------
# paged KV rollback: block-table truncation
# ----------------------------------------------------------------------------
def test_block_table_truncate_frees_only_rejected_pages():
    from repro.kvcache import PagedKVConfig, PagedKVManager, PagePool
    mgr = PagedKVManager(PagePool(PagedKVConfig(page_size=4,
                                                device_pages=8)))
    assert mgr.admit(0, 10)               # 3 pages
    assert mgr.extend(0, 15)              # 4 pages (spec round drafts 5)
    assert mgr.pages_of(0) == 4
    dropped = mgr.truncate(0, 11)         # commit 1 of 5
    assert dropped == 1 and mgr.pages_of(0) == 3
    assert mgr.tokens_of(0) == 11
    assert mgr.pool.free_pages() == 5
    # partial page shared by committed + rejected slots stays allocated
    assert mgr.truncate(0, 9) == 0 and mgr.pages_of(0) == 3
    assert mgr.truncate(0, 8) == 1 and mgr.pages_of(0) == 2


def test_paged_decode_verify_commit_lossless_vs_dense(tiny_dense_cfg):
    """Spec decode over PagedDecodeCache (verify + truncating commit)
    emits token-for-token the dense autoregressive sequence."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.kvcache.paged_decode import PagedDecodeCache
    from repro.models import model as M
    cfg = tiny_dense_cfg
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                              cfg.vocab_size)
    cache = M.init_cache(cfg, 2, 32)
    logits, cache = jax.jit(functools.partial(M.prefill, cfg))(
        params, toks, cache)
    first = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)

    # dense AR reference
    c1 = dict(cache)
    cur = first[:, None].astype(jnp.int32)
    want = [[int(first[b])] for b in range(2)]
    for _ in range(6):
        lg, c1 = M.decode_step(cfg, params, c1, cur)
        cur = jnp.argmax(lg[:, 0, :cfg.vocab_size], -1)[:, None] \
            .astype(jnp.int32)
        for b in range(2):
            want[b].append(int(cur[b, 0]))

    # paged spec decode: garbage drafts, greedy verification
    pc = PagedDecodeCache(cfg, 2, 32, page_size=4)
    pc.seed(cache)
    got = [[int(first[b])] for b in range(2)]
    cur = np.array(first)[:, None].astype(np.int32)
    rng = np.random.default_rng(0)
    freed_any = False
    while min(len(g) for g in got) < 7:
        k = 3
        draft = rng.integers(0, cfg.vocab_size, (2, k)).astype(np.int32)
        mat = np.concatenate([cur, draft], axis=1)
        lg = np.asarray(pc.verify(params, mat), np.float32)
        after_verify = pc.pages_in_use
        committed = [greedy_verify(lg[b], draft[b], cfg.vocab_size)
                     for b in range(2)]
        c = min(len(x) for x in committed)
        pc.commit(c)
        freed_any |= pc.pages_in_use < after_verify
        for b in range(2):
            got[b].extend(committed[b][:c])
            cur[b, 0] = committed[b][c - 1]
    got = [g[:7] for g in got]
    assert got == want, (got, want)
    assert freed_any                      # rollback actually freed pages


# ----------------------------------------------------------------------------
# serving integration (sim_backend: the conftest E3 fleet factory)
# ----------------------------------------------------------------------------
def test_sim_spec_exact_counts_and_counters(sim_backend):
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               make_arrivals, requests_from_arrivals,
                               summarize)
    arr = make_arrivals("bursty", 8, seed=0, burst_size=4, gap_s=4.0,
                        prompt_len=64, max_new_tokens=19)
    sched = ContinuousBatchingScheduler(
        sim_backend(4, spec=SpecConfig(k=4, acceptance=0.6, seed=0)),
        SchedulerConfig())
    done = sched.serve(requests_from_arrivals(arr))
    assert all(r.done and r.generated == 19 for r in done)
    rep = summarize(done, pattern="bursty", backend="sim",
                    stats=sched.stats)
    assert rep.spec_rounds > 0 and rep.spec_drafted > 0
    assert 0.0 < rep.spec_acceptance_rate < 1.0
    assert rep.spec_accepted <= rep.spec_drafted
    assert np.isfinite(rep.decode_tok_s_p50)


def test_sim_spec_beats_autoregressive_throughput(sim_backend):
    """The bench_specdec acceptance invariant, in-suite."""
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               make_arrivals, requests_from_arrivals,
                               summarize)
    out = {}
    for name, spec in (("ar", None),
                       ("spec", SpecConfig(k=4, acceptance=0.6, seed=0))):
        arr = make_arrivals("sporadic", 4, seed=0, gap_s=4.0,
                            prompt_len=64, max_new_tokens=24)
        sched = ContinuousBatchingScheduler(sim_backend(1, spec=spec),
                                            SchedulerConfig())
        done = sched.serve(requests_from_arrivals(arr))
        out[name] = summarize(done, pattern="sporadic", backend="sim",
                              stats=sched.stats)
    assert out["spec"].throughput_tok_s > out["ar"].throughput_tok_s


def test_sim_resident_spec_acceptance_and_depth_follow_tier(sim_backend):
    """draft='resident' in the simulator: acceptance scales with the
    plan's resident fraction and the DepthController shrinks k with it
    (DESIGN.md §14). E3/llama2-13b allocates fully resident, so the base
    plan sits at the configured acceptance; a fully demoted plan drops to
    the clipped floor and k collapses to 1."""
    import dataclasses

    from repro.core.cost_model import ExecutionPlan

    full = sim_backend(1, spec=SpecConfig(k=6, draft="resident",
                                          acceptance=0.9, seed=0))
    assert full._res_frac0 == pytest.approx(1.0)
    assert full._spec_acceptance() == pytest.approx(0.9)
    assert full._spec_k() == 6          # 0.9/(1-0.9) = 9, clipped to k

    base = full.plan
    stages = [dataclasses.replace(
        st, resident_total=0,
        off_full_seg=st.off_full_seg + st.resident_total // base.n_seg)
        for st in base.stages]
    thin = sim_backend(1, spec=SpecConfig(k=6, draft="resident",
                                          acceptance=0.9, seed=0),
                       plan=ExecutionPlan(n_seg=base.n_seg, stages=stages))
    assert thin._res_frac0 == pytest.approx(0.0)
    assert thin._spec_acceptance() == pytest.approx(0.02)   # clip floor
    assert thin._spec_k() == 1


def test_controller_external_drafts_mode(tiny_dense_cfg):
    """The engine backend drafts on-device: the controller must build no
    host providers, treat begin/observe as no-ops, and refuse propose."""
    from repro.specdec import SpecDecodeController
    ctl = SpecDecodeController(SpecConfig(k=3, draft="resident"),
                               SamplerConfig(), tiny_dense_cfg, 2,
                               external_drafts=True)
    assert ctl.drafts is None
    ctl.begin(0, [1, 2, 3])
    ctl.observe(0, [4])
    with pytest.raises(AssertionError):
        ctl.propose(0, 3)


@pytest.mark.parametrize("draft", ["ngram", "resident"])
@pytest.mark.parametrize("paged", [False, True])
def test_engine_backend_spec_lossless_single_device(paged, draft,
                                                    smoke_model):
    """Greedy spec serving == autoregressive serving, token for token,
    through the dense and paged single-device paths, for both the n-gram
    and the resident-tier self-draft (DESIGN.md §14)."""
    from repro.serving import (ContinuousBatchingScheduler, EngineBackend,
                               Request, SchedulerConfig)
    cfg, params = smoke_model

    def run(spec):
        be = EngineBackend(cfg, params, n_slots=2, max_len=48, paged=paged,
                           page_size=8, spec=spec)
        reqs = [Request(0, None, max_new_tokens=12, prompt_len=6),
                Request(1, None, max_new_tokens=9, prompt_len=4)]
        done = ContinuousBatchingScheduler(be, SchedulerConfig()).serve(
            reqs)
        return {r.rid: list(r.output) for r in done}, be

    base, _ = run(None)
    spec_out, be = run(SpecConfig(k=3, draft=draft))
    assert base == spec_out
    assert be.spec_stats["spec_rounds"] > 0


def test_engine_backend_spec_model_draft_accepts(smoke_model):
    """A draft that shares the target's weights accepts most tokens —
    the accept path (not just rejection) is exercised end to end."""
    from repro.serving import (ContinuousBatchingScheduler, EngineBackend,
                               Request, SchedulerConfig)
    cfg, params = smoke_model

    def run(spec):
        be = EngineBackend(cfg, params, n_slots=1, max_len=48, spec=spec)
        reqs = [Request(0, None, max_new_tokens=12, prompt_len=6)]
        done = ContinuousBatchingScheduler(be, SchedulerConfig()).serve(
            reqs)
        return {r.rid: list(r.output) for r in done}, be

    base, _ = run(None)
    out, be = run(SpecConfig(k=3, draft="model", draft_arch="gemma3-1b"))
    assert base == out
    assert be.spec_stats["spec_accepted"] > 0


def test_engine_backend_spec_stochastic_counts(smoke_model):
    """temperature > 0: the rejection sampler drives serving to exact
    per-request token counts (distribution-level losslessness is
    test_rejection_verify_matches_target_distribution)."""
    from repro.serving import (ContinuousBatchingScheduler, EngineBackend,
                               Request, SchedulerConfig)
    from repro.serving.sampling import SamplerConfig as SC
    cfg, params = smoke_model
    be = EngineBackend(cfg, params, n_slots=2, max_len=48,
                       sampler=SC(temperature=0.8, top_p=0.95),
                       spec=SpecConfig(k=3, draft="ngram", seed=7))
    reqs = [Request(0, None, max_new_tokens=10, prompt_len=6),
            Request(1, None, max_new_tokens=7, prompt_len=4)]
    done = ContinuousBatchingScheduler(be, SchedulerConfig()).serve(reqs)
    by = {r.rid: r for r in done}
    assert by[0].generated == 10 and len(by[0].output) == 10
    assert by[1].generated == 7 and len(by[1].output) == 7
    assert all(0 <= t < cfg.vocab_size
               for r in done for t in r.output)


# ----------------------------------------------------------------------------
# the interleaved engine: one pipeline round verifies k tokens
# ----------------------------------------------------------------------------
ENGINE_WORKER = r"""
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig, Family
import repro.core.engine as E
from repro.models import model as M
from repro.serving import (ContinuousBatchingScheduler, Request,
                           SchedulerConfig, EngineBackend)
from repro.specdec import SpecConfig

cfg = ModelConfig(name="d", family=Family.DENSE, n_layers=8, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16)
params = M.init_params(cfg, jax.random.PRNGKey(0))

# ref on the partial-auto (stage x model) mesh; pallas on the stage-only
# mesh (old XLA's partitioner rejects Pallas calls in partial-auto
# regions — a pre-existing engine limitation, independent of q_len)
for impl, shape, axes in (("ref", (4, 2), ("data", "model")),
                          ("pallas", (4,), ("data",))):
    mesh = jax.make_mesh(shape, axes)
    def run(spec):
        eng = E.InterleavedEngine(cfg, mesh, E.UniformPlan(4, 2, 0, 1),
                                  n_mb=2, mb=1, max_len=48, impl=impl)
        be = EngineBackend(cfg, params, engine=eng, n_slots=2, max_len=48,
                           spec=spec)
        reqs = [Request(0, None, max_new_tokens=10, prompt_len=6),
                Request(1, None, max_new_tokens=8, prompt_len=4)]
        done = ContinuousBatchingScheduler(be, SchedulerConfig()).serve(reqs)
        return {r.rid: list(r.output) for r in done}, be
    base, _ = run(None)
    spec_out, be = run(SpecConfig(k=3, draft="ngram"))
    stats = be.spec_stats
    ok = base == spec_out and stats["spec_rounds"] > 0
    print(f"{impl}: spec==AR {base == spec_out} stats={stats}")
    assert ok, (impl, base, spec_out)
print("ENGINE_SPEC_OK")
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_engine_spec_decode_lossless_ref_and_pallas(run_worker):
    """temperature=0 spec decoding through the InterleavedEngine equals
    autoregressive decoding token-for-token, on both the ref and Pallas
    attention paths (subprocess: needs >= 4 host devices)."""
    r = run_worker(ENGINE_WORKER)
    assert r.returncode == 0 and "ENGINE_SPEC_OK" in r.stdout
