"""LIME core algorithm tests: cost model, Alg. 1, planner, Alg. 2.

Property-based (hypothesis) over heterogeneous device fleets: the offline
scheduler must always produce memory-feasible, layer-complete plans, and
its DP must never be beaten by naive balanced offloading.
"""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.core.cost_model import CostEnv, Workload
from repro.core.offline_scheduler import allocate
from repro.core.online_planner import OnlinePlanner, _min_load_plan
from repro.core.kv_transfer import KVTransferProtocol
from repro.core.profiles import (AGX_ORIN_32, AGX_ORIN_64, XAVIER_NX_16,
                                 DeviceProfile, GB, env_E3, mbps)

CFG = get_config("llama2-13b")


def make_env(devices, bw=mbps(200), mb=1, nm=1, ctx=512):
    return CostEnv(devices, bw, Workload(CFG, mb=mb, ctx=ctx, n_micro=nm))


# ----------------------------------------------------------------------------
# deterministic behaviour
# ----------------------------------------------------------------------------
def test_fits_without_offloading_uses_zero_load():
    env = make_env([AGX_ORIN_64, AGX_ORIN_64])
    r = allocate(env, CFG.n_layers)
    assert r.feasible
    assert r.plan.n_seg == 1
    assert all(d.off_layers_seg() == 0 for d in r.plan.devices)
    assert r.plan.layers_total() == CFG.n_layers
    assert r.plan.t_uncover == 0.0


def test_memory_pressure_triggers_offload():
    small = XAVIER_NX_16.scaled_mem(0.55)
    env = make_env([small, small], ctx=1024)
    r = allocate(env, CFG.n_layers, n_emp=1024)
    assert r.feasible, r.reason
    assert r.plan.layers_total() == CFG.n_layers
    assert any(d.off_layers_seg() > 0 for d in r.plan.devices)
    assert r.plan.n_seg >= 2
    assert env.mem_ok(r.plan, 1024)


def test_infeasible_when_kv_exceeds_aggregate():
    """26 GB weights + full-context KV > 2 x 5.2 GB: correctly rejected
    (KV lives on-device in LIME; only weights stream)."""
    small = XAVIER_NX_16.scaled_mem(0.45)
    env = make_env([small, small], ctx=2048)
    r = allocate(env, CFG.n_layers, n_emp=2048)
    assert not r.feasible


def test_infeasible_when_nothing_fits():
    tiny = XAVIER_NX_16.scaled_mem(0.01)
    env = make_env([tiny])
    r = allocate(env, CFG.n_layers)
    assert not r.feasible


def test_eq1_terms_positive_and_additive():
    env = make_env(env_E3())
    r = allocate(env, CFG.n_layers)
    p = r.plan
    assert p.t_total == pytest.approx(p.t_comp + p.t_comm + p.t_uncover)
    assert p.t_comp > 0 and p.t_comm > 0 and p.t_uncover >= 0


def test_fine_grained_blocks_reduce_load():
    """With spare memory, refinement pins MHA/MLP blocks: per-segment load
    bytes strictly below full-layer offloading."""
    small = AGX_ORIN_32.scaled_mem(0.62)
    env = make_env([small, small, small], ctx=256)
    r = allocate(env, CFG.n_layers, n_emp=256)
    assert r.feasible, r.reason
    w = env.work
    for d in r.plan.devices:
        full = d.off_layers_seg() * w.l_size
        assert d.load_bytes_seg(w) <= full + 1e-6


# ----------------------------------------------------------------------------
# property tests
# ----------------------------------------------------------------------------
@st.composite
def fleets(draw):
    n = draw(st.integers(2, 6))
    devs = []
    for i in range(n):
        mem = draw(st.floats(6, 64))
        flops = draw(st.floats(2, 120))
        load = draw(st.floats(0.5, 3.0))
        devs.append(DeviceProfile(
            name=f"d{i}", mem_bytes=mem * GB, flops=flops * 1e12,
            mem_bw=60e9 + flops * 1e9, load_bw=load * 1e9))
    return devs


@given(fleets(), st.integers(1, 4), st.sampled_from([100, 200, 500]))
@settings(max_examples=40, deadline=None)
def test_allocate_invariants(devs, nm, bw_mbps):
    env = CostEnv(devs, mbps(bw_mbps),
                  Workload(CFG, mb=1, ctx=512, n_micro=nm))
    r = allocate(env, CFG.n_layers, n_emp=512)
    if not r.feasible:
        return
    p = r.plan
    # every layer placed exactly once
    assert p.layers_total() == CFG.n_layers
    # paper constraint: 2 <= #Seg <= ceil(|L|/|D|) when offloading
    if any(d.off_layers_seg() for d in p.devices):
        assert 2 <= p.n_seg <= max(math.ceil(CFG.n_layers / len(devs)), 2)
    # memory feasibility at the empirical horizon
    assert env.mem_ok(p, 512)
    # cost terms are finite and non-negative
    assert p.t_comp >= 0 and p.t_comm >= 0 and p.t_uncover >= 0
    assert p.t_total < float("inf")


@given(fleets(), st.sampled_from([128, 512, 1024]),
       st.sampled_from([100, 200, 500]))
@settings(max_examples=40, deadline=None)
def test_execution_plan_budget_and_coverage(devs, n_emp, bw_mbps):
    """ISSUE 5 S3: every ExecutionPlan the offline scheduler emits over a
    random heterogeneous fleet (i) covers exactly n_layers in its cost
    view, (ii) keeps every stage's resident weights + KV reserve inside
    that stage's memory budget (checked directly, not via mem_ok), and
    (iii) presents engine-facing geometry whose padded grid covers the
    model with per-stage splits consistent with the stage allocs."""
    env = CostEnv(devs, mbps(bw_mbps), Workload(CFG, mb=1, ctx=n_emp))
    r = allocate(env, CFG.n_layers, n_emp=n_emp)
    if not r.feasible:
        return
    p = r.plan
    w = env.work
    assert p.layers_total() == CFG.n_layers
    for i, stg in enumerate(p.stages):
        used = (stg.resident_bytes(w, p.n_seg)
                + stg.layers_total(p.n_seg) * n_emp
                * w.kv_bytes_per_token_layer())
        assert used <= devs[i].mem_bytes + 1e-6, (i, used, devs[i].mem_bytes)
    # engine-facing geometry: the padded grid covers the model and each
    # stage's chunk is its alloc's whole-layer view
    assert p.n_layers >= CFG.n_layers
    assert p.n_stage == len(devs)
    for stg, kr, ko in zip(p.stages, p.k_res_list, p.k_off_list):
        assert kr == -(-stg.resident_total // p.n_seg)
        assert ko == stg.off_layers_seg()
    assert p.k_max == max(r + o for r, o in zip(p.k_res_list, p.k_off_list))


@st.composite
def measured_fleets(draw):
    """fleets() whose members are MeasuredProfiles: every throughput
    field independently perturbed by up to 3x either way — the
    harness-on-a-noisy-box case the autotuner must plan through."""
    from repro.tune.profiles import MEASURED_FIELDS, from_analytic
    devs = draw(fleets())
    out = []
    for d in devs:
        factors = {f: draw(st.floats(1 / 3, 3.0)) for f in MEASURED_FIELDS}
        out.append(from_analytic(
            d, device_kind="hyp", source="measured",
            **{f: getattr(d, f) * v for f, v in factors.items()
               if getattr(d, f) > 0}))
    return out


@given(measured_fleets(), st.sampled_from([128, 512, 1024]),
       st.sampled_from([100, 200, 500]))
@settings(max_examples=40, deadline=None)
def test_allocate_over_measured_profiles(devs, n_emp, bw_mbps):
    """ISSUE 10 S3: allocate() over randomly perturbed MeasuredProfile
    fleets (the DeviceProfile subtype the harness emits) preserves the
    per-stage memory budget and exact layer coverage — measurement noise
    moves the *plan*, never breaks its feasibility invariants."""
    env = CostEnv(devs, mbps(bw_mbps), Workload(CFG, mb=1, ctx=n_emp))
    r = allocate(env, CFG.n_layers, n_emp=n_emp)
    if not r.feasible:
        return
    p = r.plan
    w = env.work
    assert p.layers_total() == CFG.n_layers
    for i, stg in enumerate(p.stages):
        used = (stg.resident_bytes(w, p.n_seg)
                + stg.layers_total(p.n_seg) * n_emp
                * w.kv_bytes_per_token_layer())
        assert used <= devs[i].mem_bytes + 1e-6, (i, used, devs[i].mem_bytes)
    assert env.mem_ok(p, n_emp)
    assert p.t_total < float("inf")


@given(st.integers(1, 8), st.integers(0, 8), st.integers(2, 6),
       st.floats(0.1, 4.0))
@settings(max_examples=60, deadline=None)
def test_min_load_plan_optimality(a_max, b_max, n_seg, need_gb):
    """Eq. 6/7: the chosen (alpha, beta) is feasible and no cheaper feasible
    combination exists (exhaustive check on the small domain)."""
    attn_b, mlp_b = 0.3e9, 1.2e9
    need = need_gb * 1e9
    got = _min_load_plan(need, attn_b, mlp_b, a_max, b_max, n_seg)
    factor = max(n_seg - 1, 1)
    feas = [(a, b) for a in range(a_max + 1) for b in range(b_max + 1)
            if (a * attn_b + b * mlp_b) * factor >= need]
    if not feas:
        assert got is None or (got[0] * attn_b + got[1] * mlp_b) * factor \
            < need
        return
    assert got in feas
    best = min(a * attn_b + b * mlp_b for a, b in feas)
    assert got[0] * attn_b + got[1] * mlp_b == pytest.approx(best)


def test_planner_thresholds_monotone():
    env = make_env(env_E3(), ctx=2048)
    r = allocate(env, get_config("llama3.3-70b").n_layers, n_emp=2048)
    # build planner against the 70B workload
    env70 = CostEnv(env_E3(), mbps(200),
                    Workload(get_config("llama3.3-70b"), mb=1, ctx=2048))
    r = allocate(env70, 80, n_emp=2048)
    assert r.feasible
    pl = OnlinePlanner(env70, r.plan, horizon_tokens=2 ** 18)
    for lad in pl.ladders:
        ts = [s.threshold_tokens for s in lad]
        assert ts == sorted(ts)
        # eviction volume never shrinks as pressure grows
        freed = [s.alpha * env70.work.attn_block_bytes
                 + s.beta * env70.work.mlp_block_bytes for s in lad]
        assert all(b >= a - 1e-6 for a, b in zip(freed, freed[1:]))


def test_kv_transfer_targets_and_bandwidth_rules():
    cfg70 = get_config("llama3.3-70b")
    devs = [XAVIER_NX_16, AGX_ORIN_32, AGX_ORIN_64, AGX_ORIN_64]
    env = CostEnv(devs, mbps(200), Workload(cfg70, mb=1, ctx=4096))
    r = allocate(env, cfg70.n_layers, n_emp=4096)
    assert r.feasible
    pl = OnlinePlanner(env, r.plan, horizon_tokens=2 ** 18)
    proto = KVTransferProtocol(env, r.plan, pl, n_ts=4)
    # a device is either a target or has one
    for stt in proto.states:
        assert (stt.target is None) or (0 <= stt.target < len(devs))
        if stt.target is not None:
            assert proto.states[stt.target].target is None
    proto.init_transfers(ctx_tokens=4096)
    before = [s.n_trans for s in proto.states]
    # bandwidth drop -> immediate recompute (volumes can only shrink)
    proto.on_bandwidth(mbps(100), total_tokens=4096)
    after = [s.n_trans for s in proto.states]
    for b, a, stt in zip(before, after, proto.states):
        if stt.target is not None:
            assert a <= b + proto.n_ts
